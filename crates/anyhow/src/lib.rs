//! Offline substitute for the `anyhow` crate — the API-compatible subset
//! this repository uses (the container image carries no crates.io registry,
//! so external dependencies are vendored as minimal reimplementations; see
//! the workspace `Cargo.toml`).
//!
//! Supported surface:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value carrying a message
//!   and a chain of context strings.
//! * [`Result<T>`](Result) — `Result<T, Error>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — message/format-style
//!   constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, matching anyhow's semantics (the new message becomes the
//!   outermost description; prior descriptions surface via `Debug`).
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what keeps the blanket
//! `From<E: std::error::Error>` conversion (which powers `?`) coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: outermost message plus the chain of causes beneath it.
pub struct Error {
    /// Outermost description (most recently attached context, or the root
    /// message when no context has been added).
    msg: String,
    /// Underlying descriptions, outermost-first (the `Caused by:` chain).
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), chain: Vec::new() }
    }

    /// Create an error from anything implementing `std::error::Error`,
    /// capturing its source chain as context lines.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = Vec::new();
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { msg: error.to_string(), chain }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Self { msg: context.to_string(), chain }
    }

    /// The `Caused by:` descriptions, outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) description.
    pub fn root_cause(&self) -> &str {
        self.chain.last().unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Context attachment for fallible values, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: `{}`",
                stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);

        fn failing() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(e.root_cause(), "no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key '{}'", "vocab")).unwrap_err();
        assert_eq!(e.to_string(), "missing key 'vocab'");

        // Context on an already-anyhow Result stacks.
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["inner"]);
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 7;
        let e = anyhow!("value {v} and {}", 8);
        assert_eq!(e.to_string(), "value 7 and 8");

        fn bails() -> Result<()> {
            bail!("gone {}", "wrong");
        }
        assert_eq!(bails().unwrap_err().to_string(), "gone wrong");

        fn ensures(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            Ok(x)
        }
        assert_eq!(ensures(2).unwrap(), 2);
        assert_eq!(ensures(12).unwrap_err().to_string(), "x too big: 12");
        assert!(ensures(3).unwrap_err().to_string().contains("x != 3"));
    }

    #[test]
    fn debug_prints_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("step A").unwrap_err().context("step B");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("step B"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("step A"));
        assert!(dbg.contains("no such file"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
