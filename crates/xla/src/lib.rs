//! Host-side stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The FlashSampling L3 runtime executes AOT-lowered HLO artifacts through
//! the PJRT C API via the real `xla` crate.  That crate links a multi-GB
//! native `xla_extension`, which this offline image does not carry, so the
//! workspace substitutes this stub exposing the exact API subset the
//! repository uses:
//!
//! * [`Literal`] — **fully functional** host tensors (create from untyped
//!   bytes, read back typed vectors, shape/dtype introspection).  Unit
//!   tests of the `Tensor` conversion layer run against this for real.
//! * [`PjRtClient`] / [`PjRtLoadedExecutable`] / [`HloModuleProto`] —
//!   type-correct stubs whose constructors return [`Error::PjrtUnavailable`]
//!   at **runtime**.  Integration tests and examples detect the missing
//!   `artifacts/` directory first, so the default `cargo test` never hits
//!   these paths.
//!
//! Swapping in the real backend requires no source change: `[patch]` this
//! crate with xla-rs and build with `--features pjrt` (see README §PJRT).

use std::borrow::Borrow;
use std::fmt;

/// Stub error type, mirroring the shape of xla-rs's `Error`.
#[derive(Clone, Debug)]
pub enum Error {
    /// The operation needs a live PJRT plugin, which this stub does not
    /// link.
    PjrtUnavailable(&'static str),
    /// Malformed usage of the host-literal layer.
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::PjrtUnavailable(what) => write!(
                f,
                "{what}: PJRT runtime unavailable ({}); AOT artifact \
                 execution needs the real xla-rs crate patched into the \
                 workspace — see README.md, section PJRT",
                if cfg!(feature = "pjrt") {
                    "`pjrt` feature enabled, but this build still carries \
                     the host stub"
                } else {
                    "built without the `pjrt` feature"
                }
            ),
            Error::Usage(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes (subset of xla-rs's `ElementType`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Marker for element types the host literal layer can read back.
pub trait NativeType: Copy {
    /// The XLA dtype this Rust type stores.
    const TY: ElementType;
    /// Decode one element from little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn read_le(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn read_le(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn read_le(b: &[u8]) -> Self {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// Array shape: dtype + dimensions (xla-rs `ArrayShape` subset).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// A host tensor value (or tuple of them) — xla-rs `Literal` subset.
#[derive(Clone, Debug)]
pub enum Literal {
    /// Dense array: shape + raw little-endian bytes.
    Array { shape: ArrayShape, data: Vec<u8> },
    /// Tuple of literals (what tupled executions return).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from raw bytes (`create_from_shape_and_...`
    /// in xla-rs; same name kept so call sites are identical).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let expect: usize = dims.iter().product::<usize>() * ty.size_bytes();
        if untyped_data.len() != expect {
            return Err(Error::Usage(format!(
                "literal data has {} bytes, shape {dims:?} of {ty:?} needs {expect}",
                untyped_data.len()
            )));
        }
        Ok(Literal::Array {
            shape: ArrayShape { ty, dims: dims.iter().map(|&d| d as i64).collect() },
            data: untyped_data.to_vec(),
        })
    }

    /// Shape of an array literal (error on tuples, like xla-rs).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { shape, .. } => Ok(shape.clone()),
            Literal::Tuple(_) => {
                Err(Error::Usage("array_shape() on a tuple literal".into()))
            }
        }
    }

    /// Read the array back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Tuple(_) => Err(Error::Usage("to_vec() on a tuple literal".into())),
            Literal::Array { shape, data } => {
                if shape.ty != T::TY {
                    return Err(Error::Usage(format!(
                        "to_vec: literal is {:?}, requested {:?}",
                        shape.ty,
                        T::TY
                    )));
                }
                let n = shape.ty.size_bytes();
                Ok(data.chunks_exact(n).map(T::read_le).collect())
            }
        }
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => {
                Err(Error::Usage("to_tuple() on an array literal".into()))
            }
        }
    }
}

/// Parsed HLO module (stub: parsing needs the native extension).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::PjrtUnavailable("parsing HLO text"))
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in the stub: `HloModuleProto` cannot be constructed.
        XlaComputation { _private: () }
    }
}

/// A device-resident execution result (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::PjrtUnavailable("fetching execution result"))
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with owned or borrowed literal arguments (both
    /// `execute::<Literal>` and `execute::<&Literal>` type-check, as with
    /// xla-rs).
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::PjrtUnavailable("executing artifact"))
    }
}

/// A PJRT client (stub: construction reports the missing backend).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::PjrtUnavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::PjrtUnavailable("compiling computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|f| f.to_le_bytes())
            .collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &data,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.element_count(), 3);
    }

    #[test]
    fn literal_validates_size_and_type() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 15],
        )
        .is_err());
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[1],
            &7i32.to_le_bytes(),
        )
        .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_literals_destructure() {
        let a = Literal::create_from_shape_and_untyped_data(
            ElementType::U32,
            &[1],
            &5u32.to_le_bytes(),
        )
        .unwrap();
        let t = Literal::Tuple(vec![a.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
        assert!(a.to_tuple().is_err());
    }

    #[test]
    fn pjrt_paths_report_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
