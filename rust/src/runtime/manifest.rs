//! Artifact manifest — the Rust runtime's source of truth about what
//! `make artifacts` produced (see python/compile/aot.py for the schema).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

/// The artifact ABI version this runtime speaks.  v2 introduced the
/// per-row temperature vector (`tau: [B]` instead of a scalar) across
/// every sampling artifact; v3 adds the `decode_sample_sub_b{B}`
/// candidate-tile artifacts (sub-vocabulary decode, DESIGN.md §16) with
/// the `tiles: [S]` input and (winner score, hidden norm) outputs.
/// Manifests without a `version` key are v1.
pub const TAU_ABI_VERSION: u32 = 3;

/// Element dtype of an artifact input/output or weight tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            _ => bail!("unknown dtype '{s}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One tensor slot (input or output) of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_shape()?,
            dtype: DType::parse(v.req("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Artifact family: "flash_sample", "decode_sample", "prefill", ...
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form integers: B, D, V, tile_v, n_shards, ...
    pub meta: BTreeMap<String, i64>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .copied()
            .with_context(|| format!("artifact {}: missing meta '{key}'", self.name))
            .map(|v| v as usize)
    }
}

/// One exported weight tensor.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// The serving-model hyperparameters baked into the artifacts.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub param_order: Vec<String>,
    pub decode_buckets: Vec<usize>,
    pub prefill_t_buckets: Vec<usize>,
    pub prefill_b: usize,
}

impl ModelInfo {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Parsed manifest.json plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Artifact ABI version (see [`TAU_ABI_VERSION`]); 1 if absent.
    pub abi_version: u32,
    pub model: ModelInfo,
    pub artifacts: Vec<ArtifactSpec>,
    pub weights: Vec<WeightSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        // Pre-versioning manifests (scalar-tau artifacts) carry no key.
        let abi_version = match v.get("version") {
            Some(n) => n.as_usize()? as u32,
            None => 1,
        };

        let m = v.req("model")?;
        let model = ModelInfo {
            vocab: m.req("vocab")?.as_usize()?,
            d_model: m.req("d_model")?.as_usize()?,
            n_layers: m.req("n_layers")?.as_usize()?,
            n_heads: m.req("n_heads")?.as_usize()?,
            ffn: m.req("ffn")?.as_usize()?,
            max_seq: m.req("max_seq")?.as_usize()?,
            param_order: m
                .req("param_order")?
                .as_arr()?
                .iter()
                .map(|s| s.as_str().map(String::from))
                .collect::<Result<_>>()?,
            decode_buckets: m.req("decode_buckets")?.as_shape()?,
            prefill_t_buckets: m.req("prefill_t_buckets")?.as_shape()?,
            prefill_b: m.req("prefill_b")?.as_usize()?,
        };

        let mut artifacts = Vec::new();
        for a in v.req("artifacts")?.as_arr()? {
            let mut meta = BTreeMap::new();
            if let Ok(obj) = a.req("meta")?.as_obj() {
                for (k, val) in obj {
                    if let Ok(n) = val.as_f64() {
                        meta.insert(k.clone(), n as i64);
                    }
                }
            }
            artifacts.push(ArtifactSpec {
                name: a.req("name")?.as_str()?.to_string(),
                file: a.req("file")?.as_str()?.to_string(),
                kind: a.req("kind")?.as_str()?.to_string(),
                inputs: a
                    .req("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                meta,
            });
        }

        let mut weights = Vec::new();
        for w in v.req("weights")?.as_arr()? {
            weights.push(WeightSpec {
                name: w.req("name")?.as_str()?.to_string(),
                file: w.req("file")?.as_str()?.to_string(),
                shape: w.req("shape")?.as_shape()?,
                dtype: DType::parse(w.req("dtype")?.as_str()?)?,
            });
        }

        Ok(Self { dir, abi_version, model, artifacts, weights })
    }

    /// Refuse artifact sets whose tau ABI doesn't match this runtime.
    /// `Runtime::new` calls this, so every artifact consumer is covered;
    /// a v1 (scalar-tau) set would otherwise mis-consume the `tau: [B]`
    /// vector silently.
    pub fn ensure_tau_abi(&self) -> Result<()> {
        anyhow::ensure!(
            self.abi_version == TAU_ABI_VERSION,
            "artifact manifest has ABI v{} but this runtime speaks v{} \
             (tau: [B] per-row temperature + sub-vocab decode artifacts) \
             — re-run `make artifacts`",
            self.abi_version,
            TAU_ABI_VERSION
        );
        Ok(())
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// All artifacts of a kind, e.g. every "decode_sample" bucket.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Load a weight tensor as raw f32 (little-endian .bin, canonical order).
    pub fn load_weight(&self, w: &WeightSpec) -> Result<Vec<f32>> {
        let path = self.dir.join(&w.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weight {}", path.display()))?;
        let expect = w.shape.iter().product::<usize>() * 4;
        if bytes.len() != expect {
            bail!(
                "weight {}: file has {} bytes, shape {:?} needs {}",
                w.name,
                bytes.len(),
                w.shape,
                expect
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir.join("weights")).unwrap();
        let manifest = r#"{
          "version": 3,
          "model": {"vocab": 2048, "d_model": 256, "n_layers": 4,
                    "n_heads": 4, "ffn": 512, "max_seq": 256,
                    "param_order": ["embed", "lm_head"],
                    "decode_buckets": [1, 2, 4, 8],
                    "prefill_t_buckets": [16, 64], "prefill_b": 4,
                    "weight_seed": 0},
          "artifacts": [
            {"name": "flash_sample_b4_d256_v2048",
             "file": "flash_sample_b4_d256_v2048.hlo.txt",
             "kind": "flash_sample",
             "inputs": [{"name": "h", "shape": [4, 256], "dtype": "f32"},
                        {"name": "seed", "shape": [2], "dtype": "u32"}],
             "outputs": [{"name": "out0", "shape": [4], "dtype": "i32"}],
             "meta": {"B": 4, "D": 256, "V": 2048, "tile_v": 512}}
          ],
          "weights": [
            {"name": "embed", "file": "weights/embed.bin",
             "shape": [2, 3], "dtype": "f32"}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let data: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]
            .iter()
            .flat_map(|f| f.to_le_bytes())
            .collect();
        std::fs::write(dir.join("weights/embed.bin"), data).unwrap();
    }

    #[test]
    fn loads_fixture_manifest() {
        let dir = std::env::temp_dir().join("fs_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.abi_version, TAU_ABI_VERSION);
        assert_eq!(m.model.vocab, 2048);
        assert_eq!(m.model.decode_buckets, vec![1, 2, 4, 8]);
        let a = m.find("flash_sample_b4_d256_v2048").unwrap();
        assert_eq!(a.meta_usize("tile_v").unwrap(), 512);
        assert_eq!(a.inputs[0].elem_count(), 1024);
        assert_eq!(a.inputs[1].dtype, DType::U32);
        assert_eq!(m.by_kind("flash_sample").len(), 1);
        assert!(m.find("nope").is_err());
    }

    #[test]
    fn versionless_manifest_defaults_to_abi_v1() {
        let dir = std::env::temp_dir().join("fs_manifest_test_v1");
        write_fixture(&dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"version\": 3,", "")).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.abi_version, 1);
        // ...and every tau-feeding consumer must refuse it.
        let err = m.ensure_tau_abi().unwrap_err();
        assert!(err.to_string().contains("re-run `make artifacts`"), "{err}");
    }

    #[test]
    fn loads_weight_and_validates_size() {
        let dir = std::env::temp_dir().join("fs_manifest_test2");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        let w = m.weights.iter().find(|w| w.name == "embed").unwrap();
        assert_eq!(m.load_weight(w).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // corrupt size
        std::fs::write(dir.join("weights/embed.bin"), [0u8; 7]).unwrap();
        assert!(m.load_weight(w).is_err());
    }
}
