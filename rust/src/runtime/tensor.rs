//! Host-side tensors and conversion to/from XLA literals.

use anyhow::{bail, Context, Result};

use super::manifest::{DType, TensorSpec};

/// A host tensor: flat data + shape.  The coordinator's working currency —
/// cheap to build, validated against `TensorSpec`s before execution.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl Tensor {
    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32(vec![v], vec![1])
    }

    pub fn scalar_u32(v: u32) -> Self {
        Tensor::U32(vec![v], vec![1])
    }

    /// RNG key input: `\[seed_lo, seed_hi\]` as a u32 pair.
    pub fn seed(key: crate::sampling::Key) -> Self {
        Tensor::U32(vec![key.lo, key.hi], vec![2])
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Tensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) | Tensor::U32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
            Tensor::U32(..) => DType::U32,
        }
    }

    pub fn elem_count(&self) -> usize {
        match self {
            Tensor::F32(d, _) => d.len(),
            Tensor::I32(d, _) => d.len(),
            Tensor::U32(d, _) => d.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            t => bail!("expected f32 tensor, got {:?}", t.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            t => bail!("expected i32 tensor, got {:?}", t.dtype()),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Tensor::U32(d, _) => Ok(d),
            t => bail!("expected u32 tensor, got {:?}", t.dtype()),
        }
    }

    /// Validate against an artifact slot spec (shape + dtype).
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input '{}': dtype {:?} != expected {:?}",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input '{}': shape {:?} != expected {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        Ok(())
    }

    /// Convert to an XLA literal.
    ///
    /// Uses `create_from_shape_and_untyped_data` so the host data is copied
    /// exactly ONCE — the earlier `vec1(..).reshape(..)` path copied twice
    /// (literal creation + reshape materialization), which showed up as
    /// ~13 ms/step of KV-cache conversion in the decode hot path
    /// (EXPERIMENTS.md §Perf L3).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        fn bytes<T>(d: &[T]) -> &[u8] {
            // SAFETY: plain-old-data element types, read-only view.
            unsafe {
                std::slice::from_raw_parts(
                    d.as_ptr() as *const u8,
                    std::mem::size_of_val(d),
                )
            }
        }
        let (ty, data): (xla::ElementType, &[u8]) = match self {
            Tensor::F32(d, _) => (xla::ElementType::F32, bytes(d)),
            Tensor::I32(d, _) => (xla::ElementType::S32, bytes(d)),
            Tensor::U32(d, _) => (xla::ElementType::U32, bytes(d)),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, self.shape(), data)
            .context("creating literal")
    }

    /// Convert back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        use xla::ElementType as ET;
        Ok(match shape.ty() {
            ET::F32 => Tensor::F32(lit.to_vec::<f32>()?, dims),
            ET::S32 => Tensor::I32(lit.to_vec::<i32>()?, dims),
            ET::U32 => Tensor::U32(lit.to_vec::<u32>()?, dims),
            ty => bail!("unsupported output element type {ty:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    #[test]
    fn check_validates_shape_and_dtype() {
        let spec = TensorSpec {
            name: "h".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
        };
        let good = Tensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(good.check(&spec).is_ok());
        let bad_shape = Tensor::F32(vec![0.0; 6], vec![3, 2]);
        assert!(bad_shape.check(&spec).is_err());
        let bad_dtype = Tensor::I32(vec![0; 6], vec![2, 3]);
        assert!(bad_dtype.check(&spec).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let t = Tensor::I32(vec![-1, 2, -3], vec![3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let t = Tensor::U32(vec![7, 8], vec![2]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn seed_tensor_layout() {
        let k = crate::sampling::Key::new(0xAB, 0xCD);
        let t = Tensor::seed(k);
        assert_eq!(t.as_u32().unwrap(), &[0xAB, 0xCD]);
        assert_eq!(t.shape(), &[2]);
    }
}
