//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! This is the L3 side of the AOT bridge (see `python/compile/aot.py`).
//! HLO **text** is the interchange format — jax >= 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (gotcha documented in /opt/xla-example/README.md).
//!
//! The runtime is deliberately single-threaded per instance (PJRT wrapper
//! types are not `Send`); the TP orchestrator creates one `Runtime` per rank
//! thread, mirroring one-process-per-GPU deployments.

pub mod manifest;
pub mod tensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

pub use manifest::{
    ArtifactSpec, DType, Manifest, ModelInfo, TensorSpec, WeightSpec,
    TAU_ABI_VERSION,
};
pub use tensor::Tensor;

/// Cumulative execution statistics for one artifact.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

/// A compiled artifact handle (executable + its manifest spec).
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape/dtype-validated inputs; returns host tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {}: got {} inputs, expected {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            t.check(spec)
                .with_context(|| format!("artifact {}", self.spec.name))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        // AOT lowering uses return_tuple=True: one tuple literal out.
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = out.to_tuple().context("untupling result")?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with pre-converted literals (hot-path variant: the engine
    /// caches the model parameters as literals once and reuses them every
    /// step instead of re-converting ~40 weight tensors per call).
    ///
    /// Shape validation is skipped — callers own the ABI contract.
    pub fn run_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            literals.len() == self.spec.inputs.len(),
            "artifact {}: got {} literals, expected {}",
            self.spec.name,
            literals.len(),
            self.spec.inputs.len()
        );
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = out.to_tuple().context("untupling result")?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Like [`Executable::run_literals`] but returns the raw output
    /// literals without converting them to host tensors.  The serving
    /// engine uses this to keep the KV cache as device-adjacent literals
    /// across decode steps (EXPERIMENTS.md §Perf L3: avoids ~19 ms/step of
    /// host<->literal copies in steady state).
    pub fn run_literals_raw(
        &self,
        literals: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            literals.len() == self.spec.inputs.len(),
            "artifact {}: got {} literals, expected {}",
            self.spec.name,
            literals.len(),
            self.spec.inputs.len()
        );
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        out.to_tuple().context("untupling result")
    }
}

/// The artifact runtime: PJRT CPU client + lazily compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Create a runtime over `<artifacts_dir>/manifest.json`.
    ///
    /// Refuses artifact sets whose tau ABI predates this runtime (see
    /// [`manifest::TAU_ABI_VERSION`]) so no consumer can feed `tau: [B]`
    /// literals into scalar-tau executables.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.ensure_tau_abi()?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.find(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let compile_time = t0.elapsed();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_time =
            compile_time;
        let handle = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), handle.clone());
        Ok(handle)
    }

    /// Execute artifact `name`, recording wall time in the stats table.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Ok(self.run_timed(name, inputs)?.0)
    }

    /// Execute and also return wall time (bench harness hook).
    pub fn run_timed(
        &self,
        name: &str,
        inputs: &[Tensor],
    ) -> Result<(Vec<Tensor>, Duration)> {
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let out = exe.run(inputs)?;
        let dt = t0.elapsed();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total += dt;
        Ok((out, dt))
    }

    /// Snapshot of per-artifact execution statistics.
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    /// Load every weight tensor into a name -> Tensor map (f32).
    pub fn load_weights(&self) -> Result<HashMap<String, Tensor>> {
        let mut out = HashMap::new();
        for w in &self.manifest.weights {
            let data = self.manifest.load_weight(w)?;
            out.insert(w.name.clone(), Tensor::F32(data, w.shape.clone()));
        }
        Ok(out)
    }

    /// The model parameters in canonical (positional-ABI) order.
    pub fn params_in_order(&self) -> Result<Vec<Tensor>> {
        let mut weights = self.load_weights()?;
        self.manifest
            .model
            .param_order
            .iter()
            .map(|name| {
                weights
                    .remove(name)
                    .with_context(|| format!("weight '{name}' missing"))
            })
            .collect()
    }
}
