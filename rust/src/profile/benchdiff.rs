//! Perf-regression gate over `BENCH_*.json` reports
//! (`flashsampling benchdiff OLD.json NEW.json`).
//!
//! Both inputs use the shared `benchutil` schema (schema_version ≥ 1:
//! `{"bench", "schema_version", ["source", "config",] "results": [..]}`).
//! Records are matched by their **identity fields** — every scalar
//! field that is not a recognized metric (and not the provenance
//! `source` stamp) — so the gate needs no bespoke per-bench parsing:
//! adding a metric column to a bench automatically adds it to the gate,
//! and changing a workload knob makes the record a *different record*
//! (reported as added/removed) instead of a bogus comparison.
//!
//! Metric direction is inferred from the house naming convention:
//! `*_ns` / `*_us` / `*_w` (nanoseconds, microseconds, weighted-step
//! latencies) are lower-is-better; the known throughput/yield counters
//! (`completed`, `tokens_generated`, `cached_prefill_tokens`,
//! `min_replica_completed`, `iters_per_sample`,
//! `modeled_speedup_x1000`) are higher-is-better.
//! A change beyond the relative noise band (`tolerance`, default 5%) in
//! the bad direction is a regression; the CLI exits nonzero on any.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::json::{self, Value};

/// Default relative noise band: 5%.  The accounting-sim benches are
/// bit-deterministic, so CI could run at 0, but the default leaves
/// headroom for wall-clock benches sharing the same schema.
pub const DEFAULT_TOLERANCE: f64 = 0.05;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    LowerIsBetter,
    HigherIsBetter,
}

/// Classify a record field as a metric (with direction) or an identity
/// field (`None`).
fn direction(key: &str) -> Option<Direction> {
    const HIGHER: [&str; 6] = [
        "completed",
        "tokens_generated",
        "cached_prefill_tokens",
        "min_replica_completed",
        "iters_per_sample",
        "modeled_speedup_x1000",
    ];
    if HIGHER.contains(&key) {
        Some(Direction::HigherIsBetter)
    } else if key.ends_with("_ns") || key.ends_with("_us") || key.ends_with("_w")
    {
        Some(Direction::LowerIsBetter)
    } else {
        None
    }
}

/// Canonical rendering of an identity-field value (floats that are
/// whole numbers print as integers, matching both emitters).
fn canon(v: &Value) -> String {
    match v {
        Value::Null => "null".into(),
        Value::Bool(b) => b.to_string(),
        Value::Num(n) if n.fract() == 0.0 && n.abs() < 1e15 => {
            format!("{}", *n as i64)
        }
        Value::Num(n) => format!("{n}"),
        Value::Str(s) => s.clone(),
        Value::Arr(_) | Value::Obj(_) => "<nested>".into(),
    }
}

/// A record's identity: its non-metric scalar fields, minus the
/// provenance `source` stamp (so a sim-mirror run compares against a
/// Rust-bench run of the same scenario).
fn identity(record: &BTreeMap<String, Value>) -> String {
    let mut parts: Vec<String> = record
        .iter()
        .filter(|(k, _)| direction(k).is_none() && *k != "source")
        .map(|(k, v)| format!("{k}={}", canon(v)))
        .collect();
    parts.sort();
    parts.join(" ")
}

struct Report {
    bench: String,
    records: Vec<(String, BTreeMap<String, Value>)>,
}

fn parse_report(text: &str, label: &str) -> Result<Report> {
    let root = json::parse(text).with_context(|| format!("parsing {label}"))?;
    let bench = root
        .req("bench")
        .and_then(Value::as_str)
        .with_context(|| format!("{label}: missing 'bench' name"))?
        .to_string();
    root.req("schema_version")
        .and_then(Value::as_usize)
        .with_context(|| format!("{label}: missing 'schema_version'"))?;
    let mut records = Vec::new();
    for (i, rec) in root.req("results")?.as_arr()?.iter().enumerate() {
        let obj = rec
            .as_obj()
            .with_context(|| format!("{label}: results[{i}]"))?;
        records.push((identity(obj), obj.clone()));
    }
    Ok(Report { bench, records })
}

/// Outcome of one benchdiff run.
#[derive(Clone, Debug, Default)]
pub struct BenchDiff {
    pub bench: String,
    /// Metric comparisons performed across matched records.
    pub compared: usize,
    /// Metric moved beyond the noise band in the bad direction.
    pub regressions: Vec<String>,
    /// Metric moved beyond the noise band in the good direction.
    pub improvements: Vec<String>,
    /// Added/removed records, metric-set drift, and other non-gating
    /// observations.
    pub notes: Vec<String>,
}

impl BenchDiff {
    pub fn is_regression(&self) -> bool {
        !self.regressions.is_empty()
    }

    /// Markdown summary in the repro-report house style.
    pub fn to_markdown(&self, tolerance: f64) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "## benchdiff — {} ({} comparisons, ±{:.1}% band)\n",
            self.bench,
            self.compared,
            tolerance * 100.0
        );
        for r in &self.regressions {
            let _ = writeln!(out, "- REGRESSION: {r}");
        }
        for i in &self.improvements {
            let _ = writeln!(out, "- improvement: {i}");
        }
        for n in &self.notes {
            let _ = writeln!(out, "- note: {n}");
        }
        if self.regressions.is_empty() {
            let _ = writeln!(out, "\nverdict: WITHIN NOISE BAND");
        } else {
            let _ = writeln!(
                out,
                "\nverdict: REGRESSION ({} metrics)",
                self.regressions.len()
            );
        }
        out
    }
}

/// Compare two bench reports; `tolerance` is the relative noise band.
///
/// Fails (Err) on malformed input or mismatched bench names — those are
/// usage errors, not regressions.  Detected regressions are returned in
/// the report; callers gate on [`BenchDiff::is_regression`].
pub fn diff_reports(
    old_text: &str,
    new_text: &str,
    tolerance: f64,
) -> Result<BenchDiff> {
    ensure!(
        (0.0..1.0).contains(&tolerance),
        "tolerance must be in [0, 1), got {tolerance}"
    );
    let old = parse_report(old_text, "OLD")?;
    let new = parse_report(new_text, "NEW")?;
    if old.bench != new.bench {
        bail!(
            "bench mismatch: OLD is '{}', NEW is '{}'",
            old.bench,
            new.bench
        );
    }
    let mut diff = BenchDiff { bench: old.bench.clone(), ..Default::default() };
    let old_map: BTreeMap<&str, &BTreeMap<String, Value>> =
        old.records.iter().map(|(id, r)| (id.as_str(), r)).collect();
    ensure!(
        old_map.len() == old.records.len(),
        "OLD has records with duplicate identity"
    );
    let mut matched = 0usize;
    for (id, new_rec) in &new.records {
        let Some(old_rec) = old_map.get(id.as_str()) else {
            diff.notes.push(format!("new record [{id}] has no OLD baseline"));
            continue;
        };
        matched += 1;
        for (key, new_val) in new_rec {
            let Some(dir) = direction(key) else { continue };
            let Some(old_val) = old_rec.get(key) else {
                diff.notes
                    .push(format!("[{id}] metric '{key}' absent in OLD"));
                continue;
            };
            let o = old_val.as_f64()?;
            let n = new_val.as_f64()?;
            diff.compared += 1;
            let band = o.abs() * tolerance;
            let (delta, worse) = match dir {
                Direction::LowerIsBetter => (n - o, n > o + band),
                Direction::HigherIsBetter => (o - n, n < o - band),
            };
            let better = delta < -band;
            if worse {
                diff.regressions.push(format!(
                    "[{id}] {key}: {o} -> {n} ({:+.1}% vs ±{:.1}%)",
                    pct(delta, o),
                    tolerance * 100.0
                ));
            } else if better {
                diff.improvements.push(format!(
                    "[{id}] {key}: {o} -> {n} ({:+.1}%)",
                    pct(delta, o)
                ));
            }
        }
        for key in old_rec.keys() {
            if direction(key).is_some() && !new_rec.contains_key(key) {
                diff.regressions.push(format!(
                    "[{id}] metric '{key}' dropped from NEW"
                ));
            }
        }
    }
    for (id, _) in &old.records {
        if !new.records.iter().any(|(nid, _)| nid == id) {
            diff.regressions
                .push(format!("baseline record [{id}] missing from NEW"));
        }
    }
    if matched == 0 {
        bail!("no records matched between OLD and NEW — wrong files?");
    }
    Ok(diff)
}

/// Signed percent change in the *bad* direction, relative to baseline.
fn pct(delta: f64, baseline: f64) -> f64 {
    if baseline == 0.0 {
        if delta == 0.0 { 0.0 } else { f64::INFINITY }
    } else {
        100.0 * delta / baseline.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(bench: &str, recs: &[&str]) -> String {
        format!(
            "{{\"bench\": \"{bench}\", \"schema_version\": 2, \
             \"source\": \"test\", \"config\": {{}}, \"results\": [{}]}}",
            recs.join(", ")
        )
    }

    #[test]
    fn identical_reports_are_clean() {
        let r = report(
            "serving",
            &["{\"scenario\": \"a\", \"ttft_p95_w\": 100, \
               \"completed\": 16}"],
        );
        let d = diff_reports(&r, &r, DEFAULT_TOLERANCE).unwrap();
        assert!(!d.is_regression());
        assert_eq!(d.compared, 2);
        assert!(d.improvements.is_empty());
        assert!(d.to_markdown(DEFAULT_TOLERANCE).contains("WITHIN NOISE"));
    }

    #[test]
    fn latency_regression_is_flagged_with_direction() {
        let old = report(
            "serving",
            &["{\"scenario\": \"a\", \"ttft_p95_w\": 100, \
               \"completed\": 16}"],
        );
        // +20% latency: regression.  +20% completed: improvement.
        let new = report(
            "serving",
            &["{\"scenario\": \"a\", \"ttft_p95_w\": 120, \
               \"completed\": 20}"],
        );
        let d = diff_reports(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert_eq!(d.regressions.len(), 1);
        assert!(d.regressions[0].contains("ttft_p95_w"));
        assert_eq!(d.improvements.len(), 1);
        assert!(d.improvements[0].contains("completed"));
        // Reversed direction: lower latency is NOT a regression, lower
        // completion count IS.
        let d = diff_reports(&new, &old, DEFAULT_TOLERANCE).unwrap();
        assert_eq!(d.regressions.len(), 1);
        assert!(d.regressions[0].contains("completed"));
    }

    #[test]
    fn within_band_changes_pass() {
        let old =
            report("serving", &["{\"scenario\": \"a\", \"itl_p50_w\": 100}"]);
        let new =
            report("serving", &["{\"scenario\": \"a\", \"itl_p50_w\": 104}"]);
        let d = diff_reports(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(!d.is_regression());
        assert!(d.improvements.is_empty());
    }

    #[test]
    fn identity_fields_partition_records() {
        // Different chunk setting = different record, not a comparison.
        let old = report(
            "serving",
            &["{\"scenario\": \"a\", \"chunk\": 16, \"ttft_p95_w\": 100}"],
        );
        let new = report(
            "serving",
            &[
                "{\"scenario\": \"a\", \"chunk\": 16, \"ttft_p95_w\": 100}",
                "{\"scenario\": \"a\", \"chunk\": 64, \"ttft_p95_w\": 500}",
            ],
        );
        let d = diff_reports(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(!d.is_regression());
        assert_eq!(d.notes.len(), 1);
        // A dropped baseline record IS a regression (silent coverage
        // loss must fail the gate).
        let d = diff_reports(&new, &old, DEFAULT_TOLERANCE).unwrap();
        assert!(d.is_regression());
        assert!(d.regressions[0].contains("missing from NEW"));
    }

    #[test]
    fn source_stamp_is_not_identity() {
        let old = report(
            "serving",
            &["{\"scenario\": \"a\", \"source\": \"accounting-sim\", \
               \"ttft_p95_w\": 100}"],
        );
        let new = report(
            "serving",
            &["{\"scenario\": \"a\", \"source\": \"rust-bench\", \
               \"ttft_p95_w\": 100}"],
        );
        let d = diff_reports(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert_eq!(d.compared, 1);
        assert!(!d.is_regression());
    }

    #[test]
    fn usage_errors_bail() {
        let a = report("serving", &["{\"scenario\": \"a\", \"x_w\": 1}"]);
        let b = report("router", &["{\"scenario\": \"a\", \"x_w\": 1}"]);
        assert!(diff_reports(&a, &b, DEFAULT_TOLERANCE)
            .unwrap_err()
            .to_string()
            .contains("bench mismatch"));
        assert!(diff_reports("nonsense", &a, DEFAULT_TOLERANCE).is_err());
        let c = report("serving", &["{\"scenario\": \"other\", \"x_w\": 1}"]);
        assert!(diff_reports(&a, &c, DEFAULT_TOLERANCE)
            .unwrap_err()
            .to_string()
            .contains("no records matched"));
    }

    #[test]
    fn dropped_metric_is_a_regression() {
        let old = report(
            "serving",
            &["{\"scenario\": \"a\", \"ttft_p95_w\": 100, \
               \"itl_p50_w\": 7}"],
        );
        let new =
            report("serving", &["{\"scenario\": \"a\", \"ttft_p95_w\": 100}"]);
        let d = diff_reports(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(d.is_regression());
        assert!(d.regressions[0].contains("dropped"));
    }
}
