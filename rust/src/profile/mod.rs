//! Modeled-time profiler over the flight recorder (DESIGN.md §15).
//!
//! The PR 8 trace (DESIGN.md §14) records *what* happened on a logical
//! step clock; this module answers *where the modeled time goes*.  It
//! folds a [`Trace`] event stream through a [`Pricer`] — either the
//! [`PriceTable`] distilled from the `gpusim` cost models
//! (`kernelchain` + `roofline` prefill pricing, `tpot` decode steps,
//! `SpecDecodeModel`-shaped speculative bursts, `iomodel` PCIe swap
//! traffic, `interconnect` dispatch fan-out), or the [`StepClockPricer`]
//! that reproduces the accounting sims' weighted step clock exactly —
//! and produces:
//!
//! * a per-replica **window list**: contiguous exclusive slices of the
//!   modeled timeline, one per compute/transfer batch, that provably
//!   tile the replica makespan (no gaps, no overlaps, no negative
//!   durations);
//! * a per-request **phase breakdown** (queue wait / prefill / chunk
//!   windows / swap / spec bursts / decode) whose parts sum to the
//!   request's span — the conservation law `repro profile-identity`
//!   certifies;
//! * a Chrome-trace export where `ts`/`dur` are **modeled
//!   microseconds** instead of step ticks (`flashsampling profile`);
//! * an FNV-1a digest over the canonical integer summary lines, exact
//!   and replay-stable because every price is an integer microcount —
//!   `python/tests/sim_profile_bench.py` re-derives it cross-language
//!   with no floating point anywhere.
//!
//! # Exactness and determinism
//!
//! Three properties make the profile a *certificate* rather than an
//! estimate of an estimate:
//!
//! 1. **Integer prices.**  [`PriceTable::canonical`] pins each price as
//!    a `u64` microsecond count (rounded once, at table-construction
//!    time, from the `gpusim` f64 models).  All downstream arithmetic
//!    is `u64` addition/multiplication, so there is no accumulation
//!    order to get wrong and the Python mirror needs no float replay.
//! 2. **Replay-stable input.**  The trace digest is replay-stable
//!    (DESIGN.md §14), so the same workload always yields the same
//!    event stream, hence the same profile digest.
//! 3. **Conservation by construction.**  Windows advance one cursor;
//!    request stamps are cursor values; every attributed duration is a
//!    whole window that lies inside the request's span.  The checks in
//!    [`ReplicaProfile::check`] re-verify all of it from the output
//!    alone.
//!
//! The profiler consumes the trace *ring*, so it requires an unevicted
//! trace: size `trace_ring_cap` (config) to the workload, or profile
//! per-scenario as the repro ids do.  (The trace digest itself is
//! eviction-independent; only profiling needs the full event list.)

pub mod benchdiff;

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::gpusim::iomodel::PcieModel;
use crate::gpusim::specs::GpuSpec;
use crate::gpusim::tpot::ModelSpec;
use crate::gpusim::{interconnect, Method};
use crate::trace::{EventKind, Trace};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Round a seconds quantity from the f64 `gpusim` models to integer
/// microseconds — the one place floating point touches the profiler.
fn us(seconds: f64) -> u64 {
    (seconds * 1e6 + 0.5).floor() as u64
}

/// Integer microsecond prices for every traced operation class,
/// distilled from the `gpusim` cost models.
///
/// [`PriceTable::canonical`] is the frozen calibration the digest (and
/// the Python mirror) are defined over; [`PriceTable::derive`] rebuilds
/// the same table live from the models, and a unit test keeps the two
/// within tolerance so a `gpusim` recalibration is flagged instead of
/// silently shifting every certified digest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PriceTable {
    /// Marginal prefill compute per uncached prompt token
    /// (`ModelSpec::prefill_time` slope in its compute-bound regime).
    pub prefill_us_per_token: u64,
    /// Prefill floor: one streaming pass over the weights — the
    /// roofline memory bound tiny suffixes still pay.
    pub prefill_stream_floor_us: u64,
    /// Fixed per-window cost (kernel dispatch chain + host overhead),
    /// paid by every prefill batch and every chunk window.
    pub window_fixed_us: u64,
    /// One decode step at the calibrated batch (backbone + fused
    /// FlashSampling LM head, `ModelSpec::tpot`).
    pub decode_step_us: u64,
    /// Per drafted token: one draft-model pass (the spec-decode model's
    /// `draft_cost` fraction of a backbone step).
    pub spec_draft_us: u64,
    /// One speculative verify pass (backbone + wide-batch LM head).
    pub spec_verify_us: u64,
    /// PCIe transfer of one paged-KV block (`PcieModel::transfer_us`).
    pub swap_us_per_block: u64,
    /// Router placement decision (`interconnect::fanout_barrier_time`
    /// at fan-out 2 — one probe/ack round).
    pub dispatch_us: u64,
}

impl PriceTable {
    /// The frozen calibration: [`PriceTable::derive`] on
    /// [`crate::gpusim::specs::B200`] × [`crate::gpusim::tpot::QWEN3_8B`],
    /// rounded to integer microseconds and pinned.  Certified digests —
    /// and `python/tests/sim_profile_bench.py` — embed exactly these
    /// constants; see `canonical_tracks_derived_table` for the drift
    /// tripwire.
    pub fn canonical() -> Self {
        Self {
            prefill_us_per_token: 15,
            prefill_stream_floor_us: 2412,
            window_fixed_us: 1282,
            decode_step_us: 3805,
            spec_draft_us: 360,
            spec_verify_us: 3805,
            swap_us_per_block: 84,
            dispatch_us: 24,
        }
    }

    /// Rebuild the table live from the `gpusim` models (public API
    /// only), for any GPU × model pair.
    pub fn derive(gpu: &GpuSpec, m: &ModelSpec) -> Self {
        // Slope of prefill_time in its compute-bound regime; the
        // intercept at 0 tokens splits into the weight-stream floor
        // (computable from public spec fields) plus the fixed
        // dispatch+host term.
        let slope =
            (m.prefill_time(gpu, 2000, 0.0) - m.prefill_time(gpu, 1000, 0.0))
                / 1000.0;
        let stream_floor =
            m.params * 2.0 / m.tp as f64 / (gpu.hbm_bw * gpu.bw_efficiency);
        let window_fixed = m.prefill_time(gpu, 0, 0.0) - stream_floor;
        // One draft pass is modeled at the spec-decode model's default
        // draft_cost = 0.1 of a backbone step.
        let backbone = m.backbone_time(gpu, 8);
        // KV width per token: d_model / 4 is the serving model's GQA
        // KV projection (kv_heads * head_dim = d_model / 4), FP32, at
        // the default 16-token block.
        let block_bytes =
            PcieModel::kv_block_bytes(m.n_layers, 1, m.d_model / 4, 16);
        Self {
            prefill_us_per_token: us(slope),
            prefill_stream_floor_us: us(stream_floor),
            window_fixed_us: us(window_fixed),
            decode_step_us: us(m.tpot(gpu, 8, Method::FlashSampling)),
            spec_draft_us: us(0.1 * backbone),
            spec_verify_us: us(
                backbone + m.lm_head_time(gpu, 32, Method::FlashSampling),
            ),
            // transfer_us already returns microseconds.
            swap_us_per_block: us(
                PcieModel::default().transfer_us(block_bytes) * 1e-6,
            ),
            dispatch_us: us(interconnect::fanout_barrier_time(gpu, 2)),
        }
    }
}

/// Prices one window of each phase in integer microseconds.
///
/// Two implementations ship: [`PriceTable`] (modeled GPU time) and
/// [`StepClockPricer`] (the accounting sims' weighted step clock —
/// the bridge that lets `repro profile-identity` prove the profiler's
/// window/stamp construction against `ServingMetrics` exactly).
pub trait Pricer {
    /// One chunked-prefill window consuming `take` prompt tokens.
    fn chunk_window_us(&self, take: usize) -> u64;
    /// One prefill batch whose longest uncached prompt suffix is
    /// `longest_uncached` tokens.
    fn prefill_us(&self, longest_uncached: usize) -> u64;
    /// One ordinary decode step (whole batch).
    fn decode_us(&self) -> u64;
    /// One speculative burst batch whose widest row drafted
    /// `max_drafted` tokens.
    fn spec_us(&self, max_drafted: u64) -> u64;
    /// One swap-in/out transfer of `blocks` KV blocks.
    fn swap_us(&self, blocks: u64) -> u64;
    /// One router placement decision.
    fn dispatch_us(&self) -> u64;
    /// One scheduler step that planned nothing.
    fn idle_us(&self) -> u64;
    /// Name recorded in reports.
    fn name(&self) -> &'static str;
}

impl Pricer for PriceTable {
    fn chunk_window_us(&self, take: usize) -> u64 {
        (take as u64 * self.prefill_us_per_token)
            .max(self.prefill_stream_floor_us)
            + self.window_fixed_us
    }

    fn prefill_us(&self, longest_uncached: usize) -> u64 {
        (longest_uncached as u64 * self.prefill_us_per_token)
            .max(self.prefill_stream_floor_us)
            + self.window_fixed_us
    }

    fn decode_us(&self) -> u64 {
        self.decode_step_us
    }

    fn spec_us(&self, max_drafted: u64) -> u64 {
        self.spec_verify_us + max_drafted * self.spec_draft_us
    }

    fn swap_us(&self, blocks: u64) -> u64 {
        blocks * self.swap_us_per_block
    }

    fn dispatch_us(&self) -> u64 {
        self.dispatch_us
    }

    /// An idle scheduler step runs nothing, so the modeled clock does
    /// not advance (zero-duration windows are legal in the tiling).
    fn idle_us(&self) -> u64 {
        0
    }

    fn name(&self) -> &'static str {
        "modeled"
    }
}

/// Reproduces the accounting sims' weighted step clock
/// (`testutil::schedsim` / `router::sim` `wtime`): prefill advances by
/// the longest uncached suffix, chunk windows by their take, decode /
/// spec / idle by one, swaps and dispatches are free.  Profiling a sim
/// trace with this pricer must land every stamp exactly on the sim's
/// own clock — the `repro profile-identity` agreement legs.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepClockPricer;

impl Pricer for StepClockPricer {
    fn chunk_window_us(&self, take: usize) -> u64 {
        take.max(1) as u64
    }

    fn prefill_us(&self, longest_uncached: usize) -> u64 {
        longest_uncached.max(1) as u64
    }

    fn decode_us(&self) -> u64 {
        1
    }

    fn spec_us(&self, _max_drafted: u64) -> u64 {
        1
    }

    fn swap_us(&self, _blocks: u64) -> u64 {
        0
    }

    fn dispatch_us(&self) -> u64 {
        0
    }

    fn idle_us(&self) -> u64 {
        1
    }

    fn name(&self) -> &'static str {
        "step-clock"
    }
}

/// Phase of one profiled window / one request-breakdown bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Chunk,
    Decode,
    Spec,
    Swap,
    Dispatch,
    Idle,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Chunk => "chunk",
            Phase::Decode => "decode",
            Phase::Spec => "spec",
            Phase::Swap => "swap",
            Phase::Dispatch => "dispatch",
            Phase::Idle => "idle",
        }
    }

    /// Phases attributed to participating requests.  Dispatch and idle
    /// time is nobody's compute: it lands in the requests' queue
    /// residual, which keeps the conservation law exact.
    fn attributed(self) -> bool {
        !matches!(self, Phase::Dispatch | Phase::Idle)
    }
}

/// One exclusive slice of a replica's modeled timeline.  Windows are
/// emitted in construction order and chain contiguously:
/// `windows[i+1].start_us == windows[i].start_us + windows[i].dur_us`.
#[derive(Clone, Debug)]
pub struct Window {
    pub start_us: u64,
    pub dur_us: u64,
    /// Logical step clock the window's events were traced at.
    pub step: u64,
    pub phase: Phase,
    /// Request ids served by this window (empty for engine-scoped idle
    /// windows).
    pub participants: Vec<u64>,
}

/// Per-request cost attribution: phase durations summing (with the
/// queue residual) to the request's span.
#[derive(Clone, Debug, Default)]
pub struct RequestProfile {
    pub id: u64,
    pub submit_us: u64,
    /// Stamp of the terminal event; `None` for requests still open at
    /// the end of the trace (their span runs to the makespan).
    pub finish_us: Option<u64>,
    /// Span minus all attributed phases — scheduler queueing plus any
    /// dispatch/idle time the request sat through.
    pub queue_us: u64,
    pub prefill_us: u64,
    pub chunk_us: u64,
    pub swap_us: u64,
    pub spec_us: u64,
    pub decode_us: u64,
    pub span_us: u64,
    /// Modeled time to first token (`None` if nothing was emitted).
    pub ttft_us: Option<u64>,
    /// Modeled emission time of every token (window-end stamps; spec
    /// bursts stamp all emitted tokens at the burst window's end).
    /// Excluded from the digest — `ttft_us` + `tokens` summarize it.
    pub token_times_us: Vec<u64>,
    pub tokens: u64,
    /// Finish reason, `"rejected"` for front-door rejects, `"open"` for
    /// requests without a terminal event.
    pub finish: String,
    /// Token count carried by the terminal event (conservation
    /// cross-check against `tokens`).
    finish_tokens: Option<u64>,
}

impl RequestProfile {
    /// Sum of the attributed compute/transfer phases.
    pub fn attributed_us(&self) -> u64 {
        self.prefill_us + self.chunk_us + self.swap_us + self.spec_us
            + self.decode_us
    }
}

/// One replica's profile: the window tiling plus per-request rollups.
#[derive(Clone, Debug)]
pub struct ReplicaProfile {
    pub replica: usize,
    pub windows: Vec<Window>,
    /// Sorted by request id.
    pub requests: Vec<RequestProfile>,
    /// Final cursor position == Σ window durations.
    pub makespan_us: u64,
}

/// A full profile (one entry per replica) under one pricer.
#[derive(Clone, Debug)]
pub struct Profile {
    pub pricer: &'static str,
    pub replicas: Vec<ReplicaProfile>,
}

/// In-flight window being merged from consecutive same-class events.
struct OpenWindow {
    phase: Phase,
    step: u64,
    participants: Vec<u64>,
    longest_uncached: usize,
    max_drafted: u64,
    take: usize,
    blocks: u64,
    /// `(id, tokens)` emissions stamped at window end.
    emits: Vec<(u64, u64)>,
    /// Terminal events deferred to the window end (finishes interleave
    /// per-row inside prefill batches; stamping them at the enclosing
    /// window's close keeps spans aligned to the step clock).
    finishes: Vec<(u64, String, u64)>,
}

impl OpenWindow {
    fn new(phase: Phase, step: u64) -> Self {
        Self {
            phase,
            step,
            participants: Vec::new(),
            longest_uncached: 0,
            max_drafted: 0,
            take: 0,
            blocks: 0,
            emits: Vec::new(),
            finishes: Vec::new(),
        }
    }

    fn join(&mut self, id: u64) {
        if !self.participants.contains(&id) {
            self.participants.push(id);
        }
    }
}

#[derive(Default)]
struct ReqBuild {
    submit_us: Option<u64>,
    finish: Option<(u64, String, u64)>,
    prompt_len: usize,
    /// Prompt tokens already resident (chunk-window progress or radix
    /// attach) — what the next prefill window does NOT recompute.
    resident: usize,
    prefill_us: u64,
    chunk_us: u64,
    swap_us: u64,
    spec_us: u64,
    decode_us: u64,
    tokens: u64,
    token_times: Vec<u64>,
}

/// Close `w`: price it, attribute it, stamp deferred emissions and
/// finishes at its end, and advance the cursor.
fn close_window(
    w: OpenWindow,
    cursor: &mut u64,
    windows: &mut Vec<Window>,
    reqs: &mut BTreeMap<u64, ReqBuild>,
    pricer: &dyn Pricer,
) {
    let dur = match w.phase {
        Phase::Prefill => pricer.prefill_us(w.longest_uncached),
        Phase::Chunk => pricer.chunk_window_us(w.take),
        Phase::Decode => pricer.decode_us(),
        Phase::Spec => pricer.spec_us(w.max_drafted),
        Phase::Swap => pricer.swap_us(w.blocks),
        Phase::Dispatch => pricer.dispatch_us(),
        Phase::Idle => pricer.idle_us(),
    };
    let end = *cursor + dur;
    if w.phase.attributed() {
        for &id in &w.participants {
            let r = reqs.entry(id).or_default();
            match w.phase {
                Phase::Prefill => r.prefill_us += dur,
                Phase::Chunk => r.chunk_us += dur,
                Phase::Decode => r.decode_us += dur,
                Phase::Spec => r.spec_us += dur,
                Phase::Swap => r.swap_us += dur,
                Phase::Dispatch | Phase::Idle => unreachable!(),
            }
        }
    }
    for (id, n) in &w.emits {
        let r = reqs.entry(*id).or_default();
        r.tokens += n;
        for _ in 0..*n {
            r.token_times.push(end);
        }
    }
    for (id, reason, toks) in w.finishes {
        let r = reqs.entry(id).or_default();
        r.finish = Some((end, reason, toks));
    }
    windows.push(Window {
        start_us: *cursor,
        dur_us: dur,
        step: w.step,
        phase: w.phase,
        participants: w.participants,
    });
    *cursor = end;
}

/// Profile one replica trace under `pricer`.
///
/// Requires the full event stream in the ring (no eviction): partial
/// streams cannot balance.  Size `trace_ring_cap` to the workload.
pub fn profile_trace(
    replica: usize,
    trace: &Trace,
    pricer: &dyn Pricer,
) -> Result<ReplicaProfile> {
    ensure!(
        trace.total() == trace.ring_len() as u64,
        "replica {replica}: trace ring evicted {} of {} events — \
         profiling needs the full stream; raise trace_ring_cap",
        trace.total() - trace.ring_len() as u64,
        trace.total()
    );
    let mut cursor = 0u64;
    let mut windows: Vec<Window> = Vec::new();
    let mut reqs: BTreeMap<u64, ReqBuild> = BTreeMap::new();
    let mut open: Option<OpenWindow> = None;
    // Close the open window unconditionally / on class-or-step change.
    macro_rules! flush {
        () => {
            if let Some(w) = open.take() {
                close_window(w, &mut cursor, &mut windows, &mut reqs, pricer);
            }
        };
    }
    for ev in trace.events() {
        // Merged-window classes: consecutive same-class events at the
        // same step share one window (one batch = one window).
        let merged = match &ev.kind {
            EventKind::Prefill { .. } | EventKind::FirstToken { .. } => {
                Some(Phase::Prefill)
            }
            EventKind::DecodeToken { .. } => Some(Phase::Decode),
            EventKind::SpecBurst { .. } => Some(Phase::Spec),
            _ => None,
        };
        if let Some(phase) = merged {
            let reopen = match &open {
                Some(w) => w.phase != phase || w.step != ev.step,
                None => true,
            };
            if reopen {
                flush!();
                open = Some(OpenWindow::new(phase, ev.step));
            }
            let w = open.as_mut().expect("window just ensured");
            w.join(ev.id);
            match &ev.kind {
                EventKind::Prefill { prompt_len } => {
                    let r = reqs.entry(ev.id).or_default();
                    r.prompt_len = *prompt_len;
                    let uncached = prompt_len.saturating_sub(r.resident);
                    w.longest_uncached = w.longest_uncached.max(uncached);
                }
                EventKind::FirstToken { .. } => w.emits.push((ev.id, 1)),
                EventKind::SpecBurst { drafted, emitted, .. } => {
                    w.max_drafted = w.max_drafted.max(*drafted);
                    w.emits.push((ev.id, *emitted));
                }
                EventKind::DecodeToken { .. } => w.emits.push((ev.id, 1)),
                _ => unreachable!(),
            }
            continue;
        }
        match &ev.kind {
            // Per-event windows: one window per traced transfer /
            // chunk / placement / idle step.
            EventKind::ChunkWindow { take, prefilled } => {
                flush!();
                let mut w = OpenWindow::new(Phase::Chunk, ev.step);
                w.join(ev.id);
                w.take = *take;
                close_window(w, &mut cursor, &mut windows, &mut reqs, pricer);
                reqs.entry(ev.id).or_default().resident = *prefilled;
            }
            EventKind::SwapIn { blocks } | EventKind::SwapOut { blocks } => {
                flush!();
                let mut w = OpenWindow::new(Phase::Swap, ev.step);
                w.join(ev.id);
                w.blocks = *blocks;
                close_window(w, &mut cursor, &mut windows, &mut reqs, pricer);
            }
            EventKind::Dispatch { .. } => {
                flush!();
                let mut w = OpenWindow::new(Phase::Dispatch, ev.step);
                w.join(ev.id);
                close_window(w, &mut cursor, &mut windows, &mut reqs, pricer);
            }
            EventKind::Plan { outcome, .. } if *outcome == "idle" => {
                flush!();
                let w = OpenWindow::new(Phase::Idle, ev.step);
                close_window(w, &mut cursor, &mut windows, &mut reqs, pricer);
            }
            // Front-door events happen between steps, never inside a
            // batch: they close the open window so their stamps land
            // AFTER the preceding step's work.
            EventKind::Submit { prompt_len, .. } => {
                flush!();
                let r = reqs.entry(ev.id).or_default();
                r.submit_us = Some(cursor);
                r.prompt_len = *prompt_len;
            }
            EventKind::Reject { reason } => {
                flush!();
                let r = reqs.entry(ev.id).or_default();
                if r.submit_us.is_none() {
                    r.submit_us = Some(cursor);
                }
                if r.finish.is_none() {
                    r.finish = Some((cursor, reason.clone(), 0));
                }
            }
            // Terminal events interleave per-row inside compute
            // batches: defer the stamp to the enclosing window's end,
            // or stamp at the cursor when none is open.
            EventKind::Finish { reason, tokens } => match open.as_mut() {
                Some(w) => {
                    w.finishes.push((ev.id, reason.to_string(), *tokens));
                }
                None => {
                    reqs.entry(ev.id).or_default().finish =
                        Some((cursor, reason.to_string(), *tokens));
                }
            },
            // Cache attach: the attached prefix is resident, so the
            // next prefill window prices only the remaining suffix.
            EventKind::RadixAttach { tokens } => {
                let r = reqs.entry(ev.id).or_default();
                r.resident = r.resident.saturating_add(*tokens as usize);
            }
            // Decisions and ledger deltas carry no modeled duration.
            // Subvocab skip/fallback markers ride inside the decode
            // window they annotate (the window itself is priced by the
            // token events), so they add no duration either.
            EventKind::Preempt { .. }
            | EventKind::Promote { .. }
            | EventKind::Plan { .. }
            | EventKind::KvAlloc { .. }
            | EventKind::KvFree { .. }
            | EventKind::KvCow { .. }
            | EventKind::RadixEvict { .. }
            | EventKind::SubvocabSkip { .. }
            | EventKind::SubvocabFallback { .. } => {}
            EventKind::Prefill { .. }
            | EventKind::FirstToken { .. }
            | EventKind::DecodeToken { .. }
            | EventKind::SpecBurst { .. } => unreachable!("merged above"),
        }
    }
    flush!();
    let makespan_us = cursor;
    let requests = reqs
        .into_iter()
        .map(|(id, r)| {
            let submit_us = r.submit_us.unwrap_or(0);
            let (finish_us, finish, finish_tokens) = match r.finish {
                Some((t, reason, toks)) => (Some(t), reason, Some(toks)),
                None => (None, "open".to_string(), None),
            };
            let span_us =
                finish_us.unwrap_or(makespan_us).saturating_sub(submit_us);
            let attributed = r.prefill_us + r.chunk_us + r.swap_us + r.spec_us
                + r.decode_us;
            RequestProfile {
                id,
                submit_us,
                finish_us,
                queue_us: span_us.saturating_sub(attributed),
                prefill_us: r.prefill_us,
                chunk_us: r.chunk_us,
                swap_us: r.swap_us,
                spec_us: r.spec_us,
                decode_us: r.decode_us,
                span_us,
                ttft_us: r.token_times.first().copied(),
                token_times_us: r.token_times,
                tokens: r.tokens,
                finish,
                finish_tokens,
            }
        })
        .collect();
    Ok(ReplicaProfile { replica, windows, requests, makespan_us })
}

/// Profile several replica traces (the `chrome_export` track shape).
pub fn profile_tracks(
    tracks: &[(usize, &Trace)],
    pricer: &dyn Pricer,
) -> Result<Profile> {
    let replicas = tracks
        .iter()
        .map(|&(pid, t)| profile_trace(pid, t, pricer))
        .collect::<Result<Vec<_>>>()?;
    Ok(Profile { pricer: pricer.name(), replicas })
}

impl ReplicaProfile {
    /// Verify every invariant the profile claims, from the output
    /// alone:
    ///
    /// * windows tile the makespan — contiguous from 0, no negative
    ///   durations (zero is legal), durations sum to the makespan;
    /// * per request, attributed phases + queue == span, with the
    ///   queue residual independently recomputed by scanning the
    ///   windows inside the request's span (an overlap or
    ///   double-count would break the rescan, not just the sum);
    /// * terminal token counts match the traced emissions, and
    ///   `ttft_us` matches the first token stamp.
    pub fn check(&self) -> Result<()> {
        let mut at = 0u64;
        for (i, w) in self.windows.iter().enumerate() {
            ensure!(
                w.start_us == at,
                "replica {}: window {i} starts at {} expected {at} \
                 (gap or overlap)",
                self.replica,
                w.start_us
            );
            at += w.dur_us;
        }
        ensure!(
            at == self.makespan_us,
            "replica {}: windows sum to {at}, makespan {}",
            self.replica,
            self.makespan_us
        );
        for r in &self.requests {
            let end = r.finish_us.unwrap_or(self.makespan_us);
            ensure!(
                end >= r.submit_us,
                "request {}: finish {end} before submit {}",
                r.id,
                r.submit_us
            );
            ensure!(
                r.span_us == end - r.submit_us,
                "request {}: span {} != {}",
                r.id,
                r.span_us,
                end - r.submit_us
            );
            let total = r.attributed_us().checked_add(r.queue_us);
            ensure!(
                total == Some(r.span_us),
                "request {}: phases {} + queue {} != span {}",
                r.id,
                r.attributed_us(),
                r.queue_us,
                r.span_us
            );
            // Independent queue rescan over the window tiling.
            let mut rescan = 0u64;
            for w in &self.windows {
                let inside =
                    w.start_us >= r.submit_us && w.start_us + w.dur_us <= end;
                if inside
                    && !(w.phase.attributed()
                        && w.participants.contains(&r.id))
                {
                    rescan += w.dur_us;
                }
            }
            ensure!(
                rescan == r.queue_us,
                "request {}: queue rescan {rescan} != residual {}",
                r.id,
                r.queue_us
            );
            if let Some(ft) = r.finish_tokens {
                ensure!(
                    ft == r.tokens,
                    "request {}: finish event says {ft} tokens, \
                     traced {}",
                    r.id,
                    r.tokens
                );
            }
            ensure!(
                r.ttft_us == r.token_times_us.first().copied(),
                "request {}: ttft {:?} != first token stamp {:?}",
                r.id,
                r.ttft_us,
                r.token_times_us.first()
            );
            ensure!(
                r.tokens == r.token_times_us.len() as u64,
                "request {}: {} tokens but {} stamps",
                r.id,
                r.tokens,
                r.token_times_us.len()
            );
        }
        Ok(())
    }
}

impl Profile {
    /// Run [`ReplicaProfile::check`] on every replica.
    pub fn check(&self) -> Result<()> {
        for r in &self.replicas {
            r.check()?;
        }
        Ok(())
    }

    /// Canonical integer summary lines the digest folds: one per
    /// request (replica-major, id-sorted) plus one rollup per replica.
    /// `python/tests/sim_profile_bench.py` rebuilds these byte-for-byte.
    pub fn canonical_lines(&self) -> Vec<String> {
        let mut lines = Vec::new();
        for rep in &self.replicas {
            for r in &rep.requests {
                lines.push(format!(
                    "{{\"replica\":{},\"id\":{},\"queue_us\":{},\
                     \"prefill_us\":{},\"chunk_us\":{},\"swap_us\":{},\
                     \"spec_us\":{},\"decode_us\":{},\"span_us\":{},\
                     \"ttft_us\":{},\"tokens\":{},\"finish\":\"{}\"}}",
                    rep.replica,
                    r.id,
                    r.queue_us,
                    r.prefill_us,
                    r.chunk_us,
                    r.swap_us,
                    r.spec_us,
                    r.decode_us,
                    r.span_us,
                    r.ttft_us.unwrap_or(0),
                    r.tokens,
                    r.finish
                ));
            }
            lines.push(format!(
                "{{\"replica\":{},\"requests\":{},\"windows\":{},\
                 \"makespan_us\":{}}}",
                rep.replica,
                rep.requests.len(),
                rep.windows.len(),
                rep.makespan_us
            ));
        }
        lines
    }

    /// FNV-1a 64 over the newline-terminated canonical lines — the
    /// replay-stable certificate `repro profile-identity` compares and
    /// the Python mirror re-derives.
    pub fn digest(&self) -> u64 {
        let mut d = FNV_OFFSET;
        for line in self.canonical_lines() {
            for b in line.as_bytes() {
                d = (d ^ u64::from(*b)).wrapping_mul(FNV_PRIME);
            }
            d = (d ^ u64::from(b'\n')).wrapping_mul(FNV_PRIME);
        }
        d
    }

    /// Chrome trace-event JSON with **modeled microseconds** on the
    /// time axis: one process per replica, one track per request
    /// (engine-scoped idle windows on track 0), one `"X"` slice per
    /// (window, participant).  Load at `ui.perfetto.dev`.
    pub fn chrome_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("{\"traceEvents\":[\n");
        let mut first = true;
        let mut push = |s: String, first: &mut bool| {
            if !*first {
                out.push_str(",\n");
            }
            *first = false;
            out.push_str(&s);
        };
        for rep in &self.replicas {
            push(
                format!(
                    "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\
                     \"args\":{{\"name\":\"replica {} ({})\"}}}}",
                    rep.replica, rep.replica, self.pricer
                ),
                &mut first,
            );
            let mut tids: Vec<u64> =
                rep.requests.iter().map(|r| r.id).collect();
            if rep.windows.iter().any(|w| w.participants.is_empty()) {
                tids.push(0);
            }
            tids.sort_unstable();
            tids.dedup();
            for tid in tids {
                push(
                    format!(
                        "{{\"name\":\"thread_name\",\"ph\":\"M\",\
                         \"pid\":{},\"tid\":{tid},\"args\":{{\"name\":\
                         \"request {tid}\"}}}}",
                        rep.replica
                    ),
                    &mut first,
                );
            }
            for w in &rep.windows {
                if w.participants.is_empty() {
                    push(
                        format!(
                            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":{},\
                             \"tid\":0,\"ts\":{},\"dur\":{},\
                             \"cat\":\"modeled\"}}",
                            w.phase.name(),
                            rep.replica,
                            w.start_us,
                            w.dur_us
                        ),
                        &mut first,
                    );
                    continue;
                }
                for &id in &w.participants {
                    push(
                        format!(
                            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":{},\
                             \"tid\":{id},\"ts\":{},\"dur\":{},\
                             \"cat\":\"modeled\",\"args\":{{\"step\":{}}}}}",
                            w.phase.name(),
                            rep.replica,
                            w.start_us,
                            w.dur_us,
                            w.step
                        ),
                        &mut first,
                    );
                }
            }
        }
        let _ = write!(out, "\n]}}\n");
        out
    }

    /// Human-readable markdown summary (`flashsampling profile`).
    pub fn to_markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "## modeled-time profile ({})\n", self.pricer);
        for rep in &self.replicas {
            let _ = writeln!(
                out,
                "### replica {} — {} windows, makespan {} µs\n",
                rep.replica,
                rep.windows.len(),
                rep.makespan_us
            );
            let _ = writeln!(
                out,
                "| id | queue µs | prefill | chunk | swap | spec | decode \
                 | span | ttft | tokens | finish |"
            );
            let _ = writeln!(
                out,
                "|---|---|---|---|---|---|---|---|---|---|---|"
            );
            for r in &rep.requests {
                let _ = writeln!(
                    out,
                    "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} \
                     | {} |",
                    r.id,
                    r.queue_us,
                    r.prefill_us,
                    r.chunk_us,
                    r.swap_us,
                    r.spec_us,
                    r.decode_us,
                    r.span_us,
                    r.ttft_us.map_or("-".into(), |t| t.to_string()),
                    r.tokens,
                    r.finish
                );
            }
            out.push('\n');
        }
        let _ = writeln!(out, "profile digest: {:#018x}", self.digest());
        out
    }
}

/// Count per-request SLO violations over a profile's modeled times:
/// `(ttft_violations, itl_violations)` against microsecond thresholds
/// (0 disables a threshold).  The serving-path equivalent — on measured
/// wall time — lives in [`crate::metrics::ServingMetrics`].
pub fn slo_violations(
    profile: &Profile,
    slo_ttft_us: u64,
    slo_itl_us: u64,
) -> (u64, u64) {
    let mut ttft = 0u64;
    let mut itl = 0u64;
    for rep in &profile.replicas {
        for r in &rep.requests {
            if slo_ttft_us > 0 && r.ttft_us.is_some_and(|t| t > slo_ttft_us) {
                ttft += 1;
            }
            if slo_itl_us > 0
                && r.token_times_us.windows(2).any(|w| w[1] - w[0] > slo_itl_us)
            {
                itl += 1;
            }
        }
    }
    (ttft, itl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::B200;
    use crate::gpusim::tpot::QWEN3_8B;
    use crate::trace::TraceLevel;

    #[test]
    fn canonical_tracks_derived_table() {
        // The canonical table is FROZEN (digests embed it); the live
        // derivation must stay within tolerance so a gpusim
        // recalibration is flagged here instead of silently diverging.
        let c = PriceTable::canonical();
        let d = PriceTable::derive(&B200, &QWEN3_8B);
        for (name, canon, derived) in [
            ("prefill_us_per_token", c.prefill_us_per_token,
             d.prefill_us_per_token),
            ("prefill_stream_floor_us", c.prefill_stream_floor_us,
             d.prefill_stream_floor_us),
            ("window_fixed_us", c.window_fixed_us, d.window_fixed_us),
            ("decode_step_us", c.decode_step_us, d.decode_step_us),
            ("spec_draft_us", c.spec_draft_us, d.spec_draft_us),
            ("spec_verify_us", c.spec_verify_us, d.spec_verify_us),
            ("swap_us_per_block", c.swap_us_per_block, d.swap_us_per_block),
            ("dispatch_us", c.dispatch_us, d.dispatch_us),
        ] {
            let lo = derived as f64 * 0.7;
            let hi = derived as f64 * 1.3;
            assert!(
                (canon as f64) >= lo && (canon as f64) <= hi,
                "{name}: canonical {canon} drifted outside ±30% of \
                 derived {derived} — re-pin PriceTable::canonical and \
                 recertify the profile digests"
            );
        }
    }

    #[test]
    fn pricing_rules() {
        let p = PriceTable::canonical();
        // Small suffixes hit the stream floor; large prompts scale.
        assert_eq!(
            p.prefill_us(1),
            p.prefill_stream_floor_us + p.window_fixed_us
        );
        assert_eq!(
            p.prefill_us(1000),
            1000 * p.prefill_us_per_token + p.window_fixed_us
        );
        assert_eq!(p.chunk_window_us(16), p.prefill_us(16));
        assert_eq!(p.spec_us(0), p.spec_verify_us);
        assert_eq!(p.spec_us(3), p.spec_verify_us + 3 * p.spec_draft_us);
        assert_eq!(p.swap_us(5), 5 * p.swap_us_per_block);
        assert_eq!(p.idle_us(), 0);
        let s = StepClockPricer;
        assert_eq!(s.prefill_us(0), 1);
        assert_eq!(s.prefill_us(40), 40);
        assert_eq!(s.chunk_window_us(16), 16);
        assert_eq!(s.decode_us(), 1);
        assert_eq!(s.spec_us(7), 1);
        assert_eq!(s.swap_us(9), 0);
        assert_eq!(s.idle_us(), 1);
    }

    /// Hand-built trace: two requests batched through prefill, one
    /// decode step, one finish mid-batch, one front-door reject.
    fn tiny_trace() -> Trace {
        let mut t = Trace::new(TraceLevel::Lifecycle);
        t.emit(0, 0, EventKind::Submit { prompt_len: 8, max_new: 2 });
        t.emit(0, 1, EventKind::Submit { prompt_len: 4, max_new: 1 });
        t.emit(0, 2, EventKind::Reject { reason: "empty prompt".into() });
        t.emit(1, 0, EventKind::Prefill { prompt_len: 8 });
        t.emit(1, 0, EventKind::FirstToken { row: 0, cstep: 0, token: 5 });
        t.emit(1, 1, EventKind::Prefill { prompt_len: 4 });
        t.emit(1, 1, EventKind::FirstToken { row: 1, cstep: 0, token: 6 });
        t.emit(1, 1, EventKind::Finish { reason: "max_tokens", tokens: 1 });
        t.emit(2, 0, EventKind::DecodeToken { row: 0, cstep: 1, token: 7 });
        t.emit(2, 0, EventKind::Finish { reason: "max_tokens", tokens: 2 });
        t
    }

    #[test]
    fn windows_group_and_balance() {
        let t = tiny_trace();
        let p = profile_trace(0, &t, &StepClockPricer).unwrap();
        p.check().unwrap();
        // One prefill window (both rows, longest uncached = 8) and one
        // decode window.
        assert_eq!(p.windows.len(), 2);
        assert_eq!(p.windows[0].phase, Phase::Prefill);
        assert_eq!(p.windows[0].dur_us, 8);
        assert_eq!(p.windows[0].participants, vec![0, 1]);
        assert_eq!(p.windows[1].phase, Phase::Decode);
        assert_eq!(p.windows[1].dur_us, 1);
        assert_eq!(p.makespan_us, 9);
        let r0 = &p.requests[0];
        assert_eq!(r0.prefill_us, 8);
        assert_eq!(r0.decode_us, 1);
        assert_eq!(r0.queue_us, 0);
        assert_eq!(r0.span_us, 9);
        assert_eq!(r0.ttft_us, Some(8));
        assert_eq!(r0.token_times_us, vec![8, 9]);
        // The mid-batch finish is stamped at the prefill window's end.
        let r1 = &p.requests[1];
        assert_eq!(r1.finish_us, Some(8));
        assert_eq!(r1.span_us, 8);
        assert_eq!(r1.prefill_us, 8);
        assert_eq!(r1.queue_us, 0);
        // Front-door reject: zero-length span, zero compute.
        let r2 = &p.requests[2];
        assert_eq!(r2.span_us, 0);
        assert_eq!(r2.attributed_us(), 0);
        assert_eq!(r2.finish, "rejected");
        assert_eq!(r2.tokens, 0);
    }

    #[test]
    fn modeled_pricer_balances_and_exports() {
        let t = tiny_trace();
        let profile =
            profile_tracks(&[(0, &t)], &PriceTable::canonical()).unwrap();
        profile.check().unwrap();
        let table = PriceTable::canonical();
        let rep = &profile.replicas[0];
        assert_eq!(
            rep.makespan_us,
            table.prefill_us(8) + table.decode_step_us
        );
        let chrome = profile.chrome_json();
        assert!(chrome.contains("\"name\":\"prefill\""));
        assert!(chrome.contains("\"name\":\"decode\""));
        assert!(chrome.contains(&format!("\"dur\":{}", table.prefill_us(8))));
        assert!(chrome.ends_with("]}\n"));
        let md = profile.to_markdown();
        assert!(md.contains("profile digest:"));
        // Replay determinism of the digest.
        let again =
            profile_tracks(&[(0, &t)], &PriceTable::canonical()).unwrap();
        assert_eq!(profile.digest(), again.digest());
    }

    #[test]
    fn chunk_and_radix_reduce_the_priced_suffix() {
        let mut t = Trace::new(TraceLevel::Lifecycle);
        t.emit(0, 0, EventKind::Submit { prompt_len: 40, max_new: 1 });
        t.emit(1, 0, EventKind::ChunkWindow { take: 16, prefilled: 16 });
        t.emit(2, 0, EventKind::ChunkWindow { take: 16, prefilled: 32 });
        t.emit(3, 0, EventKind::Prefill { prompt_len: 40 });
        t.emit(3, 0, EventKind::FirstToken { row: 0, cstep: 0, token: 1 });
        t.emit(3, 0, EventKind::Finish { reason: "max_tokens", tokens: 1 });
        t.emit(4, 1, EventKind::Submit { prompt_len: 32, max_new: 1 });
        t.emit(5, 1, EventKind::RadixAttach { tokens: 24 });
        t.emit(5, 1, EventKind::Prefill { prompt_len: 32 });
        t.emit(5, 1, EventKind::FirstToken { row: 0, cstep: 1, token: 2 });
        t.emit(5, 1, EventKind::Finish { reason: "max_tokens", tokens: 1 });
        let p = profile_trace(0, &t, &StepClockPricer).unwrap();
        p.check().unwrap();
        // Chunked request: two 16-token windows, final suffix 40-32=8.
        assert_eq!(p.windows[0].dur_us, 16);
        assert_eq!(p.windows[1].dur_us, 16);
        assert_eq!(p.windows[2].dur_us, 8);
        assert_eq!(p.requests[0].chunk_us, 32);
        assert_eq!(p.requests[0].prefill_us, 8);
        // Cached request: only the uncached 8-token suffix is priced.
        assert_eq!(p.windows[3].dur_us, 8);
        assert_eq!(p.requests[1].prefill_us, 8);
    }

    #[test]
    fn eviction_is_refused() {
        let mut t = Trace::with_capacity(TraceLevel::Lifecycle, 2);
        for i in 0..4 {
            t.emit(i, i, EventKind::Submit { prompt_len: 4, max_new: 1 });
        }
        let err = profile_trace(0, &t, &StepClockPricer).unwrap_err();
        assert!(err.to_string().contains("trace_ring_cap"));
    }

    #[test]
    fn slo_violation_counting() {
        let t = tiny_trace();
        let profile =
            profile_tracks(&[(0, &t)], &PriceTable::canonical()).unwrap();
        let table = PriceTable::canonical();
        let ttft = table.prefill_us(8);
        // Thresholds just below the modeled TTFT / ITL trip; 0 is off.
        assert_eq!(slo_violations(&profile, ttft - 1, 0), (2, 0));
        assert_eq!(
            slo_violations(&profile, 0, table.decode_step_us - 1),
            (0, 1)
        );
        assert_eq!(slo_violations(&profile, 0, 0), (0, 0));
        assert_eq!(
            slo_violations(&profile, ttft, table.decode_step_us),
            (0, 0)
        );
    }
}
