//! Certified sub-vocabulary LM head (DESIGN.md §16).
//!
//! CSV-Decode / FlashHead-style tile skipping fused into FlashSampling's
//! tiled structure: maintain a per-context *candidate sub-vocabulary* (a
//! small set of hot vocab tiles, ranked by frequency/recency from the
//! prompt and the emitted tokens), run the fused LM-head kernel only over
//! those tiles, and accept the skipped-tile sample **only when a
//! certificate proves the excluded tiles cannot win** the Gumbel-argmax.
//! Otherwise the engine falls back to the full-vocabulary pass at the same
//! Philox `(row, step)` coordinates — so the token stream is bit-identical
//! to full FlashSampling either way, and skipping is purely a speed lever.
//!
//! The certificate is a per-tile Cauchy–Schwarz bound.  For an excluded
//! tile `t` with per-tile weight norm `N_t = max_{i in t} ||W_i||_2`, every
//! excluded perturbed score obeys
//!
//! ```text
//!   s_i = <W_i, h> / tau + g_i  <=  N_t * ||h||_2 / tau + max_{i in t} g_i
//! ```
//!
//! `N_t` is precomputed once per artifact set from the LM-head weights
//! ([`TileNorms`]); `||h||_2` comes back from the `decode_sample_sub`
//! artifact (or is computed on the host path); and the per-tile max Gumbel
//! is evaluated *exactly* from the shared Philox streams — O(V) RNG work,
//! which is noise next to the O(V·D) matmul the skip avoids.  If the
//! candidate winner's score strictly exceeds every excluded tile's bound
//! (plus a configurable slack), no excluded index can tie or beat it, so
//! the candidate argmax *is* the full-vocab argmax — exactness by
//! construction, certified per step, never assumed.

use std::collections::HashMap;

use crate::sampling::philox::{self, Key};

/// Width of a rankable vocab tile.  Mirrors `SUB_TILE_V` in
/// `python/compile/aot.py` — finer than the kernel's `DEFAULT_TILE_V` so a
/// small budget still covers the hot head of the unigram distribution.
pub const SUB_TILE_V: usize = 128;

/// Fixed slot count of the `decode_sample_sub` artifacts' `tiles` input
/// (unused slots are -1).  Mirrors `SUB_TILES` in `python/compile/aot.py`.
pub const SUB_TILE_SLOTS: usize = 4;

/// Knobs threaded in from `EngineConfig` (config keys `subvocab_tiles`,
/// `subvocab_slack`).
#[derive(Clone, Copy, Debug)]
pub struct SubvocabConfig {
    /// Candidate tile budget per decode batch (<= [`SUB_TILE_SLOTS`]).
    pub tile_budget: usize,
    /// Additive safety margin on the certificate: skip only when
    /// `winner > bound + slack`.  0.0 is already exact; positive values
    /// trade fallback rate for numerical headroom.
    pub slack: f32,
}

impl Default for SubvocabConfig {
    fn default() -> Self {
        Self { tile_budget: SUB_TILE_SLOTS, slack: 0.0 }
    }
}

/// Per-context candidate-set maintainer: frequency/recency statistics over
/// vocab tiles, updated online from prompt tokens and emitted tokens.
#[derive(Clone, Debug)]
pub struct CandidateSet {
    tile_v: usize,
    /// Tokens observed per tile (prompt + emissions).
    counts: Vec<u64>,
    /// Logical observation clock of the last token seen per tile (0 =
    /// never observed).
    last_seen: Vec<u64>,
    clock: u64,
}

impl CandidateSet {
    pub fn new(vocab: usize, tile_v: usize) -> Self {
        assert!(tile_v > 0);
        let n_tiles = vocab.div_ceil(tile_v);
        Self { tile_v, counts: vec![0; n_tiles], last_seen: vec![0; n_tiles], clock: 0 }
    }

    pub fn n_tiles(&self) -> usize {
        self.counts.len()
    }

    /// Record one observed token (emitted or prompt).
    pub fn observe(&mut self, token: i32) {
        if token < 0 {
            return;
        }
        let t = token as usize / self.tile_v;
        if t < self.counts.len() {
            self.clock += 1;
            self.counts[t] += 1;
            self.last_seen[t] = self.clock;
        }
    }

    /// Seed the set from the prompt's unigram statistics.
    pub fn observe_prompt(&mut self, tokens: &[i32]) {
        for &t in tokens {
            self.observe(t);
        }
    }

    /// The top-`budget` tiles by (count desc, recency desc, tile-id asc),
    /// returned sorted ascending.  Fully deterministic: unseen tiles rank
    /// by ascending id, so the result is well-defined even on a cold set.
    pub fn candidates(&self, budget: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.n_tiles() as u32).collect();
        order.sort_by(|&a, &b| {
            let (a, b) = (a as usize, b as usize);
            self.counts[b]
                .cmp(&self.counts[a])
                .then(self.last_seen[b].cmp(&self.last_seen[a]))
                .then(a.cmp(&b))
        });
        order.truncate(budget.max(1).min(self.n_tiles()));
        order.sort_unstable();
        order
    }
}

/// Per-tile weight-norm bounds, precomputed once per artifact set:
/// `norms[t] = max_{i in tile t} ||W_i||_2`.
#[derive(Clone, Debug)]
pub struct TileNorms {
    pub tile_v: usize,
    pub vocab: usize,
    pub norms: Vec<f32>,
}

impl TileNorms {
    /// Compute from the row-major `[vocab, d]` LM-head weight.
    pub fn from_lm_head(w: &[f32], vocab: usize, d: usize, tile_v: usize) -> Self {
        assert_eq!(w.len(), vocab * d, "lm_head shape mismatch");
        let n_tiles = vocab.div_ceil(tile_v);
        let mut norms = vec![0.0f32; n_tiles];
        for i in 0..vocab {
            let row = &w[i * d..(i + 1) * d];
            let n = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            let t = i / tile_v;
            if n > norms[t] {
                norms[t] = n;
            }
        }
        Self { tile_v, vocab, norms }
    }

    pub fn n_tiles(&self) -> usize {
        self.norms.len()
    }
}

/// Max over all *excluded* tiles of the certificate bound
/// `N_t * h_norm / tau + max Gumbel over the tile` at Philox coordinates
/// `(row, step)`.  `candidates` lists the included tile ids; entries `< 0`
/// (slot padding) are ignored.  Returns `NEG_INFINITY` when every tile is
/// included — the skip is then trivially admissible.
pub fn excluded_bound(
    norms: &TileNorms,
    candidates: &[i32],
    h_norm: f32,
    tau: f32,
    key: Key,
    row: u32,
    step: u32,
) -> f32 {
    let mut included = vec![false; norms.n_tiles()];
    for &t in candidates {
        if t >= 0 && (t as usize) < included.len() {
            included[t as usize] = true;
        }
    }
    let mut bound = f32::NEG_INFINITY;
    let mut gbuf = vec![0.0f32; norms.tile_v];
    for (t, inc) in included.iter().enumerate() {
        if *inc {
            continue;
        }
        let start = t * norms.tile_v;
        let len = norms.tile_v.min(norms.vocab - start);
        philox::gumbel_row(key, row, step, start as u32, &mut gbuf[..len]);
        let gmax = gbuf[..len].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let b = norms.norms[t] * h_norm / tau + gmax;
        if b > bound {
            bound = b;
        }
    }
    bound
}

/// Full-vocabulary Gumbel-argmax over materialized `W h` — the oracle the
/// certificate must never disagree with.  First-max tie-breaking matches
/// `jnp.argmax` (and hence the fused kernel's cross-tile reduce).
pub fn full_argmax(
    w: &[f32],
    vocab: usize,
    d: usize,
    h: &[f32],
    tau: f32,
    key: Key,
    row: u32,
    step: u32,
) -> (i32, f32) {
    let mut best = f32::NEG_INFINITY;
    let mut arg = 0i32;
    for i in 0..vocab {
        let y = dot(&w[i * d..(i + 1) * d], h) / tau;
        let s = y + philox::gumbel_at(key, i as u32, row, step);
        if s > best {
            best = s;
            arg = i as i32;
        }
    }
    (arg, best)
}

/// Outcome of one certified sub-vocabulary sampling step.
#[derive(Clone, Copy, Debug)]
pub struct CertifiedDraw {
    /// The sampled token — from the candidate tiles when `fallback` is
    /// false, from the full pass otherwise.  Bit-identical to
    /// [`full_argmax`] in both cases.
    pub token: i32,
    /// True when the certificate could not rule out the excluded tiles and
    /// the full-vocabulary pass was taken.
    pub fallback: bool,
    /// The candidate winner's perturbed score.
    pub winner_score: f32,
    /// The excluded tiles' certificate bound ([`excluded_bound`]).
    pub bound: f32,
}

/// Host-side reference of the certified decode protocol — the oracle for
/// `repro subvocab-identity` and `rust/tests/subvocab.rs`.  The engine runs
/// the same accept/fallback decision against the `decode_sample_sub`
/// artifact's (sample, winner score, hidden norm) outputs.
///
/// `candidates` must be sorted ascending (as [`CandidateSet::candidates`]
/// returns them) so candidate-side tie-breaking scans indices in the same
/// order as the full pass.
pub fn certified_sample(
    w: &[f32],
    vocab: usize,
    d: usize,
    h: &[f32],
    tau: f32,
    candidates: &[u32],
    norms: &TileNorms,
    slack: f32,
    key: Key,
    row: u32,
    step: u32,
) -> CertifiedDraw {
    debug_assert!(candidates.windows(2).all(|p| p[0] < p[1]), "candidates must be sorted");
    // Candidate pass: exact perturbed scores over the included tiles only.
    let mut best = f32::NEG_INFINITY;
    let mut arg = -1i32;
    for &t in candidates {
        let start = (t as usize) * norms.tile_v;
        if start >= vocab {
            continue;
        }
        let end = (start + norms.tile_v).min(vocab);
        for i in start..end {
            let y = dot(&w[i * d..(i + 1) * d], h) / tau;
            let s = y + philox::gumbel_at(key, i as u32, row, step);
            if s > best {
                best = s;
                arg = i as i32;
            }
        }
    }
    let h_norm = dot(h, h).sqrt();
    let cand_i32: Vec<i32> = candidates.iter().map(|&t| t as i32).collect();
    let bound = excluded_bound(norms, &cand_i32, h_norm, tau, key, row, step);
    if arg >= 0 && best > bound + slack {
        return CertifiedDraw { token: arg, fallback: false, winner_score: best, bound };
    }
    let (token, _) = full_argmax(w, vocab, d, h, tau, key, row, step);
    CertifiedDraw { token, fallback: true, winner_score: best, bound }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Engine-side state: the precomputed tile norms plus one [`CandidateSet`]
/// per live request.
#[derive(Debug)]
pub struct SubvocabState {
    pub cfg: SubvocabConfig,
    pub norms: TileNorms,
    sets: HashMap<u64, CandidateSet>,
}

impl SubvocabState {
    pub fn new(lm_head: &[f32], vocab: usize, d: usize, cfg: SubvocabConfig) -> Self {
        let norms = TileNorms::from_lm_head(lm_head, vocab, d, SUB_TILE_V);
        Self { cfg, norms, sets: HashMap::new() }
    }

    fn set_mut(&mut self, id: u64) -> &mut CandidateSet {
        let (vocab, tile_v) = (self.norms.vocab, self.norms.tile_v);
        self.sets.entry(id).or_insert_with(|| CandidateSet::new(vocab, tile_v))
    }

    /// Seed a request's candidate set from its prompt.
    pub fn observe_prompt(&mut self, id: u64, tokens: &[i32]) {
        self.set_mut(id).observe_prompt(tokens);
    }

    /// Fold one emitted token into the request's candidate set.
    pub fn observe_token(&mut self, id: u64, token: i32) {
        self.set_mut(id).observe(token);
    }

    /// Drop a finished/aborted request's state.
    pub fn release(&mut self, id: u64) {
        self.sets.remove(&id);
    }

    /// Merged candidate tiles for one decode batch, padded with -1 to
    /// `slots` (the artifact's fixed `tiles` input width).  Tiles rank by
    /// summed counts then max recency across the batch's rows — one shared
    /// list per batch, matching the artifact's one-`tiles`-per-call ABI.
    pub fn batch_tiles(&mut self, ids: &[u64], slots: usize) -> Vec<i32> {
        let n_tiles = self.norms.n_tiles();
        let mut counts = vec![0u64; n_tiles];
        let mut recency = vec![0u64; n_tiles];
        for &id in ids {
            let set = self.set_mut(id);
            for t in 0..n_tiles {
                counts[t] += set.counts[t];
                recency[t] = recency[t].max(set.last_seen[t]);
            }
        }
        let mut order: Vec<usize> = (0..n_tiles).collect();
        order.sort_by(|&a, &b| {
            counts[b]
                .cmp(&counts[a])
                .then(recency[b].cmp(&recency[a]))
                .then(a.cmp(&b))
        });
        let budget = self.cfg.tile_budget.max(1).min(slots).min(n_tiles);
        let mut tiles: Vec<i32> = order[..budget].iter().map(|&t| t as i32).collect();
        tiles.sort_unstable();
        tiles.resize(slots, -1);
        tiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skew-structured toy LM head: tile 0 carries hot rows (amplitude
    /// `a_i` in [0.45, 0.6] along the all-ones direction plus small
    /// noise), later tiles are pure noise.  The structure matters:
    /// random-direction rows at equal scale never admit a certified skip
    /// — Cauchy–Schwarz is loose by ~sqrt(d) for incoherent vectors — so
    /// an isotropic fixture would only ever exercise the fallback path.
    /// This mirrors the Zipf-hot unigram shape the subsystem targets.
    fn toy_head(vocab: usize, d: usize, seed: u64) -> Vec<f32> {
        let key = Key::from_seed(seed);
        let mut w = vec![0.0f32; vocab * d];
        for i in 0..vocab {
            let hot = i < SUB_TILE_V;
            let a = 0.45
                + 0.15 * philox::uniform_at(key, i as u32, d as u32, 5, 0);
            for j in 0..d {
                let n =
                    philox::uniform_at(key, i as u32, j as u32, 5, 0) - 0.5;
                w[i * d + j] = if hot { a + 0.25 * n } else { n };
            }
        }
        w
    }

    /// Step-varying hidden state: a shared bias `b` in [-0.25, 1.25]
    /// along the all-ones direction (the alignment knob — steps with `b`
    /// near zero give the certificate nothing to prove and must fall
    /// back) plus unit-scale noise.
    fn toy_hidden(d: usize, seed: u64, step: u32) -> Vec<f32> {
        let key = Key::from_seed(seed);
        let b = 1.5 * philox::uniform_at(key, d as u32, 0, 6, step) - 0.25;
        (0..d)
            .map(|j| b + philox::uniform_at(key, j as u32, 0, 6, step) - 0.5)
            .collect()
    }

    #[test]
    fn candidate_ranking_is_frequency_then_recency() {
        let mut cs = CandidateSet::new(512, 128); // 4 tiles
        cs.observe_prompt(&[0, 1, 2, 130, 131, 260]); // t0 x3, t1 x2, t2 x1
        assert_eq!(cs.candidates(2), vec![0, 1]);
        // Recency breaks a count tie: push t3 to 1 observation, then t2
        // again — both at 2 observations, t2 more recent.
        cs.observe(390); // t3
        cs.observe(261); // t2 -> counts t2=2, t3=1
        assert_eq!(cs.candidates(3), vec![0, 1, 2]);
        // Out-of-range / negative tokens are ignored, not panics.
        cs.observe(-1);
        cs.observe(100_000);
        assert_eq!(cs.candidates(3), vec![0, 1, 2]);
    }

    #[test]
    fn cold_set_is_deterministic() {
        let cs = CandidateSet::new(1024, 128);
        assert_eq!(cs.candidates(3), vec![0, 1, 2]);
    }

    #[test]
    fn tile_norms_bound_every_row() {
        let (vocab, d) = (300, 16); // ragged last tile
        let w = toy_head(vocab, d, 7);
        let tn = TileNorms::from_lm_head(&w, vocab, d, 128);
        assert_eq!(tn.n_tiles(), 3);
        for i in 0..vocab {
            let n = w[i * d..(i + 1) * d].iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(n <= tn.norms[i / 128] + 1e-6, "row {i}");
        }
    }

    #[test]
    fn all_tiles_included_never_falls_back() {
        let (vocab, d) = (512, 32);
        let w = toy_head(vocab, d, 1);
        let tn = TileNorms::from_lm_head(&w, vocab, d, 128);
        let key = Key::from_seed(9);
        for step in 0..20 {
            let h = toy_hidden(d, 2, step);
            let all: Vec<u32> = (0..tn.n_tiles() as u32).collect();
            let draw = certified_sample(&w, vocab, d, &h, 1.0, &all, &tn, 0.0, key, 0, step);
            assert!(!draw.fallback, "step {step}");
            let (oracle, _) = full_argmax(&w, vocab, d, &h, 1.0, key, 0, step);
            assert_eq!(draw.token, oracle, "step {step}");
        }
    }

    #[test]
    fn fallback_token_is_identical_to_full_pass() {
        let (vocab, d) = (512, 32);
        let w = toy_head(vocab, d, 3);
        let tn = TileNorms::from_lm_head(&w, vocab, d, 128);
        let key = Key::from_seed(4);
        // Huge slack forces the fallback on every step.
        for step in 0..20 {
            let h = toy_hidden(d, 5, step);
            let draw =
                certified_sample(&w, vocab, d, &h, 1.0, &[0], &tn, 1e9, key, 0, step);
            assert!(draw.fallback);
            let (oracle, _) = full_argmax(&w, vocab, d, &h, 1.0, key, 0, step);
            assert_eq!(draw.token, oracle, "step {step}");
        }
    }

    #[test]
    fn admitted_skips_match_the_oracle() {
        let (vocab, d) = (512, 32);
        let w = toy_head(vocab, d, 11);
        let tn = TileNorms::from_lm_head(&w, vocab, d, 128);
        let key = Key::from_seed(12);
        let mut skips = 0;
        for step in 0..200 {
            let h = toy_hidden(d, 13, step);
            for budget in 1..=3usize {
                let cands: Vec<u32> = (0..budget as u32).collect();
                let draw =
                    certified_sample(&w, vocab, d, &h, 0.25, &cands, &tn, 0.0, key, 0, step);
                let (oracle, _) = full_argmax(&w, vocab, d, &h, 0.25, key, 0, step);
                assert_eq!(draw.token, oracle, "step {step} budget {budget}");
                if !draw.fallback {
                    skips += 1;
                    // The certificate's self-consistency: the winner beat
                    // the excluded bound.
                    assert!(draw.winner_score > draw.bound);
                }
            }
        }
        assert!(skips > 0, "certificate never admitted a skip at tau=0.25");
    }

    #[test]
    fn batch_tiles_merges_and_pads() {
        let (vocab, d) = (512, 8);
        let w = toy_head(vocab, d, 21);
        let mut st = SubvocabState::new(
            &w,
            vocab,
            d,
            SubvocabConfig { tile_budget: 2, slack: 0.0 },
        );
        st.observe_prompt(1, &[0, 1, 2]); // tile 0
        st.observe_prompt(2, &[130, 131]); // tile 1
        st.observe_token(2, 390); // tile 3
        let tiles = st.batch_tiles(&[1, 2], SUB_TILE_SLOTS);
        assert_eq!(tiles.len(), SUB_TILE_SLOTS);
        assert_eq!(&tiles[..2], &[0, 1]);
        assert_eq!(&tiles[2..], &[-1, -1]);
        st.release(1);
        let tiles = st.batch_tiles(&[2], SUB_TILE_SLOTS);
        assert_eq!(&tiles[..2], &[1, 3]);
    }
}
