//! `repro profile-identity` — the modeled-time profiler's conservation,
//! tiling, determinism, and profile ⇔ metrics certificate (DESIGN.md §15).
//!
//! The profiler is only worth trusting if its output is a *theorem about
//! the trace*, not a plausible summary.  Claims certified, all CPU-only:
//!
//! 1. **Span balance + makespan tiling** — over the trace-identity
//!    scenario matrix (chunked prefill, swap preemption, speculative
//!    decode, aging, forced aborts, submit-time rejection), under BOTH
//!    pricers: windows tile the makespan contiguously from zero with no
//!    negative durations, and every request's attributed phases plus its
//!    queue residual equal its span, with the residual independently
//!    re-derived by rescanning the window tiling
//!    ([`crate::profile::ReplicaProfile::check`]).
//! 2. **Step-clock agreement (scheduler sim)** — profiling with the
//!    [`StepClockPricer`] lands every stamp on the sim's own weighted
//!    clock: per request, profiled `ttft_us` equals the outcome
//!    certificate's `ttft_weighted` and the profiled token stamps equal
//!    `token_times` element-for-element.
//! 3. **Step-clock agreement (router replicas)** — on `Router<SimReplica>`
//!    (real KV/radix accounting, prefix-affinity, mid-wave aborts), the
//!    profiled spans of completed token-bearing requests reproduce that
//!    replica's [`ServingMetrics::ttft`] population exactly, and the
//!    profiled makespan equals the replica's final weighted clock.
//! 4. **Replay determinism** — rerunning the same workloads yields
//!    bit-identical modeled-profile digests (integer prices over a
//!    replay-stable event stream leave nothing to drift).
//! 5. **Python mirror anchor** — the bare-replica mirror run (shared with
//!    `repro trace-identity`) is profiled under the pinned canonical
//!    price table and its digest exported as a table row;
//!    `python/tests/sim_profile_bench.py` re-derives the same digest from
//!    an independent integer-only reimplementation and asserts bitwise
//!    equality against this report's CSV, including the pinned price
//!    constants.
//!
//! [`StepClockPricer`]: crate::profile::StepClockPricer
//! [`ServingMetrics::ttft`]: crate::metrics::ServingMetrics

use anyhow::Result;

use crate::profile::{
    profile_trace, profile_tracks, PriceTable, StepClockPricer,
};
use crate::router::{
    sim_router, DispatchPolicy, Router, SimReplica, SimReplicaConfig,
};
use crate::testutil::schedsim::Sim;
use crate::trace::{Trace, TraceLevel};

use super::router_identity::session_waves;
use super::trace_identity::{drive_router, mirror_run, scenarios};

/// The trace-identity router workload: 2 replicas, prefix-affinity,
/// session waves with mid-wave aborts — reused here so the profiler is
/// certified on the exact stream whose replay identity PR 8 proved.
fn router_run() -> Router<SimReplica> {
    let waves = session_waves(6, 3, 4);
    let aborts = [(0usize, 2u64), (1usize, 9u64)];
    let cfg = SimReplicaConfig {
        trace_level: TraceLevel::Lifecycle,
        ..Default::default()
    };
    let mut r = sim_router(2, DispatchPolicy::PrefixAffinity, cfg);
    drive_router(&mut r, &waves, &aborts);
    r
}

pub fn profile_identity() -> Result<String> {
    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut ok_all = true;
    let mut notes: Vec<String> = Vec::new();
    let mut md = String::from(
        "## profile-identity — modeled-time profiler conservation and \
         profile-vs-metrics certificate (DESIGN.md §15)\n",
    );

    // 1. Conservation + tiling over the scenario matrix, both pricers.
    md.push_str(
        "\n### Span balance + makespan tiling (scheduler-sim scenario \
         matrix, step-clock and modeled pricers)\n\n\
         | scenario | events | windows | step makespan | modeled µs | \
         balance | verdict |\n|---|---|---|---|---|---|---|\n",
    );
    for (name, cfg, reqs) in scenarios() {
        let mut sim = Sim::new(cfg);
        sim.drive(&reqs);
        let step = profile_trace(0, &sim.trace, &StepClockPricer)?;
        let modeled = profile_trace(0, &sim.trace, &PriceTable::canonical())?;
        let chk = step.check().and_then(|()| modeled.check());
        let balance = chk.is_ok();
        if let Err(e) = chk {
            notes.push(format!("**MISMATCH — {name}: {e:#}**"));
        }
        ok_all &= balance;
        md.push_str(&format!(
            "| {name} | {} | {} | {} | {} | {balance} | {} |\n",
            sim.trace.total(),
            step.windows.len(),
            step.makespan_us,
            modeled.makespan_us,
            verdict(balance),
        ));
    }

    // 2. Step-clock agreement against the sim's own outcome certificates.
    md.push_str(
        "\n### Step-clock agreement — profiler ⇔ scheduler-sim outcomes \
         (ttft_weighted, token_times)\n\n\
         | scenario | requests | ttft | token stamps | verdict |\n\
         |---|---|---|---|---|\n",
    );
    for (name, cfg, reqs) in scenarios() {
        let mut sim = Sim::new(cfg);
        sim.drive(&reqs);
        let prof = profile_trace(0, &sim.trace, &StepClockPricer)?;
        let mut ttft_ok = prof.requests.len() == sim.outcomes.len();
        let mut stamps_ok = ttft_ok;
        for r in &prof.requests {
            match sim.outcomes.get(&r.id) {
                Some(o) => {
                    ttft_ok &= r.ttft_us == o.ttft_weighted;
                    stamps_ok &= r.token_times_us == o.token_times;
                }
                None => {
                    ttft_ok = false;
                    stamps_ok = false;
                }
            }
        }
        ok_all &= ttft_ok && stamps_ok;
        md.push_str(&format!(
            "| {name} | {} | {ttft_ok} | {stamps_ok} | {} |\n",
            prof.requests.len(),
            verdict(ttft_ok && stamps_ok),
        ));
    }

    // 3. Router replicas: profiled spans == ServingMetrics TTFT
    // population; profiled makespan == the replica's weighted clock.
    md.push_str(
        "\n### Step-clock agreement — profiler ⇔ SimReplica metrics \
         (2 replicas, prefix-affinity, mid-wave aborts)\n\n\
         | replica | events | completions | spans==ttft | \
         makespan==wtime | verdict |\n|---|---|---|---|---|---|\n",
    );
    let ra = router_run();
    for (i, e) in ra.replicas().iter().enumerate() {
        let prof = profile_trace(i, &e.trace, &StepClockPricer)?;
        let chk = prof.check();
        if let Err(err) = &chk {
            notes.push(format!("**MISMATCH — replica {i}: {err:#}**"));
        }
        // Every completed request that emitted tokens pushed one TTFT
        // sample equal to its weighted span (submit → finish); compare
        // the two populations order-independently.
        let mut spans: Vec<u64> = prof
            .requests
            .iter()
            .filter(|r| r.tokens > 0 && r.finish_us.is_some())
            .map(|r| r.span_us)
            .collect();
        spans.sort_unstable();
        let mut ttfts: Vec<u64> = e
            .metrics
            .ttft
            .iter()
            .map(|d| d.as_micros() as u64)
            .collect();
        ttfts.sort_unstable();
        let spans_ok = chk.is_ok() && spans == ttfts;
        let mk_ok = prof.makespan_us == e.wtime();
        ok_all &= spans_ok && mk_ok;
        md.push_str(&format!(
            "| {i} | {} | {} | {spans_ok} | {mk_ok} | {} |\n",
            e.trace.total(),
            ttfts.len(),
            verdict(spans_ok && mk_ok),
        ));
    }

    // 4. Replay determinism of the modeled digest.
    md.push_str(
        "\n### Replay determinism (same workload run twice, modeled \
         pricer)\n\n\
         | workload | digest A | digest B | verdict |\n|---|---|---|---|\n",
    );
    {
        let mut matrix = scenarios();
        let (name, cfg, reqs) = matrix.pop().expect("non-empty matrix");
        let mut a = Sim::new(cfg.clone());
        a.drive(&reqs);
        let mut b = Sim::new(cfg);
        b.drive(&reqs);
        let da = profile_tracks(&[(0, &a.trace)], &PriceTable::canonical())?
            .digest();
        let db = profile_tracks(&[(0, &b.trace)], &PriceTable::canonical())?
            .digest();
        ok_all &= da == db;
        md.push_str(&format!(
            "| {name} | {da:#018x} | {db:#018x} | {} |\n",
            verdict(da == db),
        ));
    }
    {
        let rb = router_run();
        let tracks = |r: &Router<SimReplica>| -> Result<u64> {
            let t: Vec<(usize, &Trace)> = r
                .replicas()
                .iter()
                .enumerate()
                .map(|(i, e)| (i, &e.trace))
                .collect();
            Ok(profile_tracks(&t, &PriceTable::canonical())?.digest())
        };
        let da = tracks(&ra)?;
        let db = tracks(&rb)?;
        ok_all &= da == db;
        md.push_str(&format!(
            "| router 2×prefix-affinity | {da:#018x} | {db:#018x} | {} |\n",
            verdict(da == db),
        ));
    }

    // 5. Python mirror anchor: the digest (and the pinned price table)
    // the cross-language mirror must reproduce bit-for-bit from the CSV
    // of this report.
    md.push_str(
        "\n### Python mirror anchor (python/tests/sim_profile_bench.py)\n\n\
         | leg | requests | events | digest |\n|---|---|---|---|\n",
    );
    let m = mirror_run();
    let mp = profile_tracks(&[(0, &m.trace)], &PriceTable::canonical())?;
    if let Err(e) = mp.check() {
        ok_all = false;
        notes.push(format!("**MISMATCH — mirror leg: {e:#}**"));
    }
    md.push_str(&format!(
        "| profile-mirror | 6 | {} | {:#018x} |\n",
        m.trace.total(),
        mp.digest(),
    ));
    let p = PriceTable::canonical();
    md.push_str(&format!(
        "\nPinned canonical price table (integer µs; the mirror asserts \
         these constants before re-deriving the digest):\n\n\
         | leg | prefill_us_per_token | prefill_stream_floor_us | \
         window_fixed_us | decode_step_us | spec_draft_us | \
         spec_verify_us | swap_us_per_block | dispatch_us |\n\
         |---|---|---|---|---|---|---|---|---|\n\
         | price-table | {} | {} | {} | {} | {} | {} | {} | {} |\n",
        p.prefill_us_per_token,
        p.prefill_stream_floor_us,
        p.window_fixed_us,
        p.decode_step_us,
        p.spec_draft_us,
        p.spec_verify_us,
        p.swap_us_per_block,
        p.dispatch_us,
    ));

    for n in &notes {
        md.push('\n');
        md.push_str(n);
        md.push('\n');
    }
    md.push_str(&format!(
        "\n**Overall: {}**\n",
        if ok_all {
            "IDENTICAL / BALANCED — modeled time is conserved, tiles the \
             makespan, agrees with the sims' own clocks, and replays \
             bit-for-bit"
        } else {
            "MISMATCH — see rows above"
        }
    ));
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn certificate_is_clean() {
        let md = profile_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        assert!(md.contains("IDENTICAL"));
        assert!(md.contains("profile-mirror"));
        assert!(md.contains("| price-table | 15 | 2412 | 1282 |"), "{md}");
        assert!(md.matches("###").count() >= 5, "{md}");
    }

    #[test]
    fn mirror_profile_digest_is_stable() {
        let digest = || {
            let m = mirror_run();
            let p = profile_tracks(&[(0, &m.trace)], &PriceTable::canonical())
                .unwrap();
            p.check().unwrap();
            p.digest()
        };
        assert_eq!(digest(), digest());
    }

    #[test]
    fn step_pricer_reproduces_outcome_stamps() {
        // The agreement the certificate rows assert, spelled out on one
        // scenario so a regression pinpoints the first divergent stamp.
        let mut matrix = scenarios();
        let (_, cfg, reqs) = matrix.remove(0);
        let mut sim = Sim::new(cfg);
        sim.drive(&reqs);
        let prof = profile_trace(0, &sim.trace, &StepClockPricer).unwrap();
        prof.check().unwrap();
        assert_eq!(prof.requests.len(), sim.outcomes.len());
        for r in &prof.requests {
            let o = &sim.outcomes[&r.id];
            assert_eq!(r.ttft_us, o.ttft_weighted, "request {}", r.id);
            assert_eq!(r.token_times_us, o.token_times, "request {}", r.id);
        }
    }
}
