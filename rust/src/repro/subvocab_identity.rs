//! `repro subvocab-identity` — the certified sub-vocabulary decode
//! certificate (DESIGN.md §16).
//!
//! The sub-vocab head is only admissible if it is *invisible*: skipping
//! cold vocab tiles must never change a single sampled token.  The
//! exactness argument has three load-bearing parts, each certified here
//! CPU-only, plus a cross-language anchor:
//!
//! 1. **Forced-fallback token identity** — with a slack large enough
//!    that the certificate never admits a skip, every
//!    [`certified_sample`] draw must equal the full-vocabulary
//!    Gumbel-argmax bit-for-bit (same Philox coordinates, same
//!    tie-breaking).  This pins the fallback path: when the bound can't
//!    rule the excluded tiles out, the sub-vocab head degenerates to the
//!    exact sampler.
//! 2. **Skip-enabled chi-squared GoF** — the paper's kernel-level
//!    protocol (V = 512, 10,000 draws) run through the certified head
//!    with an *online* candidate set (frequency/recency over its own
//!    emissions, tile budget 2 of 4): the empirical histogram must pass
//!    goodness-of-fit against the exact softmax at p > 0.001 while a
//!    non-trivial fraction of draws actually skip tiles.  Exactness
//!    under skipping is the tentpole claim — this leg tests it as a
//!    *distributional* statement, not just argmax identity.
//! 3. **Bound soundness on randomized logits** — for randomized heads,
//!    hidden states, and Philox steps, the per-tile Cauchy–Schwarz bound
//!    `N_t · ‖h‖₂ / τ + max Gumbel` must dominate every excluded row's
//!    actual perturbed score.  A single violation would make leg 1's
//!    identity a coincidence instead of a theorem.
//! 4. **Python mirror anchor** — a [`SimReplica`] run with the subvocab
//!    event model on, whose trace digest and fallback counters are
//!    exported as a table row; `python/tests/sim_subvocab_bench.py`
//!    re-derives the digest from an independent reimplementation of the
//!    event rule and asserts bitwise equality against this report's CSV.
//!
//! [`certified_sample`]: crate::subvocab::certified_sample
//! [`SimReplica`]: crate::router::SimReplica

use anyhow::Result;

use crate::coordinator::{Request, SamplingParams};
use crate::router::{EngineBackend, SimReplica, SimReplicaConfig};
use crate::sampling::{multinomial, philox, stats, Key, Transform};
use crate::subvocab::{
    certified_sample, excluded_bound, full_argmax, CandidateSet, TileNorms,
    SUB_TILE_V,
};
use crate::trace::TraceLevel;

const V: usize = 512;
const D: usize = 32;
const N_SAMPLES: u32 = 10_000;

/// Skew-structured LM head, identical to the subvocab unit fixture:
/// tile 0 carries hot rows (amplitude `a_i` in [0.45, 0.6] along the
/// all-ones direction plus small noise), later tiles are pure noise.
/// Isotropic rows would never admit a certified skip — Cauchy–Schwarz
/// is loose by ~sqrt(d) for incoherent vectors — leaving the skip path
/// unexercised.
fn toy_head(vocab: usize, d: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    let mut w = vec![0.0f32; vocab * d];
    for i in 0..vocab {
        let hot = i < SUB_TILE_V;
        let a =
            0.45 + 0.15 * philox::uniform_at(key, i as u32, d as u32, 5, 0);
        for j in 0..d {
            let n = philox::uniform_at(key, i as u32, j as u32, 5, 0) - 0.5;
            w[i * d + j] = if hot { a + 0.25 * n } else { n };
        }
    }
    w
}

/// Step-varying hidden state: a shared bias `b` in [-0.25, 1.25] along
/// the all-ones direction plus unit-scale noise; steps with `b` near
/// zero force full-vocab fallbacks.
fn toy_hidden(d: usize, seed: u64, step: u32) -> Vec<f32> {
    let key = Key::from_seed(seed);
    let b = 1.5 * philox::uniform_at(key, d as u32, 0, 6, step) - 0.25;
    (0..d)
        .map(|j| b + philox::uniform_at(key, j as u32, 0, 6, step) - 0.5)
        .collect()
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Leg 4's replica run: the trace-identity mirror workload (6 closed-loop
/// requests, `prompt_len = 24 + (id % 3) * 8`, `max_new = 3 + (id % 3)`,
/// prefix cache off, `Lifecycle` level) with the subvocab event model
/// enabled.  `python/tests/sim_subvocab_bench.py` re-derives this run's
/// digest and fallback counters bit-for-bit — keep the constants in
/// lockstep with that file.
pub(crate) fn mirror_run_subvocab() -> SimReplica {
    let cfg = SimReplicaConfig {
        prefix_caching: false,
        trace_level: TraceLevel::Lifecycle,
        subvocab: true,
        ..Default::default()
    };
    let mut e = SimReplica::new(cfg);
    for id in 0..6u64 {
        let plen = 24 + (id as usize % 3) * 8;
        let prompt: Vec<i32> =
            (0..plen).map(|j| ((id * 7 + j as u64) % 97) as i32).collect();
        let req = Request::new(
            id,
            prompt,
            SamplingParams {
                max_new_tokens: 3 + (id as usize % 3),
                ..Default::default()
            },
        );
        let _ = e.submit(req).expect("mirror submit");
    }
    let mut idle = 0;
    while e.pending() > 0 {
        let step = e.step().expect("mirror step");
        if step.is_empty() {
            idle += 1;
            assert!(idle < 64, "subvocab mirror leg livelock");
        } else {
            idle = 0;
        }
    }
    e
}

pub fn subvocab_identity() -> Result<String> {
    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut ok_all = true;
    let mut md = String::from(
        "## subvocab-identity — certified sub-vocabulary decode \
         certificate (DESIGN.md §16)\n",
    );

    // 1. Forced-fallback token identity across randomized instances.
    md.push_str(
        "\n### Forced-fallback token identity (slack = 1e9, 6 heads x 40 \
         steps)\n\n\
         | head seed | steps | fallbacks | token matches | verdict |\n\
         |---|---|---|---|---|\n",
    );
    for trial in 0..6u64 {
        let w = toy_head(V, D, 100 + trial);
        let tn = TileNorms::from_lm_head(&w, V, D, SUB_TILE_V);
        let key = Key::from_seed(200 + trial);
        let (mut fallbacks, mut matches) = (0u32, 0u32);
        for step in 0..40u32 {
            let h = toy_hidden(D, 300 + trial, step);
            let draw = certified_sample(
                &w, V, D, &h, 1.0, &[0, 1], &tn, 1e9, key, 0, step,
            );
            let (oracle, _) = full_argmax(&w, V, D, &h, 1.0, key, 0, step);
            fallbacks += draw.fallback as u32;
            matches += (draw.token == oracle) as u32;
        }
        let ok = fallbacks == 40 && matches == 40;
        ok_all &= ok;
        md.push_str(&format!(
            "| {} | 40 | {fallbacks} | {matches} | {} |\n",
            100 + trial,
            verdict(ok)
        ));
    }

    // 2. Skip-enabled chi-squared GoF with an online candidate set.
    md.push_str(
        "\n### Skip-enabled chi-squared GoF (V=512, 10k draws, online \
         candidate set, budget 2/4, tau=0.25)\n\n\
         | sampler | skip rate | p-value | verdict |\n|---|---|---|---|\n",
    );
    {
        let w = toy_head(V, D, 42);
        let tn = TileNorms::from_lm_head(&w, V, D, SUB_TILE_V);
        let key = Key::new(0x51, 0x52);
        let tau = 0.25f32;
        let h = toy_hidden(D, 43, 0);
        let logits: Vec<f32> =
            (0..V).map(|i| dot(&w[i * D..(i + 1) * D], &h) / tau).collect();
        let probs = multinomial::probs(&logits, &Transform::default());
        let mut cs = CandidateSet::new(V, SUB_TILE_V);
        let mut counts = vec![0u64; V];
        let mut skips = 0u64;
        for step in 0..N_SAMPLES {
            let cands = cs.candidates(2);
            let draw = certified_sample(
                &w, V, D, &h, tau, &cands, &tn, 0.0, key, 0, step,
            );
            counts[draw.token as usize] += 1;
            skips += !draw.fallback as u64;
            cs.observe(draw.token);
        }
        let p = stats::chi_squared_pvalue(&counts, &probs, N_SAMPLES as u64);
        let skip_rate = skips as f64 / N_SAMPLES as f64;
        let pass = p > 0.001 && skips > 0;
        ok_all &= pass;
        let v = if pass { "exact (not rejected)" } else { "REJECTED" };
        md.push_str(&format!(
            "| certified sub-vocab head | {skip_rate:.3} | {p:.4} | {v} |\n"
        ));
    }

    // 3. Bound soundness: the certificate must dominate every excluded
    // row's actual perturbed score.
    md.push_str(
        "\n### Bound soundness (randomized heads/hiddens/steps, excluded \
         rows vs certificate bound)\n\n\
         | trials | excluded rows checked | violations | verdict |\n\
         |---|---|---|---|\n",
    );
    {
        let mut checked = 0u64;
        let mut violations = 0u64;
        for trial in 0..12u64 {
            let w = toy_head(V, D, 500 + trial);
            let tn = TileNorms::from_lm_head(&w, V, D, SUB_TILE_V);
            let key = Key::from_seed(600 + trial);
            // Rotate which single tile is "included" so every tile gets
            // exercised as an excluded one.
            let included = [(trial % 4) as i32];
            for step in 0..8u32 {
                let h = toy_hidden(D, 700 + trial, step);
                let h_norm = dot(&h, &h).sqrt();
                let tau = if trial % 2 == 0 { 1.0 } else { 0.25 };
                let bound =
                    excluded_bound(&tn, &included, h_norm, tau, key, 0, step);
                for i in 0..V {
                    if (i / SUB_TILE_V) as i32 == included[0] {
                        continue;
                    }
                    let s = dot(&w[i * D..(i + 1) * D], &h) / tau
                        + philox::gumbel_at(key, i as u32, 0, step);
                    checked += 1;
                    violations += (s > bound) as u64;
                }
            }
        }
        let ok = violations == 0;
        ok_all &= ok;
        md.push_str(&format!(
            "| 12 | {checked} | {violations} | {} |\n",
            verdict(ok)
        ));
    }

    // 4. Python mirror anchor: a digest plus fallback accounting the
    // cross-language mirror must reproduce from this report's CSV.
    md.push_str(
        "\n### Python mirror anchor (python/tests/sim_subvocab_bench.py)\n\n\
         | leg | requests | events | digest |\n|---|---|---|---|\n",
    );
    let m = mirror_run_subvocab();
    let steps =
        m.metrics.counters.get("subvocab_steps").copied().unwrap_or(0);
    let fallbacks =
        m.metrics.counters.get("subvocab_fallbacks").copied().unwrap_or(0);
    md.push_str(&format!(
        "| sim-subvocab | 6 | {} | {:#018x} |\n",
        m.trace.total(),
        m.trace.digest(),
    ));
    let rate_ok = m.metrics.subvocab_fallback_rate()
        == (steps > 0).then(|| fallbacks as f64 / steps as f64);
    ok_all &= steps > 0 && fallbacks > 0 && fallbacks < steps && rate_ok;
    md.push_str(&format!(
        "\nFallback accounting: {fallbacks} fallbacks over {steps} subvocab \
         steps (rate {:.3}) — {}\n",
        fallbacks as f64 / steps.max(1) as f64,
        verdict(steps > 0 && fallbacks > 0 && fallbacks < steps && rate_ok),
    ));

    md.push_str(&format!(
        "\n**Overall: {}**\n",
        if ok_all {
            "IDENTICAL / EXACT — skipping cold tiles never changed a \
             token, the bound is sound, and the skip-enabled head passes \
             the paper's GoF protocol"
        } else {
            "MISMATCH — see rows above"
        }
    ));
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn certificate_is_clean() {
        let md = subvocab_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        assert!(!md.contains("REJECTED"), "{md}");
        assert!(md.contains("sim-subvocab"));
        assert!(md.matches("###").count() >= 4, "{md}");
    }

    #[test]
    fn mirror_leg_is_stable_and_additive() {
        let a = mirror_run_subvocab();
        let b = mirror_run_subvocab();
        assert_eq!(a.trace.digest(), b.trace.digest());
        // One subvocab event per decode step on top of the trace-identity
        // mirror run's lifecycle stream.
        let base = super::super::trace_identity::mirror_run();
        let steps = a
            .metrics
            .counters
            .get("subvocab_steps")
            .copied()
            .unwrap_or(0);
        assert!(steps > 0);
        assert_eq!(a.trace.total(), base.trace.total() + steps);
        assert_ne!(a.trace.digest(), base.trace.digest());
        // Token streams are untouched by the event model: same generated
        // counts as the base mirror.
        assert_eq!(
            a.metrics.tokens_generated,
            base.metrics.tokens_generated
        );
    }
}
