//! `repro router-identity` — the multi-replica router's exactness and
//! balance certificate (DESIGN.md §13).
//!
//! CPU-only by design: it drives [`Router<SimReplica>`], where everything
//! above model execution is real (real KV manager + radix cache, real
//! stream event queues, the same pure dispatch function `Router<Engine>`
//! uses) and tokens come from the deterministic sim formula.  Claims
//! certified:
//!
//! 1. **1-replica identity** — a 1-replica router is the bare replica:
//!    identical completion order, scheduling clock, weighted time, and
//!    prefill/cache token accounting under every dispatch policy.  (Token
//!    *values* are placement-invariant in the sim by construction; the
//!    scheduling trajectory is the quantity the router could perturb, so
//!    that is what the table compares.  The artifact-gated
//!    `rust/tests/router.rs` suite asserts the byte-level token identity
//!    on `Router<Engine>` when a toolbox is present.)
//! 2. **N-replica replay stability** — rerunning the same submission
//!    sequence reproduces every placement decision and every token
//!    stream bit-for-bit.
//! 3. **Abort balance** — randomized abort schedules leak zero KV blocks
//!    and zero prefix-cache refs, and every handle's event queue drains
//!    to a terminal event.
//! 4. **Affinity wins** — on a session workload, prefix-affinity
//!    dispatch achieves a strictly higher aggregate prefix hit rate than
//!    least-loaded, without starving any replica.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::{Request, RequestHandle, SamplingParams};
use crate::router::{
    sim_router, DispatchPolicy, EngineBackend, Router, SimReplica,
    SimReplicaConfig,
};
use crate::testutil::Gen;

/// One submission wave: (id, prompt, max_new_tokens).
pub(crate) type Wave = Vec<(u64, Vec<i32>, usize)>;

/// Session workload over shared system prompts, all-integer-deterministic
/// (mirrored by `python/tests/sim_router_bench.py`): `sessions` multi-turn
/// streams, each opening with one of `num_sys` 32-token system prompts and
/// growing by a 16-token turn chunk per wave.
///
/// Within each wave the sessions appear in rotated order
/// `(turn + k) % sessions` (ids are still derived from the session): with
/// a fixed order and drained waves, least-loaded's deterministic
/// tiebreaks send every session to the same replica every turn —
/// accidental perfect affinity — and section 4's comparison would
/// measure nothing.  Rotation models the arrival jitter any open-loop
/// trace has.
pub(crate) fn session_waves(sessions: u64, turns: usize, num_sys: u64) -> Vec<Wave> {
    let sys_prompt = |s: u64| -> Vec<i32> {
        (0..32).map(|j| ((s * 97 + j * 13 + 5) % 2048) as i32).collect()
    };
    (0..turns)
        .map(|turn| {
            (0..sessions)
                .map(|k| {
                    let session = (turn as u64 + k) % sessions;
                    let mut p = sys_prompt(session % num_sys);
                    for t in 0..=turn as u64 {
                        p.extend((0..16u64).map(|j| {
                            ((session * 59 + t * 31 + j * 7 + 11) % 2048) as i32
                        }));
                    }
                    (turn as u64 * sessions + session, p, 4usize)
                })
                .collect()
        })
        .collect()
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request::new(
        id,
        prompt,
        SamplingParams { max_new_tokens: max_new, ..Default::default() },
    )
}

/// Everything one run observes — the comparison surface of every section.
#[derive(Default, PartialEq)]
struct RunOut {
    tokens: BTreeMap<u64, Vec<i32>>,
    owners: BTreeMap<u64, usize>,
    completion_order: Vec<u64>,
    clock: u64,
    wtime: u64,
    prefill_tokens: u64,
    cached_tokens: u64,
    leaked: usize,
    dangling_refs: usize,
    events_ok: bool,
    /// Completed requests per replica (starvation check).
    per_replica: Vec<u64>,
}

/// Drive waves through a bare replica — no router anywhere in the call
/// path — recording the same observables as [`drive`].  The section-1
/// baseline: a 1-replica router must be indistinguishable from this.
fn drive_bare(e: &mut SimReplica, waves: &[Wave]) -> RunOut {
    let mut out = RunOut::default();
    let mut handles: Vec<RequestHandle> = Vec::new();
    for wave in waves {
        for (id, prompt, max_new) in wave {
            handles.push(e.submit(req(*id, prompt.clone(), *max_new)).expect("submit"));
            out.owners.insert(*id, 0);
        }
        let mut idle = 0;
        while e.pending() > 0 {
            let step = e.step().expect("sim step");
            if step.is_empty() {
                idle += 1;
                if idle > 8 {
                    if let Some(c) = e.reject_unschedulable() {
                        out.tokens.insert(c.id, c.tokens.clone());
                        out.completion_order.push(c.id);
                        idle = 0;
                        continue;
                    }
                }
                assert!(idle < 64, "router-identity sim livelock");
            } else {
                idle = 0;
            }
            for c in step {
                out.tokens.insert(c.id, c.tokens.clone());
                out.completion_order.push(c.id);
            }
        }
    }
    out.clock = e.clock();
    out.leaked = e.kv_unaccounted_blocks();
    out.dangling_refs = e.prefix_attached_refs();
    out.events_ok = handles.iter().all(|h| {
        let evs = h.drain();
        let terminal = evs.last().map(|e| e.finish.is_some());
        h.is_finished() && terminal == Some(true) && h.try_next().is_none()
    });
    out.wtime = e.wtime();
    out.prefill_tokens = e.metrics.prefill_tokens;
    out.cached_tokens = e.metrics.cached_prefill_tokens;
    out.per_replica.push(e.metrics.requests_completed);
    out
}

/// Drive waves through a router, aborting `(wave, id)` entries right
/// after their wave is submitted, and drain to quiescence.
fn drive(
    r: &mut Router<SimReplica>,
    waves: &[Wave],
    aborts: &[(usize, u64)],
) -> RunOut {
    let mut out = RunOut::default();
    let mut handles: Vec<RequestHandle> = Vec::new();
    for (w, wave) in waves.iter().enumerate() {
        for (id, prompt, max_new) in wave {
            handles.push(r.submit(req(*id, prompt.clone(), *max_new)).expect("submit"));
            out.owners.insert(*id, r.owner_of(*id).expect("owned"));
        }
        for &(_, id) in aborts.iter().filter(|&&(aw, _)| aw == w) {
            if r.owner_of(id).is_some() {
                let c = r.abort(id).expect("abort live request");
                out.tokens.insert(c.id, c.tokens.clone());
                out.completion_order.push(c.id);
            }
        }
        let mut idle = 0;
        while r.pending() > 0 {
            let step = r.step().expect("sim step");
            if step.is_empty() {
                idle += 1;
                if idle > 8 {
                    if let Some(c) = r.reject_unschedulable() {
                        out.tokens.insert(c.id, c.tokens.clone());
                        out.completion_order.push(c.id);
                        idle = 0;
                        continue;
                    }
                }
                assert!(idle < 64, "router-identity sim livelock");
            } else {
                idle = 0;
            }
            for c in step {
                out.tokens.insert(c.id, c.tokens.clone());
                out.completion_order.push(c.id);
            }
        }
    }
    out.clock = r.clock();
    out.leaked = r.kv_unaccounted_blocks();
    out.dangling_refs = r.prefix_attached_refs();
    out.events_ok = handles.iter().all(|h| {
        let evs = h.drain();
        let terminal = evs.last().map(|e| e.finish.is_some());
        // Finished either way; a fully-drained queue must end terminal.
        h.is_finished() && terminal == Some(true) && h.try_next().is_none()
    });
    for e in r.replicas() {
        out.wtime += e.wtime();
        out.prefill_tokens += e.metrics.prefill_tokens;
        out.cached_tokens += e.metrics.cached_prefill_tokens;
        out.per_replica.push(e.metrics.requests_completed);
    }
    out
}

pub fn router_identity() -> Result<String> {
    let cfg = SimReplicaConfig::default();
    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut md = String::from(
        "## router-identity — multi-replica router exactness certificate \
         (SimReplica backend: real KV/radix accounting + real event \
         queues, deterministic tokens)\n",
    );

    // 1. A 1-replica router is the bare replica, under every policy.
    md.push_str(
        "\n### 1-replica identity (router vs bare replica)\n\n\
         | policy | completions | clock | weighted time | cached/prefill \
         tokens | verdict |\n|---|---|---|---|---|---|\n",
    );
    let waves = session_waves(6, 3, 4);
    let bare = drive_bare(&mut SimReplica::new(cfg), &waves);
    let mut ok_all = true;
    for policy in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::PrefixAffinity,
    ] {
        let mut r = sim_router(1, policy, cfg);
        let out = drive(&mut r, &waves, &[]);
        let ok = out.completion_order == bare.completion_order
            && out.tokens == bare.tokens
            && out.clock == bare.clock
            && out.wtime == bare.wtime
            && out.prefill_tokens == bare.prefill_tokens
            && out.cached_tokens == bare.cached_tokens
            && out.owners.values().all(|&o| o == 0);
        ok_all &= ok;
        md.push_str(&format!(
            "| {policy} | {} | {} | {} | {}/{} | {} |\n",
            out.completion_order.len(),
            out.clock,
            out.wtime,
            out.cached_tokens,
            out.prefill_tokens,
            verdict(ok),
        ));
    }

    // 2. N-replica replay stability: same submissions => same placements
    // and streams, for every policy at 3 replicas.
    md.push_str(
        "\n### Replay stability (3 replicas, run twice)\n\n\
         | policy | requests | placements equal | streams equal | \
         verdict |\n|---|---|---|---|---|\n",
    );
    for policy in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::PrefixAffinity,
    ] {
        let a = drive(&mut sim_router(3, policy, cfg), &waves, &[]);
        let b = drive(&mut sim_router(3, policy, cfg), &waves, &[]);
        let placements = a.owners == b.owners;
        let streams = a.tokens == b.tokens && a.completion_order == b.completion_order;
        ok_all &= placements && streams;
        md.push_str(&format!(
            "| {policy} | {} | {} | {} | {} |\n",
            a.owners.len(),
            placements,
            streams,
            verdict(placements && streams),
        ));
    }

    // 3. Randomized abort schedules: zero leaks, drained event queues.
    md.push_str(
        "\n### Abort balance (randomized schedules, 2 replicas, \
         prefix-affinity)\n\n\
         | case | aborts | leaked blocks | dangling refs | events drained \
         | verdict |\n|---|---|---|---|---|---|\n",
    );
    for case in 0..6u32 {
        let mut g = Gen::new(0x40F7E4, case);
        let n_aborts = g.usize_in(2, 8);
        let aborts: Vec<(usize, u64)> = (0..n_aborts)
            .map(|_| (g.usize_in(0, 2), g.usize_in(0, 17) as u64))
            .collect();
        let mut r = sim_router(2, DispatchPolicy::PrefixAffinity, cfg);
        let out = drive(&mut r, &waves, &aborts);
        let ok = out.leaked == 0 && out.dangling_refs == 0 && out.events_ok;
        ok_all &= ok;
        md.push_str(&format!(
            "| {case} (seed 0x40F7E4) | {} | {} | {} | {} | {} |\n",
            n_aborts,
            out.leaked,
            out.dangling_refs,
            out.events_ok,
            if ok { "BALANCED" } else { "MISMATCH: leak" },
        ));
    }

    // 4. Affinity beats least-loaded on hit rate without starvation.
    md.push_str(
        "\n### Prefix-affinity vs least-loaded (session workload, 2 \
         replicas)\n\n\
         | policy | hit rate | per-replica completions | verdict \
         |\n|---|---|---|---|\n",
    );
    let waves_big = session_waves(8, 3, 4);
    let aff = drive(
        &mut sim_router(2, DispatchPolicy::PrefixAffinity, cfg),
        &waves_big,
        &[],
    );
    let ll = drive(
        &mut sim_router(2, DispatchPolicy::LeastLoaded, cfg),
        &waves_big,
        &[],
    );
    let rate = |o: &RunOut| o.cached_tokens as f64 / o.prefill_tokens as f64;
    let no_starve = aff.per_replica.iter().all(|&c| c > 0);
    let wins = rate(&aff) > rate(&ll) && no_starve;
    ok_all &= wins;
    md.push_str(&format!(
        "| prefix-affinity | {:.4} | {:?} | {} |\n| least-loaded | {:.4} | {:?} | baseline |\n",
        rate(&aff),
        aff.per_replica,
        if wins { "OK" } else { "MISMATCH: affinity did not win" },
        rate(&ll),
        ll.per_replica,
    ));

    md.push_str(&format!(
        "\n**Overall: {}**\n",
        if ok_all {
            "IDENTICAL / BALANCED — router preserves the single-engine \
             contract and affinity routing pays for itself"
        } else {
            "MISMATCH — see rows above"
        }
    ));
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn certificate_is_clean() {
        let md = router_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        assert!(md.contains("IDENTICAL"));
        assert!(md.contains("BALANCED"));
        // Four sections render tables.
        assert!(md.matches("###").count() >= 4, "{md}");
    }

    #[test]
    fn session_waves_are_deterministic_and_grow() {
        let a = session_waves(4, 2, 2);
        let b = session_waves(4, 2, 2);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].len(), 4);
        // Waves are rotated, so look sessions up by id: session s has id
        // `turn * 4 + s` in wave `turn`.  Turn 1 prompts strictly extend
        // turn 0 prompts per session.
        let by_id = |wave: &Wave, id: u64| -> Vec<i32> {
            wave.iter().find(|(i, _, _)| *i == id).expect("id present").1.clone()
        };
        for s in 0..4u64 {
            let p0 = by_id(&a[0], s);
            let p1 = by_id(&a[1], 4 + s);
            assert!(p1.starts_with(&p0));
            assert!(p1.len() > p0.len());
        }
        assert_eq!(a[1][3], b[1][3]);
    }
}
