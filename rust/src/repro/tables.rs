//! Markdown generators for the simulator-backed tables and figures.

use crate::gpusim::{
    interconnect, iomodel, kernelchain, roofline, specs, tpot, Method, Workload,
};

const BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

fn header(cols: &[&str]) -> String {
    let mut s = format!("| {} |\n", cols.join(" | "));
    s.push_str(&format!("|{}\n", "---|".repeat(cols.len())));
    s
}

/// §3.3 IO model: predicted speedups + the 1+2B/D approximation.
pub fn io_model() -> String {
    let mut md = String::from(
        "## IO cost model (paper §3.3)\n\nPredicted speedup M_baseline/M_fused \
         and the 1+2B/D approximation.\n\n",
    );
    md.push_str(&header(&["config", "B", "exact", "approx 1+2B/D"]));
    for (name, w_of) in [
        ("D=4096 V=152k", Workload::small as fn(usize) -> Workload),
        ("D=8192 V=128k", Workload::large as fn(usize) -> Workload),
    ] {
        for b in BATCHES {
            let w = w_of(b);
            md.push_str(&format!(
                "| {name} | {b} | {:.4} | {:.4} |\n",
                iomodel::predicted_speedup(w),
                iomodel::predicted_speedup_approx(w),
            ));
        }
    }
    md
}

/// Table 1: sampling share of kernel time on B200 (D=4096, V=152k).
pub fn table1() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Table 1 — sampling % of kernel time (B200, D=4096 V=151936)\n\n",
    );
    md.push_str(&header(&[
        "B",
        "Flash matmul%", "Flash sampl.%",
        "Multinomial matmul%", "Multinomial sampl.%",
        "FI2 matmul%", "FI2 sampl.%",
    ]));
    for b in [1usize, 16, 64, 256] {
        let w = Workload::small(b);
        let mut row = format!("| {b} |");
        for m in [Method::FlashSampling, Method::Multinomial, Method::Fi2] {
            let c = kernelchain::chain(gpu, m, w, false);
            let f = c.sampling_fraction_kernel_time();
            row.push_str(&format!(" {:.1} | {:.1} |", (1.0 - f) * 100.0, f * 100.0));
        }
        md.push_str(&row);
        md.push('\n');
    }
    md
}

/// Tables 4/5: FlashSampling speedup vs the three baselines on 4 GPUs.
pub fn speedup_table(
    w_of: fn(usize) -> Workload,
    title: &str,
    d: usize,
    v: usize,
) -> String {
    let mut md = format!(
        "## {title} — FlashSampling relative speedup (D={d}, V={v})\n\n\
         Values > 1: FlashSampling faster.\n\n"
    );
    let mut cols = vec!["B".to_string()];
    for base in Method::BASELINES {
        for gpu in &specs::DATACENTER {
            cols.push(format!("{} {}", base.name(), gpu.name));
        }
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    md.push_str(&header(&cols_ref));
    for b in BATCHES {
        let mut row = format!("| {b} |");
        for base in Method::BASELINES {
            for gpu in &specs::DATACENTER {
                row.push_str(&format!(
                    " {:.2} |",
                    kernelchain::speedup(gpu, base, w_of(b))
                ));
            }
        }
        md.push_str(&row);
        md.push('\n');
    }
    md
}

/// Figure 2: relative performance on B200 (speedup series for plotting).
pub fn fig2() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Figure 2 — relative speedup on B200 (D=4096, V=151936)\n\n",
    );
    md.push_str(&header(&["B", "vs Multinomial", "vs FI1", "vs FI2"]));
    for b in BATCHES {
        let w = Workload::small(b);
        md.push_str(&format!(
            "| {b} | {:.2} | {:.2} | {:.2} |\n",
            kernelchain::speedup(gpu, Method::Multinomial, w),
            kernelchain::speedup(gpu, Method::Fi1, w),
            kernelchain::speedup(gpu, Method::Fi2, w),
        ));
    }
    md
}

/// Table 6: multi-GPU runtime (µs) at TP∈{1,2,4,8} (D=8192, V=128k).
pub fn table6() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Table 6 — multi-GPU kernel runtime (µs, B200, D=8192 V=128256)\n\n",
    );
    md.push_str(&header(&["B", "Method", "TP=1", "TP=2", "TP=4", "TP=8"]));
    for b in [16usize, 64, 256] {
        let w = Workload::large(b);
        for m in Method::ALL {
            let mut row = format!("| {b} | {} |", m.name());
            for tp in [1usize, 2, 4, 8] {
                row.push_str(&format!(
                    " {:.1} |",
                    interconnect::tp_runtime(gpu, m, w, tp) * 1e6
                ));
            }
            md.push_str(&row);
            md.push('\n');
        }
    }
    md
}

/// Figure 3: same data as Table 6 plus the ideal-scaling line.
pub fn fig3() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Figure 3 — TP scaling vs ideal (µs, B200, D=8192 V=128256)\n\n",
    );
    md.push_str(&header(&["B", "TP", "Flash", "Flash ideal", "FI1", "FI2", "Multinomial"]));
    for b in [16usize, 64, 256] {
        let w = Workload::large(b);
        for tp in [1usize, 2, 4, 8] {
            md.push_str(&format!(
                "| {b} | {tp} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |\n",
                interconnect::tp_runtime(gpu, Method::FlashSampling, w, tp) * 1e6,
                interconnect::ideal_runtime(gpu, Method::FlashSampling, w, tp) * 1e6,
                interconnect::tp_runtime(gpu, Method::Fi1, w, tp) * 1e6,
                interconnect::tp_runtime(gpu, Method::Fi2, w, tp) * 1e6,
                interconnect::tp_runtime(gpu, Method::Multinomial, w, tp) * 1e6,
            ));
        }
    }
    md
}

/// Figure 4: sampling vs matmul runtime decomposition (RTX3090 profile).
pub fn fig4() -> String {
    let gpu = &specs::RTX3090;
    let mut md = String::from(
        "## Figure 4 — sampling (left) and matmul (right) runtime, µs \
         (RTX3090 profile, D=4096 V=151936)\n\n",
    );
    md.push_str(&header(&[
        "B",
        "Flash sampl.", "Mult sampl.", "FI1 sampl.", "FI2 sampl.",
        "Flash matmul", "cuBLAS matmul",
    ]));
    for b in BATCHES {
        let w = Workload::small(b);
        let f = kernelchain::chain(gpu, Method::FlashSampling, w, false);
        let m = kernelchain::chain(gpu, Method::Multinomial, w, false);
        let f1 = kernelchain::chain(gpu, Method::Fi1, w, false);
        let f2 = kernelchain::chain(gpu, Method::Fi2, w, false);
        md.push_str(&format!(
            "| {b} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |\n",
            f.sampling_time() * 1e6,
            m.sampling_time() * 1e6,
            f1.sampling_time() * 1e6,
            f2.sampling_time() * 1e6,
            f.matmul_time() * 1e6,
            m.matmul_time() * 1e6,
        ));
    }
    md
}

/// Table 7: absolute TPOT (ms) baseline vs FlashSampling.
pub fn table7() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Table 7 — modeled median TPOT (ms) on B200\n\n",
    );
    let mut cols = vec!["B".to_string()];
    for m in tpot::PAPER_MODELS {
        cols.push(format!("{} base", m.name));
        cols.push(format!("{} Flash", m.name));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    md.push_str(&header(&cols_ref));
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut row = format!("| {b} |");
        for m in tpot::PAPER_MODELS {
            row.push_str(&format!(
                " {:.2} | {:.2} |",
                m.tpot(gpu, b, Method::Fi1) * 1e3,
                m.tpot(gpu, b, Method::FlashSampling) * 1e3,
            ));
        }
        md.push_str(&row);
        md.push('\n');
    }
    md
}

/// Table 8: TPOT reduction %.
pub fn table8() -> String {
    let gpu = &specs::B200;
    let mut md = String::from("## Table 8 — modeled TPOT reduction (%)\n\n");
    let mut cols = vec!["B".to_string()];
    for m in tpot::PAPER_MODELS {
        cols.push(m.name.to_string());
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    md.push_str(&header(&cols_ref));
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut row = format!("| {b} |");
        for m in tpot::PAPER_MODELS {
            row.push_str(&format!(" {:.1} |", m.tpot_reduction(gpu, b) * 100.0));
        }
        md.push_str(&row);
        md.push('\n');
    }
    md
}

/// Figure 5: TPOT vs concurrency series (same data as Tables 7/8).
pub fn fig5() -> String {
    let mut md = String::from(
        "## Figure 5 — TPOT vs concurrency (B200), baseline vs FlashSampling\n\n",
    );
    md.push_str(&table7());
    md.push_str("\n(see table8.md for the reduction percentages)\n");
    md
}

/// Table 9: logits-store ablation — predicted vs modeled-measured overhead.
pub fn table9() -> String {
    let mut md = String::from(
        "## Table 9 — logits-store ablation: predicted 2B/D vs modeled (%)\n\n",
    );
    md.push_str(&header(&[
        "B",
        "D=8192 predicted", "D=8192 modeled",
        "D=4096 predicted", "D=4096 modeled",
    ]));
    let gpu = &specs::B200;
    for b in [1usize, 4, 16, 64, 128, 256] {
        let mut vals = Vec::new();
        for w in [Workload::large(b), Workload::small(b)] {
            let pred = iomodel::logits_store_overhead_predicted(w) * 100.0;
            let base = kernelchain::chain(gpu, Method::FlashSampling, w, false).total();
            let stored = kernelchain::chain(gpu, Method::FlashSampling, w, true).total();
            let meas = (stored / base - 1.0) * 100.0;
            vals.push((pred, meas));
        }
        md.push_str(&format!(
            "| {b} | {:.2} | {:.2} | {:.2} | {:.2} |\n",
            vals[0].0, vals[0].1, vals[1].0, vals[1].1
        ));
    }
    md
}

/// Figure 6: roofline + bandwidth utilization on B200.
pub fn fig6() -> String {
    let gpu = &specs::B200;
    let mut md = String::from(
        "## Figure 6 — roofline (B200, D=4096 V=151936)\n\n",
    );
    md.push_str(&header(&[
        "B", "method", "AI (flops/byte)", "achieved TFLOP/s",
        "roofline bound TFLOP/s", "BW utilization",
    ]));
    for m in Method::ALL {
        for p in roofline::sweep(gpu, m, Workload::small, &BATCHES) {
            md.push_str(&format!(
                "| {} | {} | {:.1} | {:.1} | {:.1} | {:.2} |\n",
                p.batch,
                m.name(),
                p.intensity,
                p.achieved_flops / 1e12,
                roofline::roofline_bound(gpu, p.intensity) / 1e12,
                p.bw_utilization,
            ));
        }
    }
    md
}

#[cfg(test)]
mod tests {
    #[test]
    fn tables_are_nonempty_markdown() {
        for md in [
            super::io_model(),
            super::table1(),
            super::table6(),
            super::table7(),
            super::table8(),
            super::table9(),
            super::fig2(),
            super::fig3(),
            super::fig4(),
            super::fig6(),
        ] {
            assert!(md.lines().count() > 5);
            assert!(md.contains("|"));
        }
    }

    #[test]
    fn table4_has_all_gpu_columns() {
        let md = super::speedup_table(
            crate::gpusim::Workload::small,
            "Table 4",
            4096,
            151_936,
        );
        for gpu in ["H100", "H200", "B200", "B300"] {
            assert!(md.contains(gpu), "missing {gpu}");
        }
    }
}
