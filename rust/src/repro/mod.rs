//! Experiment reproduction harness: one generator per paper table/figure.
//!
//! `run(id, out_dir)` regenerates the table/figure data as markdown (and
//! CSV) under `out_dir` — the DESIGN.md §5 experiment index maps ids to
//! paper artifacts.  Simulator-backed experiments (tables 1/4/5/6/7/8/9,
//! figures 2/3/4/5/6) use `gpusim`; statistical experiments (`chisq`,
//! `hetero-chisq`, `specdec-chisq`, `e2e-quality`) run *real* sampling
//! through the native samplers and, when artifacts are present, the
//! serving engine.

pub mod profile_identity;
pub mod quality;
pub mod router_identity;
pub mod subvocab_identity;
pub mod tables;
pub mod trace_identity;

use anyhow::Result;
use std::path::Path;

/// All experiment ids, in paper order.
pub const ALL: [&str; 13] = [
    "io-model", "table1", "table4", "table5", "table6", "table7", "table8",
    "table9", "fig2", "fig3", "fig4", "fig5", "fig6",
];

/// Statistical experiments (run real sampling; `e2e-quality` needs
/// artifacts and a few minutes, the rest — including the prefix-cache
/// on/off identity check, the streaming-front-end identity/abort
/// certificate, the chunked-prefill/swap-tier replay-identity
/// certificate, the multi-replica router identity/balance certificate,
/// the flight-recorder trace-vs-metrics certificate, the
/// modeled-time profiler conservation certificate, and the certified
/// sub-vocabulary decode certificate — are fast and deterministic, so CI
/// runs them as a smoke gate after `cargo test`).
pub const STATS: [&str; 11] = [
    "chisq",
    "hetero-chisq",
    "specdec-chisq",
    "prefix-identity",
    "stream-identity",
    "chunk-identity",
    "router-identity",
    "trace-identity",
    "profile-identity",
    "subvocab-identity",
    "e2e-quality",
];

/// Regenerate one experiment into `out_dir`; returns the markdown.
pub fn run(id: &str, out_dir: &Path) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let md = match id {
        "io-model" => tables::io_model(),
        "table1" => tables::table1(),
        "table4" => tables::speedup_table(crate::gpusim::Workload::small, "Table 4", 4096, 151_936),
        "table5" => tables::speedup_table(crate::gpusim::Workload::large, "Table 5", 8192, 128_256),
        "table6" => tables::table6(),
        "table7" => tables::table7(),
        "table8" => tables::table8(),
        "table9" => tables::table9(),
        "fig2" => tables::fig2(),
        "fig3" => tables::fig3(),
        "fig4" => tables::fig4(),
        "fig5" => tables::fig5(),
        "fig6" => tables::fig6(),
        "chisq" => quality::chisq()?,
        "hetero-chisq" => quality::hetero_chisq()?,
        "specdec-chisq" => quality::specdec_chisq()?,
        "prefix-identity" => quality::prefix_identity()?,
        "stream-identity" => quality::stream_identity()?,
        "chunk-identity" => quality::chunk_identity()?,
        "router-identity" => router_identity::router_identity()?,
        "trace-identity" => trace_identity::trace_identity()?,
        "profile-identity" => profile_identity::profile_identity()?,
        "subvocab-identity" => subvocab_identity::subvocab_identity()?,
        "e2e-quality" => quality::e2e_quality(None)?,
        other => anyhow::bail!("unknown experiment id '{other}'"),
    };
    std::fs::write(out_dir.join(format!("{id}.md")), &md)?;
    std::fs::write(out_dir.join(format!("{id}.csv")), markdown_to_csv(&md))?;
    Ok(md)
}

/// Extract the first markdown table of a report as CSV (plot-friendly).
pub fn markdown_to_csv(md: &str) -> String {
    let mut out = String::new();
    for line in md.lines() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        if t.chars().all(|c| matches!(c, '|' | '-' | ' ')) {
            continue; // separator row
        }
        let cells: Vec<&str> = t
            .trim_matches('|')
            .split('|')
            .map(|c| c.trim())
            .collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

/// Run every simulator-backed experiment (the `repro all` target).
pub fn run_all(out_dir: &Path) -> Result<()> {
    for id in ALL {
        let md = run(id, out_dir)?;
        println!("=== {id} ===\n{md}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_simulated_experiment_renders() {
        let dir = std::env::temp_dir().join("fs_repro_test");
        for id in ALL {
            let md = run(id, &dir).unwrap();
            assert!(md.contains('|'), "{id} produced no table");
            assert!(dir.join(format!("{id}.md")).exists());
        }
    }

    #[test]
    fn csv_extraction() {
        let md = "# t\n| a | b |\n|---|---|\n| 1 | 2 |\n";
        assert_eq!(markdown_to_csv(md), "a,b\n1,2\n");
    }

    #[test]
    fn csv_files_written() {
        let dir = std::env::temp_dir().join("fs_repro_csv");
        run("table1", &dir).unwrap();
        let csv = std::fs::read_to_string(dir.join("table1.csv")).unwrap();
        assert!(csv.lines().count() > 3);
    }

    #[test]
    fn unknown_id_rejected() {
        let dir = std::env::temp_dir().join("fs_repro_test2");
        assert!(run("table99", &dir).is_err());
    }
}
