//! `repro trace-identity` — the flight recorder's replay-identity and
//! trace-vs-metrics certificate (DESIGN.md §14).
//!
//! The recorder is only worth its one-branch cost if the event log is
//! *trustworthy*: deterministic enough to diff across runs, and complete
//! enough that the serving counters can be re-derived from it.  Claims
//! certified, all CPU-only:
//!
//! 1. **Scheduler replay identity** — the engine-mirroring scheduler sim
//!    ([`crate::testutil::schedsim`]) at `Full` level, over a scenario
//!    matrix exercising chunked prefill, swap-tier preemption and
//!    revival, speculative decode, aging promotion, forced aborts, and
//!    submit-time rejection: rerunning each script reproduces a
//!    bit-identical FNV-1a digest of the canonical JSONL stream.
//! 2. **Trace ⇔ metrics** — on every scenario, the
//!    [`DerivedCounters`] folded from the event stream equal the
//!    [`ServingMetrics`] the sim bumps at the engine's own call sites,
//!    field for field (tokens, prefill/cached tokens, chunk windows,
//!    swap blocks, spec drafted/accepted, preemptions vs
//!    `preempted + swapped_out_seqs`, finishes vs `requests_completed`),
//!    and every submitted request ends in exactly one `finish` or one
//!    submit-time `reject`.
//! 3. **Router replay identity** — `Router<SimReplica>` (real KV/radix
//!    accounting) at 2 replicas under prefix-affinity with mid-wave
//!    aborts: per-replica digests replay bit-identically, per-replica
//!    derived counters match that replica's metrics, and dispatch
//!    events account for every submission exactly once.
//! 4. **Engine A/B (when artifacts exist)** — the real engine at `Full`
//!    level replays to the same digest with balanced counters; skipped
//!    gracefully on artifact-less boxes (CI's smoke gate still runs
//!    legs 1–3 and 5).
//! 5. **Python mirror anchor** — a bare `SimReplica` run at `Lifecycle`
//!    whose digest is exported as a table row;
//!    `python/tests/sim_trace_bench.py` re-derives the same digest from
//!    an independent reimplementation of the canonical serialization
//!    and asserts bitwise equality against this report's CSV.
//!
//! [`DerivedCounters`]: crate::trace::DerivedCounters
//! [`ServingMetrics`]: crate::metrics::ServingMetrics

use anyhow::Result;

use crate::coordinator::{Engine, EngineConfig, Request, SamplingParams};
use crate::metrics::ServingMetrics;
use crate::router::{
    sim_router, DispatchPolicy, EngineBackend, Router, SimReplica,
    SimReplicaConfig,
};
use crate::testutil::schedsim::{Sim, SimConfig, SimRequest};
use crate::trace::{DerivedCounters, TraceLevel};

use super::router_identity::{session_waves, Wave};

fn sreq(id: u64, prompt_len: usize, max_new_tokens: usize) -> SimRequest {
    SimRequest { id, prompt_len, max_new_tokens, arrival_step: 0 }
}

/// The full trace ⇔ metrics contract over the scheduler sim: each derived
/// counter against the metric bumped at the same engine call site, plus
/// conservation — every submitted request ends in exactly one `finish` or
/// one submit-time `reject`.
fn sim_balanced(d: &DerivedCounters, m: &ServingMetrics, submitted: u64) -> bool {
    let ctr = |name: &str| m.counters.get(name).copied().unwrap_or(0);
    d.tokens == m.tokens_generated
        && d.prefill_tokens == m.prefill_tokens
        && d.cached_prefill_tokens == m.cached_prefill_tokens
        && d.chunk_windows == m.chunked_prefill_steps
        && d.swap_out_blocks == m.swap_out_blocks
        && d.swap_in_blocks == m.swap_in_blocks
        && d.spec_drafted == ctr("spec_draft_tokens")
        && d.spec_accepted == ctr("spec_accepted_tokens")
        && d.preemptions == ctr("preempted") + ctr("swapped_out_seqs")
        && d.finishes == m.requests_completed
        && d.finishes + d.rejects == submitted
}

/// Scenario matrix for legs 1–2: every subsystem with an emission site.
/// Shared with `repro profile-identity`, which replays the same matrix
/// through the modeled-time profiler.
pub(crate) fn scenarios() -> Vec<(&'static str, SimConfig, Vec<SimRequest>)> {
    let full = |mut cfg: SimConfig| {
        cfg.trace_level = TraceLevel::Full;
        cfg
    };
    let mut chunked = SimConfig::small(256);
    chunked.sched.prefill_chunk_tokens = 16;
    chunked.force_abort = vec![(2, 0)];

    let mut swap = SimConfig::small(256);
    swap.swap_blocks = 64;
    swap.force_preempt = vec![(3, 0), (5, 1)];

    let mut spec = SimConfig::small(256);
    spec.spec_k = 3;

    let reject = SimConfig::small(256);

    let mut combined = SimConfig::small(256);
    combined.sched.prefill_chunk_tokens = 16;
    combined.sched.aging_steps = 4;
    combined.swap_blocks = 64;
    combined.spec_k = 2;
    combined.force_abort = vec![(4, 1)];
    combined.force_preempt = vec![(6, 0), (9, 2), (12, 3)];

    vec![
        (
            "chunked prefill + abort",
            full(chunked),
            (0..4).map(|id| sreq(id, 60, 4)).collect(),
        ),
        (
            "swap preempt + revival",
            full(swap),
            (0..3).map(|id| sreq(id, 20, 12)).collect(),
        ),
        (
            "speculative decode",
            full(spec),
            (0..4).map(|id| sreq(id, 24, 8)).collect(),
        ),
        (
            "submit-time rejection",
            full(reject),
            vec![sreq(0, 100, 3), sreq(1, 24, 4), sreq(2, 24, 4)],
        ),
        (
            "combined (chunk+swap+spec+aging+abort)",
            full(combined),
            (0..5).map(|id| sreq(id, 60, 6)).collect(),
        ),
    ]
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request::new(
        id,
        prompt,
        SamplingParams { max_new_tokens: max_new, ..Default::default() },
    )
}

/// Drive waves through a router (aborting `(wave, id)` entries right
/// after their wave is submitted) and drain each wave to quiescence.
pub(crate) fn drive_router(
    r: &mut Router<SimReplica>,
    waves: &[Wave],
    aborts: &[(usize, u64)],
) {
    for (w, wave) in waves.iter().enumerate() {
        for (id, prompt, max_new) in wave {
            let _ = r.submit(req(*id, prompt.clone(), *max_new)).expect("submit");
        }
        for &(_, id) in aborts.iter().filter(|&&(aw, _)| aw == w) {
            if r.owner_of(id).is_some() {
                let _ = r.abort(id).expect("abort live request");
            }
        }
        let mut idle = 0;
        while r.pending() > 0 {
            let step = r.step().expect("sim step");
            if step.is_empty() {
                idle += 1;
                if idle > 8 && r.reject_unschedulable().is_some() {
                    idle = 0;
                    continue;
                }
                assert!(idle < 64, "trace-identity sim livelock");
            } else {
                idle = 0;
            }
        }
    }
}

/// The per-replica trace ⇔ metrics contract for `SimReplica` (no chunk /
/// swap / spec subsystems there; aborts and rejects still complete).
fn replica_balanced(e: &SimReplica) -> bool {
    let d = e.trace.derived();
    d.tokens == e.metrics.tokens_generated
        && d.prefill_tokens == e.metrics.prefill_tokens
        && d.cached_prefill_tokens == e.metrics.cached_prefill_tokens
        && d.finishes == e.metrics.requests_completed
}

/// Leg 5: the bare-replica run `python/tests/sim_trace_bench.py` mirrors
/// event-for-event.  Keep the workload constants in lockstep with the
/// Python file: 6 closed-loop requests, `prompt_len = 24 + (id % 3) * 8`,
/// `max_new = 3 + (id % 3)`, prefix cache off (pool far larger than the
/// live set), `Lifecycle` level.  `repro profile-identity` profiles this
/// same run so `python/tests/sim_profile_bench.py` can re-derive its
/// digest from the identical event stream.
pub(crate) fn mirror_run() -> SimReplica {
    let cfg = SimReplicaConfig {
        prefix_caching: false,
        trace_level: TraceLevel::Lifecycle,
        ..Default::default()
    };
    let mut e = SimReplica::new(cfg);
    for id in 0..6u64 {
        let plen = 24 + (id as usize % 3) * 8;
        let prompt: Vec<i32> =
            (0..plen).map(|j| ((id * 7 + j as u64) % 97) as i32).collect();
        let _ = e
            .submit(req(id, prompt, 3 + (id as usize % 3)))
            .expect("mirror submit");
    }
    let mut idle = 0;
    while e.pending() > 0 {
        let step = e.step().expect("mirror step");
        if step.is_empty() {
            idle += 1;
            assert!(idle < 64, "mirror leg livelock");
        } else {
            idle = 0;
        }
    }
    e
}

pub fn trace_identity() -> Result<String> {
    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut ok_all = true;
    let mut md = String::from(
        "## trace-identity — flight-recorder replay-identity and \
         trace-vs-metrics certificate (DESIGN.md §14)\n",
    );

    // 1+2. Scheduler sim: digest replay identity and derived == metrics
    // over the scenario matrix.
    md.push_str(
        "\n### Scheduler replay identity + trace ⇔ metrics (engine-mirror \
         sim, Full level, each script run twice)\n\n\
         | scenario | events | digest | replay | trace==metrics | verdict \
         |\n|---|---|---|---|---|---|\n",
    );
    for (name, cfg, reqs) in scenarios() {
        let mut a = Sim::new(cfg.clone());
        a.drive(&reqs);
        let mut b = Sim::new(cfg);
        b.drive(&reqs);
        let replay = a.trace.digest() == b.trace.digest();
        let balanced =
            sim_balanced(a.trace.derived(), &a.metrics, reqs.len() as u64);
        ok_all &= replay && balanced;
        md.push_str(&format!(
            "| {name} | {} | {:#018x} | {replay} | {balanced} | {} |\n",
            a.trace.total(),
            a.trace.digest(),
            verdict(replay && balanced),
        ));
    }

    // 3. Router over SimReplica: per-replica replay identity, per-replica
    // balance, and dispatch conservation.
    md.push_str(
        "\n### Router replay identity (2 replicas, prefix-affinity, \
         mid-wave aborts, run twice)\n\n\
         | replica | events | digest | replay | trace==metrics | verdict \
         |\n|---|---|---|---|---|---|\n",
    );
    let waves = session_waves(6, 3, 4);
    let aborts = [(0usize, 2u64), (1usize, 9u64)];
    let rcfg = SimReplicaConfig {
        trace_level: TraceLevel::Lifecycle,
        ..Default::default()
    };
    let mut ra = sim_router(2, DispatchPolicy::PrefixAffinity, rcfg);
    drive_router(&mut ra, &waves, &aborts);
    let mut rb = sim_router(2, DispatchPolicy::PrefixAffinity, rcfg);
    drive_router(&mut rb, &waves, &aborts);
    let mut dispatches = 0u64;
    for (i, (ea, eb)) in
        ra.replicas().iter().zip(rb.replicas().iter()).enumerate()
    {
        let replay = ea.trace.digest() == eb.trace.digest();
        let balanced = replica_balanced(ea);
        dispatches += ea.trace.derived().dispatches;
        ok_all &= replay && balanced;
        md.push_str(&format!(
            "| {i} | {} | {:#018x} | {replay} | {balanced} | {} |\n",
            ea.trace.total(),
            ea.trace.digest(),
            verdict(replay && balanced),
        ));
    }
    let submitted: u64 = waves.iter().map(|w| w.len() as u64).sum();
    let dispatch_ok = dispatches == submitted;
    ok_all &= dispatch_ok;
    md.push_str(&format!(
        "\nDispatch conservation: {dispatches} dispatch events for \
         {submitted} submissions — {}\n",
        verdict(dispatch_ok)
    ));

    // 4. Engine A/B when artifacts are present.
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let run_engine = || -> Result<(u64, u64, bool)> {
            let mut e = Engine::new(
                &dir,
                EngineConfig {
                    trace_level: TraceLevel::Full,
                    ..Default::default()
                },
            )?;
            for id in 0..8u64 {
                let plen = 24 + (id as usize % 3) * 8;
                let prompt: Vec<i32> = (0..plen)
                    .map(|j| ((id as i32) * 5 + j as i32) % 50 + 1)
                    .collect();
                let _ = e.submit(req(id, prompt, 4 + (id as usize % 2)))?;
            }
            let _ = e.run_to_completion()?;
            let d = e.trace.derived();
            let ctr =
                |name: &str| e.metrics.counters.get(name).copied().unwrap_or(0);
            let balanced = d.tokens == e.metrics.tokens_generated
                && d.prefill_tokens == e.metrics.prefill_tokens
                && d.cached_prefill_tokens == e.metrics.cached_prefill_tokens
                && d.chunk_windows == e.metrics.chunked_prefill_steps
                && d.swap_out_blocks == e.metrics.swap_out_blocks
                && d.swap_in_blocks == e.metrics.swap_in_blocks
                && d.preemptions == ctr("preempted") + ctr("swapped_out_seqs")
                && d.finishes == e.metrics.requests_completed;
            Ok((e.trace.digest(), e.trace.total(), balanced))
        };
        let (da, ta, bal_a) = run_engine()?;
        let (db, _, bal_b) = run_engine()?;
        let ok = da == db && bal_a && bal_b;
        ok_all &= ok;
        md.push_str(&format!(
            "\nEngine A/B (real artifacts, 8 requests, Full level): \
             {ta} events, digest {da:#018x} — replay {} / balanced {} — \
             {}\n",
            da == db,
            bal_a && bal_b,
            verdict(ok)
        ));
    } else {
        md.push_str(
            "\nEngine A/B: skipped (no artifacts; run `make artifacts` for \
             the real-engine digest identity)\n",
        );
    }

    // 5. Python mirror anchor: a digest the cross-language mirror must
    // reproduce bit-for-bit from the CSV of this report.
    md.push_str(
        "\n### Python mirror anchor (python/tests/sim_trace_bench.py)\n\n\
         | leg | requests | events | digest |\n|---|---|---|---|\n",
    );
    let m = mirror_run();
    let mirror_balanced = replica_balanced(&m);
    ok_all &= mirror_balanced;
    md.push_str(&format!(
        "| sim-mirror | 6 | {} | {:#018x} |\n",
        m.trace.total(),
        m.trace.digest(),
    ));
    if !mirror_balanced {
        md.push_str("\n**MISMATCH — mirror leg counters out of balance.**\n");
    }

    md.push_str(&format!(
        "\n**Overall: {}**\n",
        if ok_all {
            "IDENTICAL / BALANCED — the event log replays bit-for-bit and \
             the metrics layer is re-derivable from it"
        } else {
            "MISMATCH — see rows above"
        }
    ));
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn certificate_is_clean() {
        let md = trace_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        assert!(md.contains("IDENTICAL"));
        assert!(md.contains("sim-mirror"));
        assert!(md.matches("###").count() >= 3, "{md}");
    }

    #[test]
    fn scenarios_exercise_every_subsystem() {
        // The matrix must actually open chunk windows, move swap blocks,
        // run spec bursts, and reject a submission — otherwise the
        // balance rows certify nothing.
        let mut windows = 0;
        let mut swaps = 0;
        let mut bursts = 0;
        let mut rejects = 0;
        for (_, cfg, reqs) in scenarios() {
            let mut sim = Sim::new(cfg);
            sim.drive(&reqs);
            let d = sim.trace.derived();
            windows += d.chunk_windows;
            swaps += d.swap_out_blocks;
            bursts += d.spec_drafted;
            rejects += d.rejects;
        }
        assert!(windows > 0, "no chunk windows opened");
        assert!(swaps > 0, "no swap blocks moved");
        assert!(bursts > 0, "no spec drafts planned");
        assert!(rejects > 0, "no submit-time rejection");
    }

    #[test]
    fn mirror_leg_is_stable() {
        let a = mirror_run();
        let b = mirror_run();
        assert_eq!(a.trace.digest(), b.trace.digest());
        assert!(a.trace.total() > 0);
        // Lifecycle events only: 6 submits + 6 prefills + 6 first tokens
        // + 6 finishes + one decode_token per remaining token.
        let extra_tokens: u64 = (0..6u64).map(|id| 2 + id % 3).sum();
        assert_eq!(a.trace.total(), 24 + extra_tokens);
    }
}
