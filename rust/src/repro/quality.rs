//! §4.6 statistical verifications — these run REAL sampling, not the
//! simulator.
//!
//! * `chisq` — the paper's kernel-level protocol: V=512, 10,000 samples,
//!   chi-squared goodness-of-fit against the exact categorical.  Run over
//!   the native Rust Gumbel-Max (pathwise identical to the Pallas kernel —
//!   see tests/integration_runtime.rs) and the grouped/online/distributed
//!   variants, each selected through the `ExactSampler` registry by config
//!   string (DESIGN.md §5).
//! * `e2e_quality` — the paper's end-to-end protocol shape: decode N
//!   prompts with FlashSampling and with the baseline sampler through the
//!   real serving engine, score each completion with a deterministic
//!   checker, and paired-bootstrap the per-prompt outcomes (paper: 89.4% vs
//!   89.6%, p = 0.776 ⇒ consistent with exact sampling).

use anyhow::Result;

use crate::coordinator::{Engine, EngineConfig, Request, SamplingParams};
#[allow(unused_imports)]
use crate::sampling::ExactSampler;
use crate::sampling::{
    build_sampler, multinomial, philox, stats, Key, RowCtx, Transform,
};

const V: usize = 512;
const N_SAMPLES: u32 = 10_000;

fn toy_logits(v: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..v)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

/// Kernel-level chi-squared goodness-of-fit (paper §4.6, V=512, 10k draws).
pub fn chisq() -> Result<String> {
    let logits = toy_logits(V, 42);
    let t = Transform::default();
    let probs = multinomial::probs(&logits, &t);
    let key = Key::new(0x51, 0x52);

    let mut md = String::from(
        "## §4.6 kernel-level verification — chi-squared GoF (V=512, 10k samples)\n\n\
         |sampler | spec | p-value | verdict |\n|---|---|---|---|\n",
    );
    // Every sampler under test is selected through the ExactSampler
    // registry by config string — the experiment definition is pure data.
    let cases: [(&str, &str); 5] = [
        ("FlashSampling (tiled Gumbel-Max, tile_v=64)", "gumbel:tile=64"),
        ("Baseline multinomial (Alg. A.1)", "multinomial"),
        ("Group-Gumbel-Max (Alg. I.2, g=64)", "grouped:group=64"),
        ("Online Group-Gumbel-Max (Alg. I.3, g=64)", "online:group=64"),
        ("Distributed merge (Alg. I.4, 4 shards)", "distributed:ranks=4"),
    ];
    for (name, spec) in cases {
        let sampler = build_sampler(spec)?;
        let mut counts = vec![0u64; V];
        for s in 0..N_SAMPLES {
            let ctx = RowCtx { transform: &t, key, row: 0, step: s };
            let d = sampler
                .sample_row(&logits, ctx)
                .expect("chisq fixture has full support");
            counts[d.index as usize] += 1;
        }
        let p = stats::chi_squared_pvalue(&counts, &probs, N_SAMPLES as u64);
        let verdict = if p > 0.001 { "exact (not rejected)" } else { "REJECTED" };
        md.push_str(&format!("| {name} | `{spec}` | {p:.4} | {verdict} |\n"));
    }
    Ok(md)
}

/// Deterministic per-completion "correctness" checker: a synthetic task
/// whose success probability is identical under any exact sampler (the
/// §4.6 claim is that FlashSampling does not shift task accuracy).
fn score(prompt: &[i32], tokens: &[i32]) -> f64 {
    // "Answer": does the generation contain a token congruent to the
    // prompt checksum mod 7?  P(success) is a property of the sampling
    // distribution only.
    let target = prompt.iter().map(|&t| t as i64).sum::<i64>().rem_euclid(7);
    tokens.iter().any(|&t| (t as i64).rem_euclid(7) == target) as u8 as f64
}

/// End-to-end paired quality comparison through the real engine.
///
/// `artifacts_dir = None` resolves `./artifacts` and skips gracefully (with
/// a note in the output) when artifacts are absent.
pub fn e2e_quality(artifacts_dir: Option<&std::path::Path>) -> Result<String> {
    let dir = artifacts_dir
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"));
    if !dir.join("manifest.json").exists() {
        return Ok("## §4.6 e2e — SKIPPED (run `make artifacts` first)\n".into());
    }
    let n_prompts = 48usize;
    let gen = crate::workload::WorkloadGen::new(7, 1000.0, 2048);
    let mut specs = gen.generate(n_prompts);
    for s in &mut specs {
        s.prompt.truncate(12);
        s.max_new_tokens = 16;
    }

    let mut outcomes = Vec::new();
    for baseline in [false, true] {
        let mut engine = Engine::new(
            &dir,
            EngineConfig { baseline_sampler: baseline, ..Default::default() },
        )?;
        for s in &specs {
            engine.submit(Request {
                id: s.id,
                prompt: s.prompt.clone(),
                params: SamplingParams {
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
            })?;
        }
        let mut done = engine.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        let scores: Vec<f64> = done
            .iter()
            .map(|c| {
                let prompt = &specs[c.id as usize].prompt;
                score(prompt, &c.tokens)
            })
            .collect();
        outcomes.push(scores);
    }

    let acc_flash: f64 = outcomes[0].iter().sum::<f64>() / n_prompts as f64;
    let acc_base: f64 = outcomes[1].iter().sum::<f64>() / n_prompts as f64;
    let p = stats::paired_bootstrap_pvalue(&outcomes[0], &outcomes[1], 5000, 99);
    Ok(format!(
        "## §4.6 end-to-end verification — paired bootstrap over {n_prompts} prompts\n\n\
         | sampler | task accuracy |\n|---|---|\n\
         | FlashSampling (fused decode) | {:.1}% |\n\
         | Baseline (materialized multinomial) | {:.1}% |\n\n\
         Two-sided paired-bootstrap p-value: **{p:.3}** — {}\n",
        acc_flash * 100.0,
        acc_base * 100.0,
        if p > 0.05 {
            "no significant difference (consistent with exact sampling)"
        } else {
            "SIGNIFICANT DIFFERENCE (investigate!)"
        }
    ))
}

#[cfg(test)]
mod tests {
    #[test]
    fn chisq_report_accepts_all_exact_samplers() {
        let md = super::chisq().unwrap();
        assert!(!md.contains("REJECTED"), "{md}");
        assert_eq!(md.matches("exact (not rejected)").count(), 5);
    }
}
