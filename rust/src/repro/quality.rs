//! §4.6 statistical verifications — these run REAL sampling, not the
//! simulator.
//!
//! * `chisq` — the paper's kernel-level protocol: V=512, 10,000 samples,
//!   chi-squared goodness-of-fit against the exact categorical.  Run over
//!   the native Rust Gumbel-Max (pathwise identical to the Pallas kernel —
//!   see tests/integration_runtime.rs) and the grouped/online/distributed
//!   variants, each selected through a typed `SamplerSpec` (DESIGN.md §5).
//! * `hetero-chisq` — the redesign's heterogeneous-batch protocol: one
//!   batch whose rows carry different `SamplingParams` (tau / top-k /
//!   top-p), sampled via `sample_batch_rows`; every row must match its own
//!   target distribution (DESIGN.md §3 per-row contract).
//! * `e2e_quality` — the paper's end-to-end protocol shape: decode N
//!   prompts with FlashSampling and with the baseline sampler through the
//!   real serving engine, score each completion with a deterministic
//!   checker, and paired-bootstrap the per-prompt outcomes (paper: 89.4% vs
//!   89.6%, p = 0.776 ⇒ consistent with exact sampling).

use anyhow::Result;

use crate::coordinator::{Engine, EngineConfig, Request, SamplingParams};
#[allow(unused_imports)]
use crate::sampling::ExactSampler;
use crate::sampling::{
    multinomial, philox, stats, Key, RowCtx, SamplerSpec, Transform,
};

const V: usize = 512;
const N_SAMPLES: u32 = 10_000;

fn toy_logits(v: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..v)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

/// Kernel-level chi-squared goodness-of-fit (paper §4.6, V=512, 10k draws).
pub fn chisq() -> Result<String> {
    let logits = toy_logits(V, 42);
    let t = Transform::default();
    let probs = multinomial::probs(&logits, &t);
    let key = Key::new(0x51, 0x52);

    let mut md = String::from(
        "## §4.6 kernel-level verification — chi-squared GoF (V=512, 10k samples)\n\n\
         |sampler | spec | p-value | verdict |\n|---|---|---|---|\n",
    );
    // Every sampler under test is selected through a typed SamplerSpec —
    // the experiment definition is pure data (Display renders the spec
    // column, so the table shows exactly what was constructed).
    let cases: [(&str, SamplerSpec); 5] = [
        (
            "FlashSampling (tiled Gumbel-Max, tile_v=64)",
            SamplerSpec::Gumbel { tile: Some(64) },
        ),
        ("Baseline multinomial (Alg. A.1)", SamplerSpec::Multinomial),
        ("Group-Gumbel-Max (Alg. I.2, g=64)", SamplerSpec::Grouped { group: 64 }),
        (
            "Online Group-Gumbel-Max (Alg. I.3, g=64)",
            SamplerSpec::Online { group: 64 },
        ),
        (
            "Distributed merge (Alg. I.4, 4 shards)",
            SamplerSpec::Distributed { ranks: 4 },
        ),
    ];
    for (name, spec) in cases {
        let sampler = spec.build()?;
        let mut counts = vec![0u64; V];
        for s in 0..N_SAMPLES {
            let ctx = RowCtx { transform: &t, key, row: 0, step: s };
            let d = sampler
                .sample_row(&logits, ctx)
                .expect("chisq fixture has full support");
            counts[d.index as usize] += 1;
        }
        let p = stats::chi_squared_pvalue(&counts, &probs, N_SAMPLES as u64);
        let verdict = if p > 0.001 { "exact (not rejected)" } else { "REJECTED" };
        md.push_str(&format!("| {name} | `{spec}` | {p:.4} | {verdict} |\n"));
    }
    Ok(md)
}

/// Heterogeneous-batch chi-squared GoF: one batch whose rows carry
/// different `SamplingParams` (temperature, top-k, top-p, and a
/// per-request seed), sampled through the per-row batch entry point
/// (`ExactSampler::sample_batch_rows`).
///
/// The claim under test is the redesign's exactness contract: coalescing
/// rows with different parameters into one batch (what the scheduler now
/// does for mixed-temperature traffic) leaves every row drawing from its
/// OWN target distribution — each row must pass GoF against the
/// distribution implied by its own params.
/// Independent GoF oracle: the target distribution implied by a row's
/// `SamplingParams`, computed directly from probabilities (f64 softmax,
/// sort, top-k count, renormalized-nucleus prefix) — deliberately NOT via
/// `Transform::truncated`, so a keep-set bug in the truncation code would
/// make the chi-squared reject instead of silently matching itself.
fn target_probs(logits: &[f32], params: &SamplingParams) -> Vec<f64> {
    let base = params.transform(logits.len());
    let probs = multinomial::probs(logits, &base);
    if params.top_k.is_none() && params.top_p.is_none() {
        return probs;
    }
    let mut order: Vec<usize> =
        (0..probs.len()).filter(|&i| probs[i] > 0.0).collect();
    order.sort_by(|&a, &b| {
        probs[b]
            .partial_cmp(&probs[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    if let Some(k) = params.top_k {
        order.truncate(k.max(1));
    }
    if let Some(p) = params.top_p {
        // Smallest prefix whose renormalized survivor mass reaches p.  The
        // prefix is taken over the oracle's OWN ordering, but the boundary
        // accumulation deliberately mirrors `Transform::truncated`'s
        // arithmetic (f32 log-normalizer, f64 cumsum of f32 differences):
        // a cum ≈ p knife-edge must not make the oracle keep one more/less
        // token than the sampler and fail an exact sampler's GoF.
        let ys: Vec<f32> =
            order.iter().map(|&i| base.apply(logits[i], i)).collect();
        let z = crate::sampling::log_sum_exp(&ys);
        let mut cum = 0.0f64;
        let mut keep = 0usize;
        for &y in &ys {
            keep += 1;
            cum += ((y - z) as f64).exp();
            if cum >= p as f64 {
                break;
            }
        }
        order.truncate(keep.max(1));
    }
    let mass: f64 = order.iter().map(|&i| probs[i]).sum();
    let mut out = vec![0.0f64; probs.len()];
    for &i in &order {
        out[i] = probs[i] / mass;
    }
    out
}

pub fn hetero_chisq() -> Result<String> {
    let logits = toy_logits(V, 42);
    let key = Key::new(0x61, 0x62);
    // Seven rows, seven parameterizations (mixed tau, with and without
    // top-k/top-p, one per-request seed override).
    let rows: [(&str, SamplingParams); 7] = [
        ("tau=0.5", SamplingParams { temperature: 0.5, ..Default::default() }),
        ("tau=1.0", SamplingParams::default()),
        ("tau=2.0", SamplingParams { temperature: 2.0, ..Default::default() }),
        (
            "tau=1.0 top_k=32",
            SamplingParams { top_k: Some(32), ..Default::default() },
        ),
        (
            "tau=0.7 top_k=64",
            SamplingParams {
                temperature: 0.7,
                top_k: Some(64),
                ..Default::default()
            },
        ),
        (
            "tau=1.5 top_p=0.9",
            SamplingParams {
                temperature: 1.5,
                top_p: Some(0.9),
                ..Default::default()
            },
        ),
        (
            "tau=1.0 seed=0xD00D",
            SamplingParams { seed: Some(0xD00D), ..Default::default() },
        ),
    ];
    // Shared logits per row; per-row transform folds tau + truncation.
    let transforms: Vec<Transform> = rows
        .iter()
        .map(|(_, p)| p.transform(V).truncated(&logits, p.top_k, p.top_p))
        .collect();
    let batch_logits: Vec<f32> = logits.repeat(rows.len());

    let sampler = SamplerSpec::default().build()?;
    let mut counts = vec![vec![0u64; V]; rows.len()];
    for s in 0..N_SAMPLES {
        // Per-row key via SamplingParams::row_key: the seeded row draws
        // from its own Philox key, the rest from the session key.
        let ctxs: Vec<RowCtx<'_>> = transforms
            .iter()
            .enumerate()
            .map(|(b, t)| RowCtx {
                transform: t,
                key: rows[b].1.row_key(key),
                row: b as u32,
                step: s,
            })
            .collect();
        for (b, d) in sampler
            .sample_batch_rows(&batch_logits, V, &ctxs)
            .into_iter()
            .enumerate()
        {
            let d = d.expect("hetero fixture keeps every row live");
            counts[b][d.index as usize] += 1;
        }
    }

    let mut md = String::from(
        "## Heterogeneous-batch verification — per-row chi-squared GoF \
         (one batch, mixed params incl. a per-request seed, V=512, \
         10k samples/row)\n\n\
         | row | params | p-value | verdict |\n|---|---|---|---|\n",
    );
    for (b, (name, params)) in rows.iter().enumerate() {
        // Expected distribution from the independent oracle, not from the
        // transform the sampler itself consumed.
        let probs = target_probs(&logits, params);
        let p = stats::chi_squared_pvalue(&counts[b], &probs, N_SAMPLES as u64);
        let verdict = if p > 0.001 { "exact (not rejected)" } else { "REJECTED" };
        md.push_str(&format!("| {b} | {name} | {p:.4} | {verdict} |\n"));
    }
    Ok(md)
}

/// `specdec-chisq` — speculative decoding's exactness certificate
/// (DESIGN.md §9, the acceptance criterion of the spec-decode subsystem).
///
/// Protocol: fix one context and its target distribution `p` (the
/// **probs-space oracle**: f64 softmax of the target logits — computed
/// independently of the verifier's arithmetic).  Run 10k independent
/// verify rounds (fresh Philox step each), every round drafting K tokens
/// with a drafter and running the full accept/reject recurrence
/// (`specdec::Verifier`), and tally the FIRST emitted token.  Whatever
/// the drafter — one-hot n-gram proposals, a same-head drafter at a
/// different temperature, an independent head — the accept branch and the
/// Gumbel-argmax residual branch must compose to exactly `p`, which the
/// chi-squared GoF checks per drafter.  (Greedy token-for-token identity
/// with the baseline decode path is the companion check, asserted by
/// `tests/specdec.rs`.)
pub fn specdec_chisq() -> Result<String> {
    use crate::specdec::{
        DraftModel, HashModel, LogitModel, NGramDraft, RuntimeDraft, Verifier,
    };
    const VS: usize = 256;
    const K: usize = 2;
    let target = HashModel::new(VS, 3, 0x5DEC);
    // A context with internal repetition so the n-gram drafter proposes.
    let ctx: Vec<i32> = vec![17, 42, 9, 17, 42, 9, 17, 42];
    let t = Transform::default();
    let logits = target.logits(&ctx);
    let oracle = multinomial::probs(&logits, &t); // probs-space oracle
    let verifier = Verifier { key: Key::new(0xD1, 0xD2) };

    let mut md = String::from(
        "## specdec — spec-decode exactness, chi-squared GoF of the first \
         emitted token vs the probs-space oracle (V=256, K=2, 10k verify \
         rounds per drafter)\n\n\
         | drafter | acceptance | p-value | verdict |\n|---|---|---|---|\n",
    );
    let drafters: Vec<(&str, Box<dyn DraftModel>)> = vec![
        ("n-gram suffix (one-hot q)", Box::new(NGramDraft { n: 2, vocab: VS })),
        (
            "runtime draft, same head at tau=2 (partial agreement)",
            Box::new(RuntimeDraft::new(
                HashModel::new(VS, 3, 0x5DEC),
                2.0,
                Key::new(0xD3, 0xD4),
            )),
        ),
        (
            "runtime draft, independent head (mostly rejected)",
            Box::new(RuntimeDraft::new(
                HashModel::new(VS, 3, 0xBEEF),
                1.0,
                Key::new(0xD5, 0xD6),
            )),
        ),
    ];
    for (name, mut drafter) in drafters {
        let mut counts = vec![0u64; VS];
        let mut drafted = 0u64;
        let mut accepted = 0u64;
        for s in 0..N_SAMPLES {
            let proposal = drafter.draft(&ctx, K, 0, s);
            let mut prefixes: Vec<Vec<i32>> =
                Vec::with_capacity(proposal.len() + 1);
            prefixes.push(ctx.clone());
            for &x in &proposal.tokens {
                let mut next = prefixes.last().unwrap().clone();
                next.push(x);
                prefixes.push(next);
            }
            let target_logits = target.logits_batch(&prefixes);
            let out = verifier.verify_row(&target_logits, &t, &proposal, 0, s);
            counts[out.tokens[0] as usize] += 1;
            drafted += proposal.len() as u64;
            accepted += out.accepted as u64;
        }
        let p = stats::chi_squared_pvalue(&counts, &oracle, N_SAMPLES as u64);
        let acc = if drafted == 0 {
            0.0
        } else {
            accepted as f64 / drafted as f64
        };
        // The acceptance bar: spec decode must be statistically
        // indistinguishable from direct target sampling at p > 0.01.
        let verdict = if p > 0.01 { "exact (not rejected)" } else { "REJECTED" };
        md.push_str(&format!("| {name} | {acc:.2} | {p:.4} | {verdict} |\n"));
    }
    Ok(md)
}

/// Engine-mirroring accounting simulation for [`prefix_identity`] and
/// [`stream_identity`]: the real scheduler + KV manager driven over a
/// workload, tracking the Philox step accounting exactly as the engine
/// does (one step per prefill batch — the `sample_hidden` call — and one
/// per decode batch), with optional mid-flight aborts mirroring
/// `Engine::abort` (drop from waiting, or release from running).
#[derive(Debug, Default, PartialEq)]
struct PrefixSimOut {
    /// Philox step coordinate at which each request sampled its first
    /// token (the `sample_hidden` step input).
    first_token_step: std::collections::BTreeMap<u64, u32>,
    /// Total engine steps consumed.
    steps: u32,
    /// Prefill batches planned.
    prefill_plans: u32,
    /// Leaked blocks after all releases + cache drain (must be 0).
    leaked: usize,
    /// Prefix-cache attachment refs left after the run (must be 0).
    dangling_refs: usize,
    /// Requests actually aborted mid-flight.
    aborted: usize,
    /// Prefill tokens total / served from cache.
    prefill_tokens: u64,
    cached_tokens: u64,
}

/// `aborts` is a `(request_id, at_step)` schedule: before planning step
/// `at_step`, the request is cancelled exactly the way `Engine::abort`
/// does it — dropped from the waiting queue (no KV yet) or released from
/// the running set (blocks + prefix-cache refs).
fn prefix_sim(
    specs: &[crate::workload::RequestSpec],
    caching: bool,
    aging_steps: u64,
    aborts: &[(u64, u32)],
) -> PrefixSimOut {
    use crate::coordinator::request::{SeqState, Sequence};
    use crate::coordinator::scheduler::{plan, Plan, SchedulerConfig};
    use crate::kvcache::{KvCacheConfig, KvCacheManager};
    use crate::prefixcache::BlockKv;

    const TOTAL_BLOCKS: usize = 2048;
    let sched = SchedulerConfig {
        decode_buckets: vec![1, 2, 4, 8],
        prefill_t_buckets: vec![16, 64],
        prefill_b: 4,
        max_concurrency: 8,
        max_tokens_per_step: 1,
        aging_steps,
        prefill_chunk_tokens: 0,
        chunk_interleave: false,
    };
    let mut kv = KvCacheManager::new(KvCacheConfig {
        block_size: 16,
        num_blocks: TOTAL_BLOCKS,
        prefix_caching: caching,
    });
    let mut waiting: Vec<Sequence> = specs
        .iter()
        .map(|s| {
            Sequence::new(crate::coordinator::Request {
                id: s.id,
                prompt: s.prompt.clone(),
                params: SamplingParams {
                    temperature: s.temperature,
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
                priority: s.priority,
            })
        })
        .collect();
    let mut running: Vec<Sequence> = Vec::new();
    let mut out = PrefixSimOut::default();
    loop {
        // Mid-flight aborts scheduled for this step (Engine::abort's two
        // phases: waiting = no KV registered yet; running = release
        // blocks AND prefix-cache attachment refs).
        for &(id, at) in aborts {
            if at != out.steps {
                continue;
            }
            if let Some(i) = waiting.iter().position(|s| s.id == id) {
                waiting.remove(i);
                out.aborted += 1;
            } else if let Some(i) = running.iter().position(|s| s.id == id) {
                let s = running.remove(i);
                kv.release(s.id).expect("running sequence is registered");
                out.aborted += 1;
            }
        }
        // Engine-identical batch admission: the SAME `BatchAdmission`
        // rule `Engine::step` uses, so the certificate can never drift
        // from the engine's real admission logic.
        let mut admission = kv.batch_admission();
        let p = plan(
            &sched,
            &waiting,
            &running,
            |s, burst| admission.admit(&kv, &s.prompt, burst),
            |s| kv.cached_prefix_tokens(&s.prompt),
            out.steps as u64,
        );
        match p {
            Plan::Prefill { seq_ids, .. } => {
                out.prefill_plans += 1;
                // Mirror the engine's phase order: every row of the batch
                // registers (and attaches) BEFORE any row publishes its
                // freshly computed prefix — same-batch prompts can't hit
                // each other's insertions.
                let mut batch: Vec<Sequence> = Vec::with_capacity(seq_ids.len());
                for id in &seq_ids {
                    let idx = waiting
                        .iter()
                        .position(|s| s.id == *id)
                        .expect("planned sequence vanished");
                    let s = waiting.remove(idx);
                    let a = kv
                        .register_with_prefix(s.id, &s.prompt)
                        .expect("admission checked");
                    out.prefill_tokens += s.prompt.len() as u64;
                    out.cached_tokens += a.cached_tokens as u64;
                    batch.push(s);
                }
                for mut s in batch {
                    kv.insert_prefix(s.id, &s.prompt, |_| BlockKv::default())
                        .expect("registered above");
                    // The engine samples every first token of the batch at
                    // THIS step (one sample_hidden call per prefill).
                    out.first_token_step.insert(s.id, out.steps);
                    s.generated.push(0);
                    s.state = SeqState::Running;
                    if s.generated.len() >= s.params.max_new_tokens {
                        kv.release(s.id).expect("registered");
                    } else {
                        kv.append_token(s.id).expect("registered");
                        running.push(s);
                    }
                }
                out.steps += 1;
            }
            Plan::Decode { seq_ids, .. } => {
                out.steps += 1;
                let mut finished: Vec<usize> = Vec::new();
                for id in &seq_ids {
                    let ri = running
                        .iter()
                        .position(|s| s.id == *id)
                        .expect("planned sequence vanished");
                    let s = &mut running[ri];
                    s.generated.push(0);
                    if s.generated.len() >= s.params.max_new_tokens {
                        finished.push(ri);
                    } else {
                        kv.append_token(s.id).expect("registered");
                    }
                }
                finished.sort_unstable_by(|a, b| b.cmp(a));
                for ri in finished {
                    let s = running.remove(ri);
                    kv.release(s.id).expect("registered");
                }
            }
            Plan::ChunkPrefill { .. } => {
                unreachable!("prefix_sim runs with chunking disabled")
            }
            Plan::Idle => break,
        }
        if waiting.is_empty() && running.is_empty() {
            break;
        }
    }
    // Refcount balance: every resident block must be cache-held, every
    // attachment ref must be detached, and draining the cache must
    // return the pool to pristine.
    out.leaked = kv.unaccounted_blocks();
    out.dangling_refs = kv.prefix_attached_refs();
    kv.clear_prefix_cache();
    out.leaked += TOTAL_BLOCKS - kv.free_blocks();
    out
}

/// `prefix-identity` — automatic prefix caching's exactness certificate
/// (DESIGN.md §10, the acceptance criterion of the prefix-cache
/// subsystem): with the same seeds and `SamplerSpec`, the engine's output
/// must be **token-for-token identical** with caching on and off.
///
/// Two layers, so the certificate runs everywhere:
///
/// 1. **Scheduling/coordinate identity (always, CPU-only)** — drive the
///    real scheduler + KV manager over a shared-prefix workload twice
///    (caching on/off) via [`prefix_sim`].  Caching must not change any
///    plan sequence or any request's first-token step coordinate;
///    allocator refcounts must balance to zero leaks.  Combined with the
///    byte-identity of cached KV (the Python `test_prefix_cache.py`
///    bitwise checks and the engine A/B below), unchanged coordinates
///    make the §4.6 chi-squared results provably identical with caching
///    on or off.
/// 2. **Engine A/B (when artifacts exist)** — run the same multi-turn
///    workload through two real engines (prefix caching on vs off) and
///    compare completions token-for-token.
pub fn prefix_identity() -> Result<String> {
    use crate::workload::{LengthDist, SharedPrefix, WorkloadGen};

    // A hit-heavy multi-turn workload: 2 system prompts x 4 users x 6
    // turns (prompts stay within the t=64 prefill bucket).
    let mut gen = WorkloadGen::new(0x9F1C, 1000.0, 2048);
    gen.prefix_mode = Some(SharedPrefix {
        num_prefixes: 2,
        prefix_len: 32,
        users: 4,
        turn_len: LengthDist::Fixed(4),
    });
    gen.output_len = LengthDist::Uniform(4, 9);
    let specs = gen.generate(24);

    let on = prefix_sim(&specs, true, 32, &[]);
    let off = prefix_sim(&specs, false, 32, &[]);

    let coords_identical = on.first_token_step == off.first_token_step
        && on.steps == off.steps
        && on.prefill_plans == off.prefill_plans;
    let hit_rate = on.cached_tokens as f64 / on.prefill_tokens.max(1) as f64;

    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut md = format!(
        "## prefix-identity — caching-on/off identity over a shared-prefix \
         workload ({} requests, 2 system prompts x 4 users, multi-turn)\n\n\
         | check | caching on | caching off | verdict |\n|---|---|---|---|\n\
         | engine steps | {} | {} | {} |\n\
         | prefill batches | {} | {} | {} |\n\
         | first-token Philox step coordinates | {} requests | {} requests | {} |\n\
         | leaked blocks after release+drain | {} | {} | {} |\n\
         | cached prefill tokens | {}/{} ({:.0}% hit rate) | 0/{} | - |\n",
        specs.len(),
        on.steps,
        off.steps,
        verdict(on.steps == off.steps),
        on.prefill_plans,
        off.prefill_plans,
        verdict(on.prefill_plans == off.prefill_plans),
        on.first_token_step.len(),
        off.first_token_step.len(),
        verdict(on.first_token_step == off.first_token_step),
        on.leaked,
        off.leaked,
        verdict(on.leaked == 0 && off.leaked == 0),
        on.cached_tokens,
        on.prefill_tokens,
        hit_rate * 100.0,
        off.prefill_tokens,
    );
    if !coords_identical || on.leaked != 0 || off.leaked != 0 {
        md.push_str("\n**MISMATCH — prefix caching altered scheduling or \
                     leaked blocks.**\n");
        return Ok(md);
    }
    // Hit-heavy acceptance bar: the shared-prefix workload must reuse at
    // least half of all prefill tokens.
    md.push_str(&format!(
        "\nCached-prefill token reduction: **{:.0}%** ({})\n",
        hit_rate * 100.0,
        if hit_rate >= 0.5 {
            "meets the >= 50% hit-heavy bar"
        } else {
            "MISMATCH: below the 50% bar"
        }
    ));

    // Engine A/B when artifacts are present (token-for-token identity
    // through the real fused artifacts).
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let run_engine = |caching: bool| -> Result<Vec<(u64, Vec<i32>)>> {
            let mut e = Engine::new(
                &dir,
                EngineConfig { prefix_caching: caching, ..Default::default() },
            )?;
            let vocab = e.runtime().manifest().model.vocab;
            let mut g = WorkloadGen::new(0x9F1C, 1000.0, vocab);
            g.prefix_mode = Some(SharedPrefix {
                num_prefixes: 2,
                prefix_len: 32,
                users: 4,
                turn_len: LengthDist::Fixed(4),
            });
            g.output_len = LengthDist::Uniform(4, 9);
            for s in g.generate(12) {
                e.submit(Request {
                    id: s.id,
                    prompt: s.prompt.clone(),
                    params: SamplingParams {
                        temperature: s.temperature,
                        max_new_tokens: s.max_new_tokens,
                        ..Default::default()
                    },
                    priority: s.priority,
                })?;
            }
            let mut done = e.run_to_completion()?;
            done.sort_by_key(|c| c.id);
            Ok(done.into_iter().map(|c| (c.id, c.tokens)).collect())
        };
        let a = run_engine(true)?;
        let b = run_engine(false)?;
        let same = a == b;
        md.push_str(&format!(
            "\nEngine A/B (real artifacts, 12 multi-turn requests): \
             token-for-token {}\n",
            verdict(same)
        ));
    } else {
        md.push_str(
            "\nEngine A/B: skipped (no artifacts; run `make artifacts` for \
             the end-to-end token identity)\n",
        );
    }
    Ok(md)
}

/// `stream-identity` — the streaming front-end's exactness certificate
/// (DESIGN.md §11): the handle API must not move a single Philox
/// coordinate relative to the legacy batch path, and any abort schedule
/// must leave the block allocator and the prefix-cache refcounts
/// balanced.
///
/// Three layers, the first two always runnable (CPU-only):
///
/// 1. **Priority-machinery neutrality** — the same mixed-tau
///    shared-prefix workload scheduled with aging disabled vs the
///    default aging, all requests at `Normal` priority: step counts,
///    prefill plans, and every first-token Philox step coordinate must
///    be identical (the stable-sort FCFS-tiebreak argument, checked).
/// 2. **Abort balance** — deterministic abort schedules covering the
///    prefill-pending, mid-decode, and prefix-shared-tail phases: after
///    the run, zero leaked blocks and zero dangling attachment refs.
/// 3. **Engine A/B (when artifacts exist)** — the same workload through
///    two real engines: one consumed via `run_to_completion`
///    completions, one driven step-by-step with every token taken from
///    the `RequestHandle` streams; concatenated streams must equal the
///    batch outputs token-for-token.
pub fn stream_identity() -> Result<String> {
    use crate::workload::{LengthDist, SharedPrefix, WorkloadGen};

    // Mixed-tau shared-prefix workload (the satellite's required shape).
    let make_gen = || {
        let mut gen = WorkloadGen::new(0x57E4, 1000.0, 2048);
        gen.prefix_mode = Some(SharedPrefix {
            num_prefixes: 2,
            prefix_len: 32,
            users: 4,
            turn_len: LengthDist::Fixed(4),
        });
        gen.output_len = LengthDist::Uniform(4, 9);
        gen.temperature_choices = vec![0.5, 1.0, 2.0];
        gen
    };
    let specs = make_gen().generate(24);

    // 1. Priority machinery must be a no-op at uniform Normal priority.
    let plain = prefix_sim(&specs, true, 0, &[]);
    let aged = prefix_sim(&specs, true, 32, &[]);
    let neutral = plain.first_token_step == aged.first_token_step
        && plain.steps == aged.steps
        && plain.prefill_plans == aged.prefill_plans;

    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut md = format!(
        "## stream-identity — streaming front-end exactness certificate \
         ({} requests, mixed tau, shared prefixes)\n\n\
         ### Priority/aging neutrality at uniform priority\n\n\
         | check | aging off | aging 32 | verdict |\n|---|---|---|---|\n\
         | engine steps | {} | {} | {} |\n\
         | prefill batches | {} | {} | {} |\n\
         | first-token Philox step coordinates | {} requests | {} requests | {} |\n",
        specs.len(),
        plain.steps,
        aged.steps,
        verdict(plain.steps == aged.steps),
        plain.prefill_plans,
        aged.prefill_plans,
        verdict(plain.prefill_plans == aged.prefill_plans),
        plain.first_token_step.len(),
        aged.first_token_step.len(),
        verdict(plain.first_token_step == aged.first_token_step),
    );
    if !neutral {
        md.push_str(
            "\n**MISMATCH — priority scheduling moved Philox coordinates \
             under uniform priority.**\n",
        );
        return Ok(md);
    }

    // 2. Abort-balance sweep over the lifecycle phases.
    let schedules: [(&str, Vec<(u64, u32)>); 4] = [
        // Step 0: nothing planned yet — these die in the waiting queue.
        ("prefill-pending", vec![(5, 0), (11, 0)]),
        // A few steps in: victims are mid-decode with live KV.
        ("mid-decode", vec![(0, 3), (6, 5), (13, 9)]),
        // Late multi-turn requests share cached prefix chains — aborting
        // them must drop attachment refs without touching siblings.
        ("prefix-shared tail", vec![(20, 12), (21, 12), (22, 14)]),
        // A burst across all phases at once.
        (
            "burst (every 3rd request)",
            (0..24u64).step_by(3).map(|i| (i, (i % 11) as u32)).collect(),
        ),
    ];
    md.push_str(
        "\n### Abort balance (zero-leak release of KV blocks and \
         prefix-cache refs)\n\n\
         | schedule | scheduled | aborted mid-flight | leaked blocks | \
         dangling refs | verdict |\n|---|---|---|---|---|---|\n",
    );
    let mut aborts_ok = true;
    for (name, sched) in &schedules {
        let out = prefix_sim(&specs, true, 32, sched);
        let ok = out.leaked == 0 && out.dangling_refs == 0;
        aborts_ok &= ok;
        md.push_str(&format!(
            "| {name} | {} | {} | {} | {} | {} |\n",
            sched.len(),
            out.aborted,
            out.leaked,
            out.dangling_refs,
            if ok { "BALANCED" } else { "MISMATCH: leak" },
        ));
    }
    if !aborts_ok {
        md.push_str("\n**MISMATCH — an abort schedule leaked blocks or refs.**\n");
        return Ok(md);
    }

    // 3. Engine A/B through real artifacts: handle streams vs batch.
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let engine_specs = |e: &Engine| {
            let vocab = e.runtime().manifest().model.vocab;
            let mut g = make_gen();
            g.vocab = vocab;
            g.generate(12)
        };
        let request = |s: &crate::workload::RequestSpec| Request {
            id: s.id,
            prompt: s.prompt.clone(),
            params: SamplingParams {
                temperature: s.temperature,
                max_new_tokens: s.max_new_tokens,
                ..Default::default()
            },
            priority: s.priority,
        };
        // Batch path: completions only.
        let mut batch = Engine::new(&dir, EngineConfig::default())?;
        for s in &engine_specs(&batch) {
            batch.submit(request(s))?;
        }
        let mut done = batch.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        let batch_tokens: Vec<(u64, Vec<i32>)> =
            done.into_iter().map(|c| (c.id, c.tokens)).collect();
        // Streaming path: every token consumed from handle events.
        let mut stream = Engine::new(&dir, EngineConfig::default())?;
        let mut handles = Vec::new();
        for s in &engine_specs(&stream) {
            handles.push(stream.submit(request(s))?);
        }
        while stream.pending() > 0 {
            if stream.step()?.is_empty() {
                // No-progress backstop (same as run_to_completion's): a
                // never-admittable head must become a Rejected terminal
                // event, not an infinite Plan::Idle spin.  No-op while
                // work is still running.
                let _ = stream.reject_unschedulable();
            }
        }
        let mut stream_tokens: Vec<(u64, Vec<i32>)> = handles
            .iter()
            .map(|h| {
                let toks = h.drain().iter().filter_map(|ev| ev.token).collect();
                (h.id(), toks)
            })
            .collect();
        stream_tokens.sort_by_key(|(id, _)| *id);
        let same = batch_tokens == stream_tokens;
        md.push_str(&format!(
            "\nEngine A/B (real artifacts, 12 mixed-tau shared-prefix \
             requests): handle-stream vs batch tokens {}\n",
            verdict(same)
        ));
    } else {
        md.push_str(
            "\nEngine A/B: skipped (no artifacts; run `make artifacts` for \
             the end-to-end stream/batch token identity)\n",
        );
    }
    Ok(md)
}

/// `chunk-identity` — chunked prefill's exactness certificate (DESIGN.md
/// §12, the acceptance criterion of the chunked-prefill + swap-tier
/// subsystem): sticky chunk windows run the prompt through the cached-
/// prefill artifact *without sampling*, so the final chunk's batch sees
/// the same rows and the same Philox step counter as an unchunked
/// prefill — no coordinate may move.
///
/// The certificate drives the REAL scheduler + KV manager through the
/// engine-mirroring [`crate::testutil::schedsim`] harness:
///
/// 1. **Replay identity** — deterministic and randomized closed-loop
///    scripts, chunked vs unchunked: token coordinates, first-token
///    (row, Philox step), and finish state must be identical for every
///    request.  (`ttft_weighted` is excluded — chunking reshapes *time*,
///    never coordinates.)
/// 2. **Capability** — a prompt beyond the largest prefill T bucket is
///    unservable without chunking (submit-time rejection) and must
///    complete with it.
/// 3. **Swap balance** — forced mid-decode preemptions to the swap tier:
///    every swapped-out block must swap back in, and the run must drain
///    with zero leaks (the harness panics on any per-step ledger
///    imbalance).
pub fn chunk_identity() -> Result<String> {
    use crate::testutil::schedsim::{self, Finish, Sim, SimConfig, SimRequest};
    use crate::testutil::Gen;

    fn script(prompts: &[usize], gen_len: usize) -> Vec<SimRequest> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, &p)| SimRequest {
                id: i as u64,
                prompt_len: p,
                max_new_tokens: gen_len,
                arrival_step: 0,
            })
            .collect()
    }

    /// Outcome equality modulo `ttft_weighted`.
    fn identical(base: &SimConfig, chunk: usize, reqs: &[SimRequest]) -> bool {
        let mut off = base.clone();
        off.sched.prefill_chunk_tokens = 0;
        let mut on = base.clone();
        on.sched.prefill_chunk_tokens = chunk;
        on.sched.chunk_interleave = false;
        let a = schedsim::run(off, reqs);
        let b = schedsim::run(on, reqs);
        a.len() == b.len()
            && a.iter().all(|(id, x)| {
                b.get(id).is_some_and(|y| {
                    x.tokens == y.tokens
                        && x.first_token == y.first_token
                        && x.finish == y.finish
                })
            })
    }

    let base = SimConfig::small(2048);
    let verdict = |ok: bool| if ok { "IDENTICAL" } else { "MISMATCH" };
    let mut all_ok = true;
    let mut md = String::from(
        "## chunk-identity — chunked prefill exactness certificate \
         (engine-mirroring scheduler sim, real plan() + KV manager)\n\n\
         ### Replay identity: chunked (sticky) vs unchunked\n\n\
         | scenario | chunk | requests | verdict |\n|---|---|---|---|\n",
    );

    // 1a. Deterministic scenarios.
    let fixed: [(&str, usize, Vec<SimRequest>); 3] = [
        ("uniform shorts", 16, script(&[24; 6], 6)),
        ("long head + companions", 16, script(&[60, 20, 20, 20], 4)),
        ("window-free (chunk = max bucket)", 64, script(&[60, 24], 5)),
    ];
    for (name, chunk, reqs) in &fixed {
        let ok = identical(&base, *chunk, reqs);
        all_ok &= ok;
        md.push_str(&format!(
            "| {name} | {chunk} | {} | {} |\n",
            reqs.len(),
            verdict(ok)
        ));
    }

    // 1b. Randomized closed-loop scripts (replayable: seed/case printed
    // on mismatch via the table row).
    for case in 0..20u32 {
        let mut g = Gen::new(0xC11D, case);
        let n = g.usize_in(2, 10);
        let reqs: Vec<SimRequest> = (0..n)
            .map(|i| SimRequest {
                id: i as u64,
                prompt_len: g.usize_in(4, 64),
                max_new_tokens: g.usize_in(1, 8),
                arrival_step: 0,
            })
            .collect();
        let chunk = *g.choose(&[8usize, 16, 32]);
        let ok = identical(&base, chunk, &reqs);
        all_ok &= ok;
        if !ok || case < 3 {
            md.push_str(&format!(
                "| randomized case {case} (seed 0xC11D) | {chunk} | {n} | {} |\n",
                verdict(ok)
            ));
        }
    }
    md.push_str("| randomized cases 3..20 | mixed | mixed | elided unless MISMATCH |\n");

    // 2. Capability: beyond-bucket prompts are only servable chunked.
    let oversized = script(&[100], 3);
    let rejected = schedsim::run(base.clone(), &oversized)[&0].finish
        == Some(Finish::Rejected);
    let mut on = base.clone();
    on.sched.prefill_chunk_tokens = 16;
    let served = {
        let o = &schedsim::run(on, &oversized)[&0];
        o.finish == Some(Finish::Done) && o.tokens.len() == 3
    };
    all_ok &= rejected && served;
    md.push_str(&format!(
        "\n### Capability (prompt 100 > largest t bucket 64)\n\n\
         | mode | outcome | verdict |\n|---|---|---|\n\
         | chunking off | submit-time rejection | {} |\n\
         | chunk 16 | completes (3 tokens) | {} |\n",
        if rejected { "OK" } else { "MISMATCH: admitted" },
        if served { "OK" } else { "MISMATCH: not served" },
    ));

    // 3. Swap-tier balance under forced preemption.
    let mut swap_cfg = base.clone();
    swap_cfg.swap_blocks = 64;
    swap_cfg.force_preempt = vec![(3, 0), (5, 1)];
    let mut sim = Sim::new(swap_cfg);
    sim.drive(&script(&[20, 20, 20], 12));
    let balanced = sim.swap_out_blocks == sim.swap_in_blocks
        && sim.swap_out_blocks > 0
        && sim
            .outcomes
            .values()
            .all(|o| o.finish == Some(Finish::Done) && o.tokens.len() == 12);
    all_ok &= balanced;
    md.push_str(&format!(
        "\n### Swap-tier balance (forced preemption mid-decode)\n\n\
         | swapped-out blocks | swapped-in blocks | verdict |\n|---|---|---|\n\
         | {} | {} | {} |\n",
        sim.swap_out_blocks,
        sim.swap_in_blocks,
        if balanced { "BALANCED" } else { "MISMATCH: swap ledger" },
    ));

    if !all_ok {
        md.push_str(
            "\n**MISMATCH — chunked prefill moved Philox coordinates or \
             the swap tier broke the block ledger.**\n",
        );
    }
    Ok(md)
}

/// Deterministic per-completion "correctness" checker: a synthetic task
/// whose success probability is identical under any exact sampler (the
/// §4.6 claim is that FlashSampling does not shift task accuracy).
fn score(prompt: &[i32], tokens: &[i32]) -> f64 {
    // "Answer": does the generation contain a token congruent to the
    // prompt checksum mod 7?  P(success) is a property of the sampling
    // distribution only.
    let target = prompt.iter().map(|&t| t as i64).sum::<i64>().rem_euclid(7);
    tokens.iter().any(|&t| (t as i64).rem_euclid(7) == target) as u8 as f64
}

/// End-to-end paired quality comparison through the real engine.
///
/// `artifacts_dir = None` resolves `./artifacts` and skips gracefully (with
/// a note in the output) when artifacts are absent.
pub fn e2e_quality(artifacts_dir: Option<&std::path::Path>) -> Result<String> {
    let dir = artifacts_dir
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"));
    if !dir.join("manifest.json").exists() {
        return Ok("## §4.6 e2e — SKIPPED (run `make artifacts` first)\n".into());
    }
    let n_prompts = 48usize;
    let gen = crate::workload::WorkloadGen::new(7, 1000.0, 2048);
    let mut specs = gen.generate(n_prompts);
    for s in &mut specs {
        s.prompt.truncate(12);
        s.max_new_tokens = 16;
    }

    let mut outcomes = Vec::new();
    for sampler in [SamplerSpec::default(), SamplerSpec::Multinomial] {
        let mut engine =
            Engine::new(&dir, EngineConfig { sampler, ..Default::default() })?;
        for s in &specs {
            engine.submit(Request {
                id: s.id,
                prompt: s.prompt.clone(),
                params: SamplingParams {
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
                priority: s.priority,
            })?;
        }
        let mut done = engine.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        let scores: Vec<f64> = done
            .iter()
            .map(|c| {
                let prompt = &specs[c.id as usize].prompt;
                score(prompt, &c.tokens)
            })
            .collect();
        outcomes.push(scores);
    }

    let acc_flash: f64 = outcomes[0].iter().sum::<f64>() / n_prompts as f64;
    let acc_base: f64 = outcomes[1].iter().sum::<f64>() / n_prompts as f64;
    let p = stats::paired_bootstrap_pvalue(&outcomes[0], &outcomes[1], 5000, 99);
    Ok(format!(
        "## §4.6 end-to-end verification — paired bootstrap over {n_prompts} prompts\n\n\
         | sampler | task accuracy |\n|---|---|\n\
         | FlashSampling (fused decode) | {:.1}% |\n\
         | Baseline (materialized multinomial) | {:.1}% |\n\n\
         Two-sided paired-bootstrap p-value: **{p:.3}** — {}\n",
        acc_flash * 100.0,
        acc_base * 100.0,
        if p > 0.05 {
            "no significant difference (consistent with exact sampling)"
        } else {
            "SIGNIFICANT DIFFERENCE (investigate!)"
        }
    ))
}

#[cfg(test)]
mod tests {
    #[test]
    fn chisq_report_accepts_all_exact_samplers() {
        let md = super::chisq().unwrap();
        assert!(!md.contains("REJECTED"), "{md}");
        assert_eq!(md.matches("exact (not rejected)").count(), 5);
    }

    #[test]
    fn hetero_chisq_every_row_matches_its_own_distribution() {
        let md = super::hetero_chisq().unwrap();
        assert!(!md.contains("REJECTED"), "{md}");
        assert_eq!(md.matches("exact (not rejected)").count(), 7);
    }

    #[test]
    fn specdec_chisq_matches_the_probs_space_oracle() {
        let md = super::specdec_chisq().unwrap();
        assert!(!md.contains("REJECTED"), "{md}");
        assert_eq!(md.matches("exact (not rejected)").count(), 3);
    }

    #[test]
    fn stream_identity_is_neutral_and_abort_balanced() {
        let md = super::stream_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        // Neutrality table: steps, prefill batches, first-token coords.
        assert!(md.matches("IDENTICAL").count() >= 3, "{md}");
        // Every abort schedule balances, and aborts actually happened —
        // the step-0 schedule cancels its 2 victims while they wait, so
        // its mid-flight abort count is exactly 2 by construction.
        assert_eq!(md.matches("BALANCED").count(), 4, "{md}");
        assert!(md.contains("| prefill-pending | 2 | 2 | 0 | 0 |"), "{md}");
    }

    #[test]
    fn chunk_identity_holds_and_swaps_balance() {
        let md = super::chunk_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        // 3 deterministic + 3 shown randomized identity rows.
        assert!(md.matches("IDENTICAL").count() >= 6, "{md}");
        // Both capability rows and the swap ledger row.
        assert_eq!(md.matches("| OK |").count(), 2, "{md}");
        assert!(md.contains("| BALANCED |"), "{md}");
    }

    #[test]
    fn prefix_identity_holds_and_is_hit_heavy() {
        let md = super::prefix_identity().unwrap();
        assert!(!md.contains("MISMATCH"), "{md}");
        // Steps, prefill batches, first-token coordinates, leak balance
        // (plus the engine A/B row when artifacts are present).
        assert!(md.matches("IDENTICAL").count() >= 4, "{md}");
        // The shared-prefix workload must clear the >= 50% reuse bar.
        assert!(md.contains("meets the >= 50% hit-heavy bar"), "{md}");
    }
}
