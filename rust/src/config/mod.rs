//! Typed configuration: defaults < config file < CLI overrides.
//!
//! The config file is a flat `key = value` format (a strict INI subset —
//! the offline image has no TOML crate; see Cargo.toml).  Every knob of the
//! serving stack lives here so deployments are reproducible from one file,
//! e.g.:
//!
//! ```text
//! # flashsampling.conf
//! artifacts_dir = artifacts
//! max_concurrency = 8
//! kv_blocks = 512
//! kv_block_size = 16
//! seed = 42
//! sampler = gumbel        # typed SamplerSpec grammar (see sampling docs)
//! temperature = 1.0
//! max_new_tokens = 64
//! request_rate = 8.0
//! num_requests = 64
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::{EngineConfig, Priority};
use crate::gpusim::iomodel::SwapPolicy;
use crate::router::DispatchPolicy;
use crate::sampling::SamplerSpec;
use crate::trace::TraceLevel;

/// Full launcher configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub artifacts_dir: PathBuf,
    pub max_concurrency: usize,
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    pub seed: u64,
    /// Automatic prefix caching (radix-tree KV reuse across requests,
    /// DESIGN.md §10).  Exact — identical outputs on or off — so it
    /// defaults on; `prefix_caching = false` is the A/B switch.
    pub prefix_caching: bool,
    /// Typed sampler selection (`SamplerSpec::Gumbel { .. }` = fused
    /// FlashSampling, `SamplerSpec::Multinomial` = baseline artifact).
    /// Parsed once from the `sampler` config key.
    pub sampler: SamplerSpec,
    /// Deprecated `baseline_sampler` key.  `true` forces the baseline
    /// artifact regardless of `sampler` — exactly the old independent
    /// bool's `bool || spec` semantics, so key order never matters and
    /// `false` never clobbers an explicit `sampler`.  Resolved into the
    /// typed spec by [`Config::engine_config`].
    pub baseline_override: bool,
    pub temperature: f32,
    /// Non-empty: `serve` draws each request's temperature uniformly from
    /// this set (comma-separated in the config file) — the mixed-client
    /// workload the per-row tau ABI exists for.  Empty: uniform
    /// `temperature`.
    pub temperature_choices: Vec<f32>,
    pub max_new_tokens: usize,
    /// Open-loop arrival rate (req/s) for `serve`.
    pub request_rate: f64,
    pub num_requests: usize,
    /// Anti-starvation aging for priority scheduling (engine steps per
    /// promoted priority class; 0 disables aging — DESIGN.md §11).
    pub priority_aging_steps: u64,
    /// Non-empty: `serve` draws each request's priority uniformly from
    /// this set (comma-separated `low|normal|high` in the config file) —
    /// mixed-SLO traffic for the priority scheduler.  Empty: all
    /// `normal` (identity-neutral).
    pub priority_choices: Vec<Priority>,
    /// Chunked-prefill window in prompt tokens (DESIGN.md §12); 0
    /// disables chunking.
    pub prefill_chunk_tokens: usize,
    /// Interleave chunk windows with other work on odd steps (bounded
    /// TTFT, replay identity traded away; see EngineConfig docs).
    pub chunk_interleave: bool,
    /// Host-side swap ledger capacity in KV blocks; 0 disables the swap
    /// tier.
    pub swap_blocks: usize,
    /// Swap-vs-recompute preemption policy: `auto` | `always` | `never`.
    pub swap_policy: SwapPolicy,
    /// Serving replicas behind the router (DESIGN.md §13).  1 (default)
    /// serves through a bare engine — byte-identical to the pre-router
    /// stack; N >= 2 fans requests out by `dispatch_policy`.
    pub replicas: usize,
    /// Router dispatch policy: `round-robin` | `least-loaded` |
    /// `prefix-affinity` (default — cache-aware session routing).
    /// Inert at `replicas = 1`, where every policy picks replica 0.
    pub dispatch_policy: DispatchPolicy,
    /// Flight-recorder level (DESIGN.md §14): `off` (default — one
    /// branch per event site) | `lifecycle` | `full`.
    pub trace_level: TraceLevel,
    /// Flight-recorder ring capacity in events (default 4096, min 64).
    /// The trace digest and `DerivedCounters` are eviction-independent,
    /// so certificates are unaffected by a small ring; the modeled-time
    /// profiler (DESIGN.md §15) needs the full event stream, so size
    /// this to the workload before `flashsampling profile`.
    pub trace_ring_cap: usize,
    /// TTFT SLO threshold in milliseconds for
    /// `flashsampling_slo_violations_total` (DESIGN.md §15); 0 (default)
    /// disables the classification.
    pub slo_ttft_ms: u64,
    /// Inter-token-latency SLO threshold in milliseconds; 0 (default)
    /// disables the classification.
    pub slo_itl_ms: u64,
    /// Certified sub-vocabulary decode (DESIGN.md §16): skip cold vocab
    /// tiles in the LM head under a per-step exactness certificate.
    /// Off by default; token streams are bit-identical on or off.
    pub subvocab: bool,
    /// Candidate tile budget per decode batch
    /// (1..=[`crate::subvocab::SUB_TILE_SLOTS`]).
    pub subvocab_tiles: usize,
    /// Additive certificate slack (finite, >= 0): skip only when the
    /// candidate winner beats the excluded-tile bound by more than this.
    pub subvocab_slack: f32,
    /// Output directory for `repro`.
    pub out_dir: PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            max_concurrency: 8,
            kv_blocks: 512,
            kv_block_size: 16,
            seed: 42,
            prefix_caching: true,
            sampler: SamplerSpec::default(),
            baseline_override: false,
            temperature: 1.0,
            temperature_choices: Vec::new(),
            max_new_tokens: 32,
            request_rate: 8.0,
            num_requests: 32,
            priority_aging_steps: 32,
            priority_choices: Vec::new(),
            prefill_chunk_tokens: 0,
            chunk_interleave: false,
            swap_blocks: 0,
            swap_policy: SwapPolicy::Auto,
            replicas: 1,
            dispatch_policy: DispatchPolicy::default(),
            trace_level: TraceLevel::Off,
            trace_ring_cap: 4096,
            slo_ttft_ms: 0,
            slo_itl_ms: 0,
            subvocab: false,
            subvocab_tiles: crate::subvocab::SUB_TILE_SLOTS,
            subvocab_slack: 0.0,
            out_dir: "results".into(),
        }
    }
}

impl Config {
    /// Parse a flat `key = value` file over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut cfg = Self::default();
        cfg.apply_pairs(parse_pairs(&text)?)?;
        Ok(cfg)
    }

    /// Apply `key=value` CLI overrides (e.g. `--set seed=7`).
    ///
    /// Transactional: values are staged onto a copy and committed only
    /// when every key parses AND the cross-field validation passes, so a
    /// failed apply never clobbers previously-valid configuration —
    /// whether the failure is a parse error or a range check.
    pub fn apply_pairs(&mut self, pairs: BTreeMap<String, String>) -> Result<()> {
        let mut next = self.clone();
        next.apply_pairs_direct(pairs)?;
        *self = next;
        Ok(())
    }

    fn apply_pairs_direct(&mut self, pairs: BTreeMap<String, String>) -> Result<()> {
        for (k, v) in pairs {
            match k.as_str() {
                "artifacts_dir" => self.artifacts_dir = v.into(),
                "max_concurrency" => self.max_concurrency = v.parse()?,
                "kv_blocks" => self.kv_blocks = v.parse()?,
                "kv_block_size" => self.kv_block_size = v.parse()?,
                "seed" => self.seed = v.parse()?,
                "prefix_caching" => self.prefix_caching = v.parse()?,
                // Deprecated: pre-typed boolean A/B switch, preserved
                // with its original `bool || spec` semantics (see the
                // `baseline_override` field docs).
                "baseline_sampler" => self.baseline_override = v.parse()?,
                "sampler" => {
                    // Parse ONCE at the config boundary, with the engine's
                    // constraint (only artifact-backed specs are servable).
                    let spec: SamplerSpec = v
                        .parse()
                        .with_context(|| format!("config key 'sampler' = '{v}'"))?;
                    let mut probe = self.engine_config();
                    probe.sampler = spec.clone();
                    probe
                        .validate_sampler()
                        .with_context(|| format!("config key 'sampler' = '{v}'"))?;
                    self.sampler = spec;
                }
                "temperature" => self.temperature = v.parse()?,
                "temperature_choices" => {
                    self.temperature_choices = v
                        .split(',')
                        .filter(|s| !s.trim().is_empty())
                        .map(|s| s.trim().parse::<f32>().map_err(Into::into))
                        .collect::<Result<Vec<f32>>>()?;
                }
                "max_new_tokens" => self.max_new_tokens = v.parse()?,
                "request_rate" => self.request_rate = v.parse()?,
                "num_requests" => self.num_requests = v.parse()?,
                "priority_aging_steps" => self.priority_aging_steps = v.parse()?,
                "priority_choices" => {
                    self.priority_choices = v
                        .split(',')
                        .filter(|s| !s.trim().is_empty())
                        .map(|s| s.parse::<Priority>())
                        .collect::<Result<Vec<Priority>>>()?;
                }
                "prefill_chunk_tokens" => self.prefill_chunk_tokens = v.parse()?,
                "chunk_interleave" => self.chunk_interleave = v.parse()?,
                "swap_blocks" => self.swap_blocks = v.parse()?,
                "swap_policy" => {
                    self.swap_policy = v
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))
                        .with_context(|| format!("config key 'swap_policy' = '{v}'"))?;
                }
                "replicas" => self.replicas = v.parse()?,
                "subvocab" => self.subvocab = v.parse()?,
                "subvocab_tiles" => self.subvocab_tiles = v.parse()?,
                "subvocab_slack" => self.subvocab_slack = v.parse()?,
                "trace_ring_cap" => self.trace_ring_cap = v.parse()?,
                "slo_ttft_ms" => self.slo_ttft_ms = v.parse()?,
                "slo_itl_ms" => self.slo_itl_ms = v.parse()?,
                "trace_level" => {
                    self.trace_level = v
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))
                        .with_context(|| format!("config key 'trace_level' = '{v}'"))?;
                }
                "dispatch_policy" => {
                    self.dispatch_policy = v
                        .parse()
                        .with_context(|| format!("config key 'dispatch_policy' = '{v}'"))?;
                }
                "out_dir" => self.out_dir = v.into(),
                other => bail!("unknown config key '{other}'"),
            }
        }
        if !(self.temperature > 0.0 && self.temperature.is_finite()) {
            bail!("temperature must be finite and > 0");
        }
        if self.temperature_choices.iter().any(|&t| t <= 0.0 || !t.is_finite()) {
            bail!("temperature_choices must all be finite and > 0");
        }
        if self.max_concurrency == 0 {
            bail!("max_concurrency must be >= 1");
        }
        if self.replicas == 0 {
            bail!("replicas must be >= 1");
        }
        if self.trace_ring_cap < 64 {
            bail!("trace_ring_cap must be >= 64");
        }
        if !(1..=crate::subvocab::SUB_TILE_SLOTS).contains(&self.subvocab_tiles) {
            bail!(
                "subvocab_tiles must be in 1..={}",
                crate::subvocab::SUB_TILE_SLOTS
            );
        }
        if !(self.subvocab_slack.is_finite() && self.subvocab_slack >= 0.0) {
            bail!("subvocab_slack must be finite and >= 0");
        }
        Ok(())
    }

    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            max_concurrency: self.max_concurrency,
            kv_blocks: self.kv_blocks,
            kv_block_size: self.kv_block_size,
            seed: self.seed,
            prefix_caching: self.prefix_caching,
            // The deprecated bool forces the baseline artifact; otherwise
            // the typed spec stands (the old `bool || spec` A/B rule).
            sampler: if self.baseline_override {
                SamplerSpec::Multinomial
            } else {
                self.sampler.clone()
            },
            priority_aging_steps: self.priority_aging_steps,
            prefill_chunk_tokens: self.prefill_chunk_tokens,
            chunk_interleave: self.chunk_interleave,
            swap_blocks: self.swap_blocks,
            swap_policy: self.swap_policy,
            trace_level: self.trace_level,
            trace_ring_cap: self.trace_ring_cap,
            slo_ttft_us: self.slo_ttft_ms * 1000,
            slo_itl_us: self.slo_itl_ms * 1000,
            subvocab: self.subvocab,
            subvocab_tiles: self.subvocab_tiles,
            subvocab_slack: self.subvocab_slack,
            // TP-sharded replicas are constructed programmatically
            // (`EngineConfig::tp`); the config file drives the router
            // shape via `replicas` / `dispatch_policy` only.
            tp: None,
        }
    }
}

/// Parse `key = value` lines; `#` comments and blank lines ignored.
pub fn parse_pairs(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
        };
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.temperature > 0.0);
        assert!(c.max_concurrency >= 1);
    }

    #[test]
    fn parse_pairs_handles_comments_and_spacing() {
        let p = parse_pairs("a = 1\n# comment\n\n b=2  # trailing\n").unwrap();
        assert_eq!(p["a"], "1");
        assert_eq!(p["b"], "2");
        assert!(parse_pairs("no equals here").is_err());
    }

    #[test]
    fn overrides_apply_and_validate() {
        let mut c = Config::default();
        c.apply_pairs(parse_pairs("seed = 7\nbaseline_sampler = true").unwrap())
            .unwrap();
        assert_eq!(c.seed, 7);
        // The deprecated key resolves at engine_config() time, `bool ||
        // spec` — it never rewrites the typed sampler field.
        assert_eq!(c.sampler, SamplerSpec::default());
        assert!(c.engine_config().uses_baseline_artifact());
        c.apply_pairs(parse_pairs("baseline_sampler = false").unwrap())
            .unwrap();
        assert!(!c.engine_config().uses_baseline_artifact());
        // `false` must NOT clobber an explicitly configured spec, in
        // either direction and in any key order (it was an independent
        // bool before the typed redesign).
        c.apply_pairs(parse_pairs("sampler = gumbel:tile=512").unwrap())
            .unwrap();
        c.apply_pairs(parse_pairs("baseline_sampler = false").unwrap())
            .unwrap();
        assert_eq!(c.sampler, SamplerSpec::Gumbel { tile: Some(512) });
        c.apply_pairs(parse_pairs("sampler = multinomial").unwrap()).unwrap();
        c.apply_pairs(parse_pairs("baseline_sampler = false").unwrap())
            .unwrap();
        assert!(c.engine_config().uses_baseline_artifact(), "explicit spec stands");
        // ...while `true` forces the baseline over any fused spec.
        c.apply_pairs(parse_pairs("sampler = gumbel:tile=512").unwrap())
            .unwrap();
        c.apply_pairs(parse_pairs("baseline_sampler = true").unwrap())
            .unwrap();
        assert!(c.engine_config().uses_baseline_artifact());
        assert_eq!(c.sampler, SamplerSpec::Gumbel { tile: Some(512) });
        assert!(c
            .apply_pairs(parse_pairs("bogus_key = 1").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("temperature = 0").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("temperature = nan").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("temperature = inf").unwrap())
            .is_err());
    }

    #[test]
    fn sampler_key_is_registry_validated() {
        let mut c = Config::default();
        assert_eq!(c.sampler, SamplerSpec::default());
        c.apply_pairs(parse_pairs("sampler = gumbel:tile=2048").unwrap())
            .unwrap();
        assert_eq!(c.sampler, SamplerSpec::Gumbel { tile: Some(2048) });
        assert_eq!(c.engine_config().sampler.to_string(), "gumbel:tile=2048");
        // Unknown sampler names and malformed params fail at parse time.
        assert!(c
            .apply_pairs(parse_pairs("sampler = frobnicate").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("sampler = gumbel:bogus=1").unwrap())
            .is_err());
        // Host-side samplers are valid registry specs but not servable by
        // the decode artifacts: rejected here, not at serve time.
        assert!(c
            .apply_pairs(parse_pairs("sampler = grouped:group=64").unwrap())
            .is_err());
        // A failed apply must not clobber the previous value.
        assert_eq!(c.sampler, SamplerSpec::Gumbel { tile: Some(2048) });
        // The baseline artifact can be selected by spec alone.
        c.apply_pairs(parse_pairs("sampler = multinomial").unwrap()).unwrap();
        assert!(c.engine_config().uses_baseline_artifact());
    }

    #[test]
    fn prefix_caching_key_parses_and_defaults_on() {
        let mut c = Config::default();
        assert!(c.prefix_caching);
        assert!(c.engine_config().prefix_caching);
        c.apply_pairs(parse_pairs("prefix_caching = false").unwrap()).unwrap();
        assert!(!c.prefix_caching);
        assert!(!c.engine_config().prefix_caching);
        c.apply_pairs(parse_pairs("prefix_caching = true").unwrap()).unwrap();
        assert!(c.engine_config().prefix_caching);
        assert!(c
            .apply_pairs(parse_pairs("prefix_caching = maybe").unwrap())
            .is_err());
    }

    #[test]
    fn temperature_choices_parse_and_validate() {
        let mut c = Config::default();
        c.apply_pairs(parse_pairs("temperature_choices = 0.5, 1.0,2.0").unwrap())
            .unwrap();
        assert_eq!(c.temperature_choices, vec![0.5, 1.0, 2.0]);
        assert!(c
            .apply_pairs(parse_pairs("temperature_choices = 0.5,0").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("temperature_choices = abc").unwrap())
            .is_err());
        // Empty value clears the set (back to uniform `temperature`).
        c.apply_pairs(parse_pairs("temperature_choices = 1.5").unwrap())
            .unwrap();
        c.apply_pairs(parse_pairs("temperature_choices =").unwrap()).unwrap();
        assert!(c.temperature_choices.is_empty());
    }

    #[test]
    fn priority_keys_parse_and_flow_to_the_engine() {
        let mut c = Config::default();
        assert_eq!(c.priority_aging_steps, 32);
        assert_eq!(c.engine_config().priority_aging_steps, 32);
        assert!(c.priority_choices.is_empty());
        c.apply_pairs(parse_pairs("priority_aging_steps = 0").unwrap()).unwrap();
        assert_eq!(c.engine_config().priority_aging_steps, 0);
        c.apply_pairs(parse_pairs("priority_choices = low, normal,high").unwrap())
            .unwrap();
        assert_eq!(
            c.priority_choices,
            vec![Priority::Low, Priority::Normal, Priority::High]
        );
        // Empty value clears the set; bad names are rejected.
        c.apply_pairs(parse_pairs("priority_choices =").unwrap()).unwrap();
        assert!(c.priority_choices.is_empty());
        assert!(c
            .apply_pairs(parse_pairs("priority_choices = urgent").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("priority_aging_steps = x").unwrap())
            .is_err());
    }

    #[test]
    fn chunking_and_swap_keys_flow_to_the_engine() {
        let mut c = Config::default();
        // Both subsystems default off: byte-identical legacy behavior.
        assert_eq!(c.prefill_chunk_tokens, 0);
        assert!(!c.chunk_interleave);
        assert_eq!(c.swap_blocks, 0);
        assert_eq!(c.swap_policy, SwapPolicy::Auto);
        c.apply_pairs(
            parse_pairs(
                "prefill_chunk_tokens = 16\nchunk_interleave = true\n\
                 swap_blocks = 64\nswap_policy = always",
            )
            .unwrap(),
        )
        .unwrap();
        let e = c.engine_config();
        assert_eq!(e.prefill_chunk_tokens, 16);
        assert!(e.chunk_interleave);
        assert_eq!(e.swap_blocks, 64);
        assert_eq!(e.swap_policy, SwapPolicy::Always);
        assert!(c
            .apply_pairs(parse_pairs("swap_policy = sometimes").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("prefill_chunk_tokens = -1").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("chunk_interleave = maybe").unwrap())
            .is_err());
        // Failed applies never clobber prior values.
        assert_eq!(c.swap_policy, SwapPolicy::Always);
        c.apply_pairs(parse_pairs("swap_policy = never").unwrap()).unwrap();
        assert_eq!(c.engine_config().swap_policy, SwapPolicy::Never);
    }

    #[test]
    fn router_keys_parse_and_validate() {
        let mut c = Config::default();
        // Defaults: 1 replica (bare-engine identity), prefix-affinity.
        assert_eq!(c.replicas, 1);
        assert_eq!(c.dispatch_policy, DispatchPolicy::PrefixAffinity);
        c.apply_pairs(
            parse_pairs("replicas = 4\ndispatch_policy = least-loaded").unwrap(),
        )
        .unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.dispatch_policy, DispatchPolicy::LeastLoaded);
        assert!(c.apply_pairs(parse_pairs("replicas = 0").unwrap()).is_err());
        assert!(c
            .apply_pairs(parse_pairs("dispatch_policy = random").unwrap())
            .is_err());
        // Failed applies never clobber prior values.
        assert_eq!(c.dispatch_policy, DispatchPolicy::LeastLoaded);
        // The config-file shape never reaches the engine as TP.
        assert!(c.engine_config().tp.is_none());
    }

    #[test]
    fn trace_level_key_parses_and_defaults_off() {
        let mut c = Config::default();
        assert_eq!(c.trace_level, TraceLevel::Off);
        assert_eq!(c.engine_config().trace_level, TraceLevel::Off);
        c.apply_pairs(parse_pairs("trace_level = lifecycle").unwrap()).unwrap();
        assert_eq!(c.engine_config().trace_level, TraceLevel::Lifecycle);
        c.apply_pairs(parse_pairs("trace_level = full").unwrap()).unwrap();
        assert_eq!(c.trace_level, TraceLevel::Full);
        assert!(c
            .apply_pairs(parse_pairs("trace_level = verbose").unwrap())
            .is_err());
        // Failed applies never clobber prior values.
        assert_eq!(c.trace_level, TraceLevel::Full);
        c.apply_pairs(parse_pairs("trace_level = off").unwrap()).unwrap();
        assert_eq!(c.engine_config().trace_level, TraceLevel::Off);
    }

    #[test]
    fn trace_ring_cap_parses_and_validates() {
        let mut c = Config::default();
        assert_eq!(c.trace_ring_cap, 4096);
        assert_eq!(c.engine_config().trace_ring_cap, 4096);
        c.apply_pairs(parse_pairs("trace_ring_cap = 128").unwrap()).unwrap();
        assert_eq!(c.engine_config().trace_ring_cap, 128);
        // Below the floor and unparsable values are rejected without
        // clobbering the prior value.
        assert!(c
            .apply_pairs(parse_pairs("trace_ring_cap = 63").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("trace_ring_cap = lots").unwrap())
            .is_err());
        assert_eq!(c.trace_ring_cap, 128);
        c.apply_pairs(parse_pairs("trace_ring_cap = 64").unwrap()).unwrap();
        assert_eq!(c.trace_ring_cap, 64);
    }

    #[test]
    fn slo_keys_parse_and_flow_to_the_engine_in_microseconds() {
        let mut c = Config::default();
        // Default 0 = SLO accounting off (legacy-identical exposition).
        assert_eq!(c.slo_ttft_ms, 0);
        assert_eq!(c.slo_itl_ms, 0);
        assert_eq!(c.engine_config().slo_ttft_us, 0);
        assert_eq!(c.engine_config().slo_itl_us, 0);
        c.apply_pairs(parse_pairs("slo_ttft_ms = 250\nslo_itl_ms = 40").unwrap())
            .unwrap();
        assert_eq!(c.engine_config().slo_ttft_us, 250_000);
        assert_eq!(c.engine_config().slo_itl_us, 40_000);
        assert!(c
            .apply_pairs(parse_pairs("slo_ttft_ms = -1").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("slo_itl_ms = soon").unwrap())
            .is_err());
        assert_eq!(c.slo_ttft_ms, 250);
    }

    #[test]
    fn subvocab_keys_parse_validate_and_flow_to_the_engine() {
        let mut c = Config::default();
        // Default off with a full-slot budget and zero slack.
        assert!(!c.subvocab);
        assert_eq!(c.subvocab_tiles, crate::subvocab::SUB_TILE_SLOTS);
        assert_eq!(c.subvocab_slack, 0.0);
        assert!(!c.engine_config().subvocab);
        c.apply_pairs(
            parse_pairs(
                "subvocab = true\nsubvocab_tiles = 2\nsubvocab_slack = 0.5",
            )
            .unwrap(),
        )
        .unwrap();
        let e = c.engine_config();
        assert!(e.subvocab);
        assert_eq!(e.subvocab_tiles, 2);
        assert!((e.subvocab_slack - 0.5).abs() < 1e-9);
        // Out-of-range budgets and non-finite / negative slack rejected.
        assert!(c
            .apply_pairs(parse_pairs("subvocab_tiles = 0").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("subvocab_tiles = 99").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("subvocab_slack = -1").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("subvocab_slack = nan").unwrap())
            .is_err());
        assert!(c
            .apply_pairs(parse_pairs("subvocab = maybe").unwrap())
            .is_err());
        // Failed applies never clobber prior values.
        assert_eq!(c.subvocab_tiles, 2);
        assert!((c.subvocab_slack - 0.5).abs() < 1e-9);
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join("fs_config_test.conf");
        std::fs::write(&path, "max_concurrency = 4\nrequest_rate = 2.5\n").unwrap();
        let c = Config::from_file(&path).unwrap();
        assert_eq!(c.max_concurrency, 4);
        assert!((c.request_rate - 2.5).abs() < 1e-9);
        // engine config mirrors the fields
        assert_eq!(c.engine_config().max_concurrency, 4);
    }
}
