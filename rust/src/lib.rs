//! # FlashSampling
//!
//! Reproduction of *FlashSampling: Fast and Memory-Efficient Exact Sampling*
//! (CS.LG 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — the fused tiled Gumbel-Max kernel lives in
//!   `python/compile/kernels/flash_sampling.py` (Pallas, AOT-lowered).
//! * **L2** — the serving model (tiny transformer + FlashSampling LM head)
//!   lives in `python/compile/model.py` (JAX, AOT-lowered).
//! * **L3** — this crate: the serving coordinator (continuous batching,
//!   paged KV cache, prefill/decode scheduling), the PJRT runtime that
//!   executes the AOT artifacts, native exact samplers mirroring the paper's
//!   algorithms, the simulated tensor-parallel runtime, and the analytical
//!   GPU performance model that regenerates every table and figure of the
//!   paper's evaluation (see `DESIGN.md` for the experiment index).
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! graphs to HLO text once; the coordinator loads and executes them through
//! the PJRT C API (`xla` crate).

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod json;
pub mod kvcache;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod sampling;
pub mod testutil;
pub mod tp;
pub mod workload;

/// Crate-wide result type (library errors carry context via `anyhow`).
pub type Result<T> = anyhow::Result<T>;
