//! # FlashSampling
//!
//! Reproduction of *FlashSampling: Fast and Memory-Efficient Exact Sampling*
//! (CS.LG 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — the fused tiled Gumbel-Max kernel lives in
//!   `python/compile/kernels/flash_sampling.py` (Pallas, AOT-lowered).
//! * **L2** — the serving model (tiny transformer + FlashSampling LM head)
//!   lives in `python/compile/model.py` (JAX, AOT-lowered).
//! * **L3** — this crate: the serving coordinator (continuous batching,
//!   paged KV cache, prefill/decode scheduling), the PJRT runtime that
//!   executes the AOT artifacts, native exact samplers mirroring the paper's
//!   algorithms, the simulated tensor-parallel runtime, and the analytical
//!   GPU performance model that regenerates every table and figure of the
//!   paper's evaluation (see `DESIGN.md` for the experiment index).
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! graphs to HLO text once; the coordinator loads and executes them through
//! the PJRT C API (`xla` crate).
//!
//! # Build features
//!
//! * **default** — CPU-only: the workspace's `crates/xla` host stub stands
//!   in for the PJRT bindings.  Everything that does not execute AOT
//!   artifacts (all native samplers, the scheduler/KV machinery, the GPU
//!   simulator, the repro tables) works; artifact execution returns a
//!   "PJRT unavailable" error and the integration tests skip.
//! * **`pjrt`** (non-default) — the seam for the real runtime: build with
//!   `--features pjrt` and a `[patch]` of `xla` onto the real xla-rs crate
//!   (see README.md, section PJRT).
//!
//! # Sampler selection
//!
//! All six paper samplers implement [`sampling::ExactSampler`] and are
//! selected by the typed [`sampling::SamplerSpec`] (config strings parse
//! once at the boundary; [`sampling::build_sampler`] is the string shim) —
//! the coordinator ([`coordinator::EngineConfig::sampler`]), the TP
//! orchestrator ([`tp::Strategy::leader_sampler_spec`]), the benches, and
//! the repro tables all select algorithms through that one seam.  Per-row
//! sampling parameters travel via [`coordinator::SamplingParams`] and the
//! `ExactSampler::sample_batch_rows` entry point.
//!
//! # Speculative decoding
//!
//! The [`specdec`] subsystem (DESIGN.md §9) adds an alternative decode
//! path: a [`specdec::DraftModel`] proposes K tokens, an exact verifier
//! (accept with `min(1, p/q)`, Gumbel-argmax residual resample — or the
//! Gumbel-coupled token-matching rule on the sample-only artifact path)
//! keeps the output provably distributed as the target model, and the
//! engine emits 1..=K+1 tokens per step.  Selected by
//! `sampler = specdec:k=4,ngram=3`; verified by `repro specdec-chisq`.
//!
//! # Streaming serving front-end
//!
//! The engine's request lifecycle is a vLLM-style submission/streaming
//! split (DESIGN.md §11): [`coordinator::Engine::submit`] returns a
//! [`coordinator::RequestHandle`] that yields per-token
//! [`coordinator::RequestOutput`] events (token, position, logical-step
//! TTFT/inter-token timing), [`coordinator::Engine::abort`] cancels
//! mid-flight with zero-leak KV + prefix-cache release, requests carry a
//! [`coordinator::Priority`] with an anti-starvation aging rule, and the
//! public boundary reports typed [`coordinator::EngineError`]s.  The
//! legacy batch entry points survive as shims with byte-identical token
//! streams — `repro stream-identity` and `rust/tests/streaming.rs` are
//! the certificate.
//!
//! # Automatic prefix caching
//!
//! The [`prefixcache`] subsystem (DESIGN.md §10) removes redundant prefill
//! for shared-prefix traffic (system prompts, few-shot templates,
//! multi-turn histories): a chain-hashed radix tree maps full-block token
//! prefixes to refcounted KV blocks, the scheduler charges only uncached
//! tokens against admission, and the engine restores cached KV
//! byte-identically and prefills the suffix only (`prefill_cached`
//! artifacts).  Output is token-for-token identical with caching on or
//! off — `repro prefix-identity` and `rust/tests/prefixcache.rs` assert
//! it — and `cargo bench --bench prefixcache` measures the cached-token
//! reduction and the modeled TTFT win on shared-prefix workloads.
//!
//! # Multi-replica serving router
//!
//! The [`router`] subsystem (DESIGN.md §13) scales the serving stack past
//! one engine: a [`router::Router`] owns N replicas behind the same
//! handle-based front door (`serve --replicas N`), dispatching by a
//! pluggable [`router::DispatchPolicy`] — round-robin, least-loaded (KV
//! headroom probes), or prefix-affinity, which routes on the radix chain
//! hash of the prompt's cacheable prefix so multi-turn sessions land on
//! the replica whose radix tree is warm.  Replicas implement
//! [`router::EngineBackend`]: a plain [`coordinator::Engine`], or a
//! TP-sharded one (`EngineConfig::tp`) whose decode fans out through
//! [`tp::TpOrchestrator`] — exact by the paper's hierarchical
//! factorization, so shard count never shows in the token stream.
//! `repro router-identity` and `rust/tests/router.rs` certify 1-replica
//! byte-identity, replay-stable dispatch, and zero-leak aborts.
//!
//! # Flight-recorder tracing
//!
//! The [`trace`] subsystem (DESIGN.md §14) is a zero-dependency flight
//! recorder: a bounded ring of typed events keyed by the logical step
//! clock, request id, and Philox `(row, cstep)` coordinates, emitted
//! across scheduler, KV, spec decode, and router.  `trace_level = off`
//! (the default) costs one branch per event site; `lifecycle` records
//! request lifecycles; `full` adds scheduler/KV internals.  Exports are
//! Chrome trace-event JSON (Perfetto) and canonical JSONL; because no
//! event carries wall-clock data, the trace digest is replay-stable and
//! `repro trace-identity` certifies both that identity and that
//! counters derived from the event log reproduce
//! [`metrics::ServingMetrics`] exactly.
//!
//! # Modeled-time profiling and the perf gate
//!
//! The [`profile`] subsystem (DESIGN.md §15) turns the flight recorder
//! into an attribution instrument: it folds the trace through a
//! [`profile::Pricer`] — the [`profile::PriceTable`] distilled from the
//! [`gpusim`] cost models, or the step-clock pricer that reproduces the
//! accounting sims exactly — into per-request phase breakdowns (queue /
//! prefill / chunk / swap / spec / decode), per-replica window tilings
//! of the makespan, a modeled-microseconds Chrome trace
//! (`flashsampling profile`), and an integer-only FNV digest.  SLO
//! thresholds (`slo_ttft_ms` / `slo_itl_ms`) classify violations into
//! `flashsampling_slo_violations_total`, and
//! [`profile::benchdiff`] (`flashsampling benchdiff OLD NEW`) gates CI
//! on regressions in the provenance-stamped `BENCH_*.json` schema.
//! `repro profile-identity` certifies span-balance conservation,
//! makespan tiling, replay determinism, and profile⇔metrics agreement;
//! `python/tests/sim_profile_bench.py` re-derives the digest
//! cross-language.
//!
//! # Certified sub-vocabulary decoding
//!
//! The [`subvocab`] subsystem (DESIGN.md §16) skips cold vocab tiles in
//! the decode LM head without giving up the exact-sampling contract: a
//! per-request [`subvocab::CandidateSet`] ranks vocab tiles by
//! frequency/recency (prompt statistics + emitted tokens), the engine
//! runs only those tiles through the `decode_sample_sub` tile-subset
//! artifacts (ABI v3), and a per-step certificate — the per-tile
//! Cauchy–Schwarz weight-norm bound [`subvocab::TileNorms`] plus the
//! exact per-tile max Gumbel — either *proves* the excluded tiles cannot
//! win the Gumbel-argmax or forces a full-vocabulary fallback pass at
//! the same Philox coordinates.  Tokens are bit-identical to full
//! FlashSampling either way; `repro subvocab-identity`,
//! `rust/tests/subvocab.rs`, and `python/tests/sim_subvocab_bench.py`
//! are the certificate, and [`metrics::ServingMetrics`] exports the
//! fallback rate.

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod json;
pub mod kvcache;
pub mod metrics;
pub mod prefixcache;
pub mod profile;
pub mod repro;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod specdec;
pub mod subvocab;
pub mod testutil;
pub mod tp;
pub mod trace;
pub mod workload;

/// Crate-wide result type (library errors carry context via `anyhow`).
pub type Result<T> = anyhow::Result<T>;
