//! Exact verification of drafted tokens — both constructions (DESIGN.md
//! §9):
//!
//! * [`Verifier::verify_row`] — the Chen et al. accept/reject recurrence
//!   over materialized target logits: accept draft token `x_i` with
//!   probability `min(1, p_i(x_i) / q_i(x_i))`; on the first rejection,
//!   resample from the residual `(p_i − q_i)₊` via **Gumbel argmax on the
//!   adjusted logits** `ln (p_i − q_i)₊`, then stop; if all K drafts
//!   survive, draw the bonus token from `p_{K+1}` with the target's
//!   ordinary Gumbel draw.  Every random decision is a deterministic
//!   function of Philox coordinates, so runs replay exactly from
//!   `(key, row, step)`.
//! * [`coupled_emit_len`] — the Gumbel-coupled token-matching rule for
//!   sample-only backends (the AOT decode artifacts emit samples, never
//!   logits): the target is sampled once per drafted prefix with fresh
//!   noise, the emitted tokens are the target's own samples, and the draft
//!   merely gates how many of those speculated samples were conditioned on
//!   the right prefix.  Output tokens are literally target samples given
//!   their prefixes, so exactness is immediate from the chain rule.
//!
//! Stream layout per `(row, step)`: accept uniforms on
//! [`philox::STREAM_SPEC_ACCEPT`] at counter `i` = draft position; the
//! residual resample and the bonus draw share the target's
//! `STREAM_GUMBEL` coordinates `(·, row, step)` — at most one of the two
//! occurs per verify round, so they never collide.

use super::draft::DraftProposal;
use crate::sampling::philox::{self, Key};
use crate::sampling::{gumbel, multinomial, Transform};

/// The accept/reject verifier (host logits path).
#[derive(Clone, Copy, Debug)]
pub struct Verifier {
    /// Verifier RNG key — the serving session key on the engine path.
    pub key: Key,
}

/// Outcome of one verify round.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyOutcome {
    /// Emitted tokens: the accepted draft prefix plus one more token (the
    /// residual resample on rejection, the bonus draw on full acceptance).
    /// Always non-empty; `tokens.len() == accepted + 1`.
    pub tokens: Vec<i32>,
    /// How many drafted tokens were accepted.
    pub accepted: usize,
    /// All drafts accepted ⇒ the last token is the bonus draw from the
    /// K+1-th target distribution.
    pub bonus: bool,
}

impl Verifier {
    /// Run the accept/reject recurrence for one row.
    ///
    /// `target_logits` holds K+1 rows of raw target logits: row `i` is the
    /// target distribution after accepting `i` draft tokens (the batched
    /// target pass over the draft prefixes), row K feeds the bonus draw.
    /// `target` is the row's logit transform (temperature / bias);
    /// `proposal.logits` are final draft logits (`q_i = softmax`, no
    /// further transform — see [`DraftProposal::logits`]).
    ///
    /// Panics if the target distribution has no support (all `-inf` row) —
    /// the same contract as `ExactSampler` callers treating `None` as an
    /// error.
    pub fn verify_row(
        &self,
        target_logits: &[Vec<f32>],
        target: &Transform,
        proposal: &DraftProposal,
        row: u32,
        step: u32,
    ) -> VerifyOutcome {
        assert_eq!(
            target_logits.len(),
            proposal.len() + 1,
            "verify needs K+1 target rows for K drafted tokens"
        );
        let ident = Transform::default();
        let mut tokens = Vec::with_capacity(proposal.len() + 1);
        for (i, &x) in proposal.tokens.iter().enumerate() {
            let p = multinomial::probs(&target_logits[i], target);
            let q = multinomial::probs(&proposal.logits[i], &ident);
            let (px, qx) = (p[x as usize], q[x as usize]);
            debug_assert!(qx > 0.0, "draft token outside its own support");
            let u = philox::uniform_at(
                self.key,
                i as u32,
                row,
                philox::STREAM_SPEC_ACCEPT,
                step,
            ) as f64;
            // u <= min(1, px/qx)  ⇔  u·qx <= px   (qx > 0).
            if u * qx <= px {
                tokens.push(x);
                continue;
            }
            // First rejection: Gumbel-argmax the adjusted logits
            // ln (p − q)₊ — the residual distribution of the coupling.
            let resid: Vec<f32> = p
                .iter()
                .zip(&q)
                .map(|(&pv, &qv)| {
                    let r = pv - qv;
                    if r > 0.0 { r.ln() as f32 } else { f32::NEG_INFINITY }
                })
                .collect();
            let draw = gumbel::sample_row(&resid, &ident, self.key, row, step)
                // Numerically-empty residual (p == q to f64 precision yet
                // the ratio test rejected): fall back to the plain target
                // draw, which is the correct limit of the residual as
                // q → p.
                .or_else(|| {
                    gumbel::sample_row(&target_logits[i], target, self.key, row, step)
                })
                .expect("target distribution has support");
            tokens.push(draw.index as i32);
            return VerifyOutcome { accepted: i, tokens, bonus: false };
        }
        // Every draft accepted: bonus token from the K+1-th distribution,
        // drawn exactly as the target's ordinary decode draw at this
        // (row, step) would be.
        let k = proposal.len();
        let draw = gumbel::sample_row(&target_logits[k], target, self.key, row, step)
            .expect("target distribution has support");
        tokens.push(draw.index as i32);
        VerifyOutcome { accepted: k, tokens, bonus: true }
    }
}

/// Gumbel-coupled token-matching verification for sample-only backends
/// (the engine's AOT decode artifacts): given the target's sampled token
/// `y_j` at each drafted prefix (fresh noise per position), the emitted
/// tokens are `y_0..y_m` where `m` is the first index with
/// `y_m != draft[m]` (all K matched ⇒ K+1 tokens).  Returns how many
/// leading `target_samples` to emit — always in `1..=draft.len() + 1`.
pub fn coupled_emit_len(draft: &[i32], target_samples: &[i32]) -> usize {
    assert_eq!(
        target_samples.len(),
        draft.len() + 1,
        "coupled verification needs one target sample per drafted prefix"
    );
    let mut m = 0;
    while m < draft.len() && target_samples[m] == draft[m] {
        m += 1;
    }
    m + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::stats;

    const V: usize = 16;

    fn peaked(argmax: usize) -> Vec<f32> {
        let mut l = vec![-20.0f32; V];
        l[argmax] = 20.0;
        l
    }

    fn one_hot_proposal(token: i32) -> DraftProposal {
        let mut logits = vec![f32::NEG_INFINITY; V];
        logits[token as usize] = 0.0;
        let mut p = DraftProposal::default();
        p.push(token, logits);
        p
    }

    #[test]
    fn coupled_emit_len_rules() {
        assert_eq!(coupled_emit_len(&[], &[9]), 1);
        assert_eq!(coupled_emit_len(&[5], &[5, 7]), 2);
        assert_eq!(coupled_emit_len(&[5], &[6, 7]), 1);
        assert_eq!(coupled_emit_len(&[1, 2, 3], &[1, 2, 3, 4]), 4);
        assert_eq!(coupled_emit_len(&[1, 2, 3], &[1, 9, 3, 4]), 2);
    }

    #[test]
    fn matching_one_hot_draft_is_always_accepted() {
        // q one-hot on the target's ~certain token: accept prob ≈ 1.
        let v = Verifier { key: Key::new(8, 9) };
        let t = Transform::default();
        let target = vec![peaked(3), peaked(5)];
        for step in 0..50 {
            let out = v.verify_row(&target, &t, &one_hot_proposal(3), 0, step);
            assert_eq!(out.accepted, 1);
            assert!(out.bonus);
            assert_eq!(out.tokens[0], 3);
            assert_eq!(out.tokens[1], 5); // bonus from the peaked row 1
        }
    }

    #[test]
    fn wrong_one_hot_draft_is_rejected_and_resampled_off_itself() {
        // q one-hot on a ~zero-probability token: reject, and the residual
        // (p − q)₊ has zero mass at the drafted token, so the resample can
        // never return it.
        let v = Verifier { key: Key::new(4, 7) };
        let t = Transform::default();
        let target = vec![peaked(3), peaked(5)];
        for step in 0..50 {
            let out = v.verify_row(&target, &t, &one_hot_proposal(9), 0, step);
            assert_eq!(out.accepted, 0);
            assert!(!out.bonus);
            assert_eq!(out.tokens.len(), 1);
            assert_ne!(out.tokens[0], 9);
            assert_eq!(out.tokens[0], 3); // the peaked target's mass
        }
    }

    #[test]
    fn empty_proposal_degenerates_to_one_target_draw() {
        let v = Verifier { key: Key::new(1, 2) };
        let t = Transform::default();
        let out =
            v.verify_row(&[peaked(7)], &t, &DraftProposal::default(), 0, 0);
        assert_eq!(out.tokens, vec![7]);
        assert_eq!(out.accepted, 0);
        assert!(out.bonus);
    }

    #[test]
    fn deterministic_in_the_philox_coordinates() {
        let v = Verifier { key: Key::new(21, 12) };
        let t = Transform::default();
        let logits: Vec<f32> = (0..V).map(|i| (i as f32 * 0.37).sin()).collect();
        let target = vec![logits.clone(), logits];
        let p = one_hot_proposal(2);
        let a = v.verify_row(&target, &t, &p, 3, 11);
        let b = v.verify_row(&target, &t, &p, 3, 11);
        assert_eq!(a, b);
    }

    /// Marginal exactness of the first emitted token: whatever the (fixed)
    /// one-hot proposal, accept + residual must compose to exactly `p` —
    /// chi-squared against the probs-space oracle.
    #[test]
    fn first_token_marginal_matches_target_distribution() {
        let v = Verifier { key: Key::new(0x5E, 0xC7) };
        let t = Transform::default();
        let key = Key::new(0xAB, 0xCD);
        let logits: Vec<f32> = (0..V)
            .map(|i| 2.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect();
        let oracle = multinomial::probs(&logits, &t);
        let n = 6000u32;
        // Draft a mid-probability token so both branches fire often.
        let drafted = 5i32;
        let mut counts = vec![0u64; V];
        for step in 0..n {
            let target = vec![logits.clone(), logits.clone()];
            let out =
                v.verify_row(&target, &t, &one_hot_proposal(drafted), 0, step);
            counts[out.tokens[0] as usize] += 1;
        }
        let p = stats::chi_squared_pvalue(&counts, &oracle, n as u64);
        assert!(p > 0.001, "accept/reject distorts the marginal: p = {p}");
        // Both branches actually fired.
        assert!(counts[drafted as usize] > 0);
        assert!(counts.iter().enumerate().any(|(i, &c)| i != drafted as usize && c > 0));
    }
}
