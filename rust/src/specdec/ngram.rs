//! Deterministic n-gram / suffix-match drafter.
//!
//! The cheapest useful drafter (the "prompt lookup decoding" family): find
//! the longest suffix of the context, up to order `n`, that reoccurs
//! earlier in the context, and propose whatever followed its most recent
//! earlier occurrence; repeat on the extended context for up to K tokens.
//! Needs no model, no weights, and no randomness — its proposal
//! distribution is a point mass (one-hot `q`), which makes the accept
//! ratio simply `p(x)` and keeps the engine's coupled verification exact
//! without any drafter noise bookkeeping.
//!
//! Great on repetitive continuations (code, tables, quoted spans), useless
//! on fresh text — exactly the acceptance-rate spread the spec-decode
//! bench and the TPOT model explore.

use super::draft::{DraftModel, DraftProposal};

/// Suffix-match drafter of maximum order `n` over a vocabulary of size
/// `vocab` (needed to shape the one-hot proposal distributions).
#[derive(Clone, Copy, Debug)]
pub struct NGramDraft {
    /// Maximum suffix order to try (longest match wins).
    pub n: usize,
    pub vocab: usize,
}

impl NGramDraft {
    /// The continuation after the most recent earlier occurrence of the
    /// longest reoccurring suffix (order `n` down to 1); `None` when no
    /// suffix reoccurs.
    fn continuation(&self, ctx: &[i32]) -> Option<i32> {
        let max_order = self.n.min(ctx.len().saturating_sub(1));
        for order in (1..=max_order).rev() {
            let suffix = &ctx[ctx.len() - order..];
            for start in (0..ctx.len() - order).rev() {
                if &ctx[start..start + order] == suffix {
                    return Some(ctx[start + order]);
                }
            }
        }
        None
    }
}

impl DraftModel for NGramDraft {
    fn name(&self) -> &'static str {
        "ngram"
    }

    fn draft(&mut self, ctx: &[i32], k: usize, _row: u32, _step: u32) -> DraftProposal {
        let mut ext = ctx.to_vec();
        let mut out = DraftProposal::default();
        for _ in 0..k {
            let Some(t) = self.continuation(&ext) else { break };
            if t < 0 || t as usize >= self.vocab {
                break; // out-of-vocab context token: stop drafting
            }
            let mut logits = vec![f32::NEG_INFINITY; self.vocab];
            logits[t as usize] = 0.0;
            ext.push(t);
            out.push(t, logits);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draft(ctx: &[i32], n: usize, k: usize) -> DraftProposal {
        NGramDraft { n, vocab: 100 }.draft(ctx, k, 0, 0)
    }

    #[test]
    fn repeating_context_proposes_the_continuation() {
        // ... 7 3 | 7 3 ⇒ suffix [7, 3] last seen at 0..2, followed by 7.
        let p = draft(&[7, 3, 7, 3], 2, 3);
        assert_eq!(p.tokens, vec![7, 3, 7]); // period-2 loop extends itself
        // One-hot proposal distributions on the proposed tokens.
        for (i, &t) in p.tokens.iter().enumerate() {
            assert_eq!(p.logits[i][t as usize], 0.0);
            let live = p.logits[i].iter().filter(|l| l.is_finite()).count();
            assert_eq!(live, 1);
        }
    }

    #[test]
    fn fresh_context_proposes_nothing() {
        assert!(draft(&[1, 2, 3, 4, 5], 3, 4).is_empty());
        assert!(draft(&[], 3, 4).is_empty());
        assert!(draft(&[9], 3, 4).is_empty());
    }

    #[test]
    fn longest_suffix_order_wins() {
        // Suffix [5]: most recent earlier 5 is followed by 8.
        // Suffix [2, 5]: earlier occurrence followed by 6.  Order 2 must win.
        let ctx = [2, 5, 6, 5, 8, 2, 5];
        assert_eq!(draft(&ctx, 2, 1).tokens, vec![6]);
        // Capping the order at 1 falls back to the unigram continuation.
        assert_eq!(draft(&ctx, 1, 1).tokens, vec![8]);
    }

    #[test]
    fn respects_k_and_extends_its_own_proposals() {
        let p = draft(&[1, 2, 1, 2], 2, 8);
        assert_eq!(p.len(), 8);
        // Period-2 context keeps alternating.
        assert_eq!(p.tokens, vec![1, 2, 1, 2, 1, 2, 1, 2]);
        assert!(draft(&[1, 2, 1, 2], 2, 0).is_empty());
    }
}
