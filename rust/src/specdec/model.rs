//! Target/draft model abstraction for speculative decoding.
//!
//! The spec-decode core is written against [`LogitModel`] — anything that
//! can produce next-token logits for a token context.  On the serving path
//! the "model" is the fused decode artifact (which emits *samples*, not
//! logits — see `crate::specdec::verify::coupled_emit_len` for the
//! verification rule that works there); on the host paths (the
//! `specdec-chisq` repro experiment, `benches/specdec.rs`, the greedy
//! identity integration test) it is one of the deterministic models below:
//!
//! * [`HashModel`] — a synthetic LM whose next-token distribution depends
//!   on the recent context through Philox hashing.  Deterministic,
//!   context-sensitive, and cheap — the standard target/drafter fixture.
//! * [`Blend`] — log-space interpolation of two models; benchmarks dial
//!   the draft/target agreement (and therefore the acceptance rate) with
//!   the blend weight.

use crate::sampling::philox::{self, Key};

/// Anything that can score token contexts with next-token logits.
pub trait LogitModel: Send + Sync {
    /// Vocabulary size (the length of every logits row).
    fn vocab(&self) -> usize;

    /// Next-token logits `[V]` given the context (prompt + generated).
    fn logits(&self, ctx: &[i32]) -> Vec<f32>;

    /// Score many contexts at once — the verifier's single batched target
    /// pass over the K+1 draft prefixes.  The default maps [`Self::logits`];
    /// batched backends (a real model executing one `[K+1, T]` scoring
    /// pass) override it.
    fn logits_batch(&self, ctxs: &[Vec<i32>]) -> Vec<Vec<f32>> {
        ctxs.iter().map(|c| self.logits(c)).collect()
    }
}

/// Deterministic synthetic LM: the last [`order`](HashModel::order) context
/// tokens are Philox-hashed into a stream selector, and every vocabulary
/// entry draws its logit from that stream — so the next-token distribution
/// genuinely depends on the context (an n-gram-ish language) while staying
/// reproducible from `(seed, ctx)` alone.
#[derive(Clone, Copy, Debug)]
pub struct HashModel {
    pub vocab: usize,
    /// How many trailing context tokens enter the hash.
    pub order: usize,
    /// Logit spread: logits are uniform in `(-scale/2, scale/2)`.
    pub scale: f32,
    pub key: Key,
}

impl HashModel {
    pub fn new(vocab: usize, order: usize, seed: u64) -> Self {
        Self { vocab, order, scale: 3.0, key: Key::from_seed(seed) }
    }

    /// Hash the last `order` context tokens into a 2-word stream selector.
    fn ctx_hash(&self, ctx: &[i32]) -> [u32; 2] {
        let mut h = [0x243F_6A88u32, 0x85A3_08D3];
        for &t in ctx.iter().rev().take(self.order) {
            let out = philox::philox4x32_10(
                [t as u32, h[0], h[1], 0x5EED],
                [self.key.lo, self.key.hi],
            );
            h = [out[0], out[1]];
        }
        h
    }
}

impl LogitModel for HashModel {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn logits(&self, ctx: &[i32]) -> Vec<f32> {
        let h = self.ctx_hash(ctx);
        (0..self.vocab)
            .map(|v| {
                let r = philox::philox4x32_10(
                    [v as u32, h[0], h[1], 0x10D5],
                    [self.key.lo, self.key.hi],
                )[0];
                self.scale * (philox::uniform_open01(r) - 0.5)
            })
            .collect()
    }
}

/// Log-space interpolation of two models: `w·a + (1-w)·b` per logit.
/// `w = 1` is model `a` exactly; lowering `w` degrades a drafter's
/// agreement with the target — the acceptance-rate dial the spec-decode
/// bench sweeps.
#[derive(Clone, Copy, Debug)]
pub struct Blend<A, B> {
    pub a: A,
    pub b: B,
    pub w: f32,
}

impl<A: LogitModel, B: LogitModel> LogitModel for Blend<A, B> {
    fn vocab(&self) -> usize {
        let v = self.a.vocab();
        assert_eq!(v, self.b.vocab(), "blended models must share a vocab");
        v
    }

    fn logits(&self, ctx: &[i32]) -> Vec<f32> {
        let la = self.a.logits(ctx);
        let lb = self.b.logits(ctx);
        la.iter()
            .zip(&lb)
            .map(|(&x, &y)| self.w * x + (1.0 - self.w) * y)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_model_is_deterministic_and_context_sensitive() {
        let m = HashModel::new(64, 3, 7);
        let a = m.logits(&[1, 2, 3]);
        assert_eq!(a.len(), 64);
        assert_eq!(a, m.logits(&[1, 2, 3]));
        // A different trailing token changes the distribution.
        assert_ne!(a, m.logits(&[1, 2, 4]));
        // Tokens beyond the hash window are ignored (order-3 language).
        assert_eq!(a, m.logits(&[9, 9, 1, 2, 3]));
        // A different seed is a different language.
        assert_ne!(a, HashModel::new(64, 3, 8).logits(&[1, 2, 3]));
    }

    #[test]
    fn logits_stay_in_the_documented_range() {
        let m = HashModel::new(128, 2, 3);
        for l in m.logits(&[5]) {
            assert!(l > -1.5 && l < 1.5, "{l}");
        }
    }

    #[test]
    fn batch_default_matches_single_calls() {
        let m = HashModel::new(32, 2, 11);
        let ctxs = vec![vec![1], vec![1, 2], vec![3, 4, 5]];
        let batch = m.logits_batch(&ctxs);
        for (c, row) in ctxs.iter().zip(&batch) {
            assert_eq!(row, &m.logits(c));
        }
    }

    #[test]
    fn blend_endpoints_reproduce_the_parts() {
        let a = HashModel::new(16, 2, 1);
        let b = HashModel::new(16, 2, 2);
        let ctx = [4, 2];
        let full = Blend { a, b, w: 1.0 };
        assert_eq!(full.vocab(), 16);
        assert_eq!(full.logits(&ctx), a.logits(&ctx));
        let none = Blend { a, b, w: 0.0 };
        assert_eq!(none.logits(&ctx), b.logits(&ctx));
        let mid = Blend { a, b, w: 0.5 };
        let (la, lb, lm) = (a.logits(&ctx), b.logits(&ctx), mid.logits(&ctx));
        for i in 0..16 {
            assert!((lm[i] - 0.5 * (la[i] + lb[i])).abs() < 1e-6);
        }
    }
}
