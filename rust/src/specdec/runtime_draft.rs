//! Model-backed drafter: a (typically smaller / cheaper) [`LogitModel`]
//! head sampled autoregressively on its own Philox streams.
//!
//! This is the classic two-model speculative setup (Chen et al.): the
//! draft head proposes `x_j ~ q_j = softmax(logits_draft / tau)` for K
//! positions, and the verifier replays the accept/reject recurrence
//! against the target's `p_j`.  The drafter's Gumbel draws live on stream
//! [`philox::STREAM_SPEC_DRAFT`]` + j` — independent of the verifier's
//! accept uniforms and of the target's own epilogue stream at the same
//! `(row, step)`, which is what the exactness proof requires.

use super::draft::{DraftModel, DraftProposal};
use super::model::LogitModel;
use crate::sampling::philox::{self, Key};
use crate::sampling::Transform;

/// Drafter backed by a [`LogitModel`] head sampled at temperature `tau`.
#[derive(Clone, Debug)]
pub struct RuntimeDraft<M: LogitModel> {
    pub model: M,
    /// Draft temperature (folded into the proposal's final logits, so the
    /// verifier's `q = softmax(proposal.logits[i])` needs no extra
    /// transform).
    pub tau: f32,
    /// The drafter's own RNG key (independent of the verifier's key by
    /// construction of the stream layout, but a distinct key keeps
    /// drafter reproducibility independent of the serving session seed).
    pub key: Key,
}

impl<M: LogitModel> RuntimeDraft<M> {
    pub fn new(model: M, tau: f32, key: Key) -> Self {
        Self { model, tau, key }
    }
}

impl<M: LogitModel> DraftModel for RuntimeDraft<M> {
    fn name(&self) -> &'static str {
        "runtime"
    }

    fn draft(&mut self, ctx: &[i32], k: usize, row: u32, step: u32) -> DraftProposal {
        let t = Transform::with_temperature(self.tau);
        let mut ext = ctx.to_vec();
        let mut out = DraftProposal::default();
        for j in 0..k {
            let raw = self.model.logits(&ext);
            // Final draft logits: temperature folded once, here.
            let y: Vec<f32> =
                raw.iter().enumerate().map(|(v, &l)| t.apply(l, v)).collect();
            // Gumbel-argmax on the per-position draft stream.
            let stream = philox::STREAM_SPEC_DRAFT + j as u32;
            let mut best = f32::NEG_INFINITY;
            let mut best_v: i64 = -1;
            for (v, &yv) in y.iter().enumerate() {
                if yv == f32::NEG_INFINITY {
                    continue;
                }
                let u = philox::uniform_at(self.key, v as u32, row, stream, step);
                let g = -(-(u.ln())).ln();
                let s = yv + g;
                if s > best {
                    best = s;
                    best_v = v as i64;
                }
            }
            if best_v < 0 {
                break; // zero-mass draft distribution: stop proposing
            }
            ext.push(best_v as i32);
            out.push(best_v as i32, y);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::specdec::model::HashModel;

    #[test]
    fn drafts_are_deterministic_and_in_vocab() {
        let m = HashModel::new(64, 3, 5);
        let mut d = RuntimeDraft::new(m, 1.0, Key::new(3, 4));
        let a = d.draft(&[1, 2, 3], 4, 0, 7);
        assert_eq!(a.len(), 4);
        assert!(a.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(a, d.draft(&[1, 2, 3], 4, 0, 7));
        // Fresh step ⇒ (virtually surely) different proposal somewhere.
        let mut any = false;
        for s in 8..40 {
            if d.draft(&[1, 2, 3], 4, 0, s).tokens != a.tokens {
                any = true;
                break;
            }
        }
        assert!(any, "drafter never varied across steps");
    }

    #[test]
    fn tiny_temperature_drafts_the_argmax_chain() {
        // tau = 1e-6: even the smallest top-2 logit gap along this chain
        // (≈ 4.7e-4, checked by simulation) scales to ≫ the Gumbel noise
        // spread, so the drafted chain is the argmax chain deterministically.
        let m = HashModel::new(64, 3, 5);
        let mut d = RuntimeDraft::new(m, 1e-6, Key::new(1, 1));
        let p = d.draft(&[9, 8], 3, 2, 3);
        // Greedy: each proposal is the model's argmax on the growing ctx.
        let mut ctx = vec![9, 8];
        for &t in &p.tokens {
            let l = m.logits(&ctx);
            let argmax = (0..64).max_by(|&a, &b| l[a].total_cmp(&l[b])).unwrap();
            assert_eq!(t, argmax as i32);
            ctx.push(t);
        }
    }

    #[test]
    fn proposal_logits_carry_the_temperature() {
        let m = HashModel::new(32, 2, 9);
        let mut d = RuntimeDraft::new(m, 2.0, Key::new(2, 2));
        let p = d.draft(&[4], 1, 0, 0);
        let raw = m.logits(&[4]);
        for v in 0..32 {
            assert!((p.logits[0][v] - raw[v] / 2.0).abs() < 1e-6);
        }
        // The support invariant: the drafted token is live in q.
        assert!(p.logits[0][p.tokens[0] as usize].is_finite());
    }
}
