//! The speculative decode loop: draft → one batched target pass → exact
//! verify, repeated until the budget is spent.
//!
//! This is the logits-space instantiation of the loop (host backends /
//! [`LogitModel`]); `coordinator::engine::Engine` runs the same
//! round structure against the sample-only AOT artifacts with the coupled
//! verification rule (`crate::specdec::verify::coupled_emit_len`) — see
//! DESIGN.md §9 for why both emit exactly the target distribution.

use super::draft::DraftModel;
use super::model::LogitModel;
use super::verify::Verifier;
use crate::sampling::philox::Key;
use crate::sampling::{gumbel, Transform};

/// Spec-decode accounting: enough to derive the two headline rates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecDecodeStats {
    /// Engine rounds (one draft + one batched verify each).
    pub rounds: u64,
    /// Tokens drafted in total.
    pub drafted: u64,
    /// Drafted tokens accepted by the verifier.
    pub accepted: u64,
    /// Tokens emitted (accepted + resample/bonus, clipped to the budget).
    pub emitted: u64,
    /// Rounds in which every draft survived and a bonus token was drawn.
    pub bonus: u64,
}

impl SpecDecodeStats {
    /// Fraction of drafted tokens accepted (0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean tokens emitted per round — the spec-decode speedup currency
    /// (1 ⇒ no better than ordinary decode, K+1 ⇒ every draft accepted).
    pub fn tokens_per_step(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.emitted as f64 / self.rounds as f64
        }
    }
}

/// One generated sequence plus its accounting.
#[derive(Clone, Debug)]
pub struct SpecDecodeResult {
    pub tokens: Vec<i32>,
    pub stats: SpecDecodeStats,
}

/// The speculative decode loop over a [`LogitModel`] target.
pub struct SpecDecodeLoop<'a> {
    pub target: &'a dyn LogitModel,
    pub drafter: &'a mut dyn DraftModel,
    /// Target logit transform (temperature; bias folds in as anywhere
    /// else).
    pub transform: Transform,
    /// Maximum draft length per round (the K of `specdec:k=K`).
    pub k: usize,
    /// Verifier key — plays the role of the engine session seed.
    pub key: Key,
}

impl SpecDecodeLoop<'_> {
    /// Generate exactly `max_new` tokens continuing `prompt`.  `row` is
    /// the Philox row coordinate (batch slot); `step` starts at 0 and
    /// advances once per round, so a generation replays exactly from
    /// `(key, row)`.
    pub fn generate(&mut self, prompt: &[i32], max_new: usize, row: u32) -> SpecDecodeResult {
        let verifier = Verifier { key: self.key };
        let mut generated: Vec<i32> = Vec::with_capacity(max_new);
        let mut stats = SpecDecodeStats::default();
        let mut step = 0u32;
        while generated.len() < max_new {
            let mut ctx: Vec<i32> =
                Vec::with_capacity(prompt.len() + generated.len());
            ctx.extend_from_slice(prompt);
            ctx.extend_from_slice(&generated);
            // Never draft past the budget: the verifier always emits the
            // accepted prefix plus one, so at most remaining−1 drafts.
            let k = self.k.min(max_new - generated.len() - 1);
            let proposal = self.drafter.draft(&ctx, k, row, step);
            // THE batched target pass: score all K+1 draft prefixes at
            // once (on a real backend this is one forward over the
            // drafted tokens, not K+1 sequential decodes).
            let mut prefixes: Vec<Vec<i32>> =
                Vec::with_capacity(proposal.len() + 1);
            prefixes.push(ctx);
            for &x in &proposal.tokens {
                let mut next = prefixes.last().unwrap().clone();
                next.push(x);
                prefixes.push(next);
            }
            let target_logits = self.target.logits_batch(&prefixes);
            let out =
                verifier.verify_row(&target_logits, &self.transform, &proposal, row, step);
            stats.rounds += 1;
            stats.drafted += proposal.len() as u64;
            stats.accepted += out.accepted as u64;
            stats.bonus += u64::from(out.bonus);
            for t in out.tokens {
                if generated.len() == max_new {
                    break;
                }
                generated.push(t);
                stats.emitted += 1;
            }
            step += 1;
        }
        SpecDecodeResult { tokens: generated, stats }
    }
}

/// The non-speculative reference: one target Gumbel draw per step, `step`
/// advancing once per token.  Spec decode must match this in distribution
/// — and token-for-token in the greedy (`tau → 0`) limit, where noise
/// cannot flip any argmax (asserted by `tests/specdec.rs`).
pub fn baseline_generate(
    target: &dyn LogitModel,
    transform: &Transform,
    key: Key,
    prompt: &[i32],
    max_new: usize,
    row: u32,
) -> Vec<i32> {
    let mut generated: Vec<i32> = Vec::with_capacity(max_new);
    for step in 0..max_new as u32 {
        let mut ctx: Vec<i32> = Vec::with_capacity(prompt.len() + generated.len());
        ctx.extend_from_slice(prompt);
        ctx.extend_from_slice(&generated);
        let logits = target.logits(&ctx);
        let d = gumbel::sample_row(&logits, transform, key, row, step)
            .expect("target distribution has support");
        generated.push(d.index as i32);
    }
    generated
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::specdec::model::HashModel;
    use crate::specdec::ngram::NGramDraft;
    use crate::specdec::runtime_draft::RuntimeDraft;

    const V: usize = 64;

    #[test]
    fn generates_exactly_the_budget_and_consistent_stats() {
        let target = HashModel::new(V, 3, 0x70);
        let mut drafter = RuntimeDraft::new(HashModel::new(V, 3, 0x71), 1.0, Key::new(5, 6));
        let mut l = SpecDecodeLoop {
            target: &target,
            drafter: &mut drafter,
            transform: Transform::default(),
            k: 4,
            key: Key::new(9, 9),
        };
        for budget in [1usize, 2, 5, 33] {
            let r = l.generate(&[3, 1, 4], budget, 0);
            assert_eq!(r.tokens.len(), budget);
            assert!(r.tokens.iter().all(|&t| (0..V as i32).contains(&t)));
            assert_eq!(r.stats.emitted, budget as u64);
            // Each round emits accepted+1 (clipping only drops tokens, so
            // emitted <= accepted + rounds).
            assert!(r.stats.emitted <= r.stats.accepted + r.stats.rounds);
            assert!(r.stats.rounds >= 1);
            assert!(r.stats.accepted <= r.stats.drafted);
        }
    }

    #[test]
    fn replays_exactly_from_the_key() {
        let target = HashModel::new(V, 2, 0x72);
        let run = || {
            let mut drafter = NGramDraft { n: 3, vocab: V };
            let mut l = SpecDecodeLoop {
                target: &target,
                drafter: &mut drafter,
                transform: Transform::with_temperature(1.3),
                k: 3,
                key: Key::new(2, 8),
            };
            l.generate(&[7, 7, 7], 24, 1)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn self_drafting_accepts_everything() {
        // Draft with the target itself at the SAME temperature: q == p, so
        // min(1, p/q) = 1 and every draft is accepted — acceptance 1.0 and
        // K+1 tokens per round (modulo the budget tail).
        let target = HashModel::new(V, 3, 0x73);
        let mut drafter = RuntimeDraft::new(target, 1.0, Key::new(4, 4));
        let mut l = SpecDecodeLoop {
            target: &target,
            drafter: &mut drafter,
            transform: Transform::default(),
            k: 4,
            key: Key::new(6, 1),
        };
        let r = l.generate(&[2, 4, 6], 25, 0); // 5 full rounds of K+1
        assert_eq!(r.tokens.len(), 25);
        assert!((r.stats.acceptance_rate() - 1.0).abs() < 1e-12, "{:?}", r.stats);
        assert!((r.stats.tokens_per_step() - 5.0).abs() < 1e-12, "{:?}", r.stats);
    }

    #[test]
    fn stats_rates_handle_empty_denominators() {
        let s = SpecDecodeStats::default();
        assert_eq!(s.acceptance_rate(), 0.0);
        assert_eq!(s.tokens_per_step(), 0.0);
    }

    #[test]
    fn baseline_is_deterministic_and_step_indexed() {
        let target = HashModel::new(V, 3, 0x74);
        let t = Transform::default();
        let a = baseline_generate(&target, &t, Key::new(1, 2), &[5, 5], 16, 0);
        let b = baseline_generate(&target, &t, Key::new(1, 2), &[5, 5], 16, 0);
        assert_eq!(a, b);
        let c = baseline_generate(&target, &t, Key::new(1, 3), &[5, 5], 16, 0);
        assert_ne!(a, c);
    }
}
