//! Speculative decoding with Gumbel-coupled **exact** verification
//! (DESIGN.md §9).
//!
//! Spec decode (Chen et al., *Accelerating Large Language Model Decoding
//! with Speculative Sampling*) hides decode latency by letting a cheap
//! **drafter** propose K tokens, then verifying all K in one batched
//! target pass: accepted prefixes cost one target step for up to K+1
//! tokens.  The whole scheme is only admissible here because FlashSampling
//! makes the verification *exact*: every accept/reject uniform, residual
//! resample, and bonus draw is a deterministic function of Philox
//! coordinates, so the output is provably distributed as the target model
//! — and replayable token-for-token from `(seed, row, step)`.  The
//! `repro specdec-chisq` experiment (`crate::repro::quality::specdec_chisq`)
//! checks the claim with the same chi-squared machinery as the mixed-tau
//! batches.
//!
//! # Layout
//!
//! | Module | Contents |
//! |---|---|
//! | [`model`] | [`LogitModel`] abstraction + deterministic test models |
//! | [`draft`] | [`DraftModel`] trait, [`DraftProposal`] (tokens + q) |
//! | [`ngram`] | [`NGramDraft`] — deterministic suffix drafter, one-hot q |
//! | [`runtime_draft`] | [`RuntimeDraft`] — smaller-head drafter, q = softmax |
//! | [`verify`] | [`Verifier`] accept/reject + [`coupled_emit_len`] |
//! | [`decode`] | [`SpecDecodeLoop`], [`baseline_generate`], stats |
//!
//! # The two verifier instantiations
//!
//! * **Logits path** ([`Verifier::verify_row`]): accept draft `x_i` with
//!   probability `min(1, p_i(x_i)/q_i(x_i))`; on first rejection resample
//!   from the residual `(p_i − q_i)₊` by Gumbel argmax on the adjusted
//!   logits — the standard recurrence, with all noise on dedicated Philox
//!   streams (`STREAM_SPEC_ACCEPT`, `STREAM_SPEC_DRAFT + j`).
//! * **Sample path** ([`coupled_emit_len`]): the AOT decode artifacts emit
//!   samples, never logits, so `coordinator::engine` instead
//!   samples the target once per drafted prefix (fresh noise each inner
//!   pass) and emits the target's own samples while they agree with the
//!   draft — Gumbel coupling through the shared deterministic noise makes
//!   every emitted token an exact target sample given its prefix.
//!
//! Both constructions emit 1..=K+1 tokens per round and leave the output
//! distribution identical to non-speculative decoding; the drafter only
//! moves the acceptance rate.  Engine selection:
//! `sampler = specdec:k=4,ngram=3` (a `SamplerSpec` variant — see
//! `crate::sampling::SamplerSpec::SpecDecode`).

pub mod decode;
pub mod draft;
pub mod model;
pub mod ngram;
pub mod runtime_draft;
pub mod verify;

pub use decode::{baseline_generate, SpecDecodeLoop, SpecDecodeResult, SpecDecodeStats};
pub use draft::{DraftModel, DraftProposal};
pub use model::{Blend, HashModel, LogitModel};
pub use ngram::NGramDraft;
pub use runtime_draft::RuntimeDraft;
pub use verify::{coupled_emit_len, Verifier, VerifyOutcome};

/// Default draft length K (`specdec:k=4`): the sweet spot of the modeled
/// TPOT curve at moderate acceptance (`gpusim::tpot::SpecDecodeModel`).
pub const DEFAULT_K: usize = 4;
/// Default n-gram drafter order (`specdec:ngram=3`).
pub const DEFAULT_NGRAM: usize = 3;
