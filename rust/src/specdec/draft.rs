//! The drafting side of speculative decoding: the [`DraftModel`] trait and
//! the proposal type it returns.
//!
//! A drafter proposes up to K continuation tokens for a context, together
//! with the distribution each token was drawn from — `q_i` in the Chen et
//! al. accept/reject recurrence.  Two built-in drafters implement the
//! trait: the deterministic suffix drafter (`crate::specdec::NGramDraft`,
//! one-hot `q`) and the model-backed drafter
//! (`crate::specdec::RuntimeDraft`, `q = softmax` of a smaller head's
//! logits).

/// Up to K drafted tokens plus, for each, the draft distribution it was
/// drawn from.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DraftProposal {
    /// Proposed continuation tokens `x_1..x_k` (possibly fewer than asked).
    pub tokens: Vec<i32>,
    /// `logits[i]` is the **final** draft distribution token `i` was drawn
    /// from (any draft temperature already folded in):
    /// `q_i = softmax(logits[i])`.  `-inf` marks zero support;
    /// `logits[i][tokens[i]]` must be finite — a drafter may only propose
    /// tokens its own distribution could produce (the accept ratio
    /// `p/q` is undefined at `q = 0`).
    pub logits: Vec<Vec<f32>>,
}

impl DraftProposal {
    /// Number of drafted tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Append one drafted token and the distribution it came from.
    pub fn push(&mut self, token: i32, logits: Vec<f32>) {
        debug_assert!(
            logits
                .get(token as usize)
                .is_some_and(|l| l.is_finite()),
            "drafted token must lie in its own support"
        );
        self.tokens.push(token);
        self.logits.push(logits);
    }
}

/// A draft model: proposes candidate continuations for the verifier to
/// accept or reject.
///
/// Exactness contract: the *output* distribution of spec decode never
/// depends on the drafter (only the acceptance rate does), provided the
/// proposal satisfies the [`DraftProposal::logits`] support invariant and
/// any drafter randomness is independent of the verifier's streams.
/// Sampling drafters draw position `j` on Philox stream
/// `crate::sampling::philox::STREAM_SPEC_DRAFT + j`; deterministic
/// drafters ignore the coordinates entirely.
pub trait DraftModel: Send {
    /// Drafter name (metrics / bench labels).
    fn name(&self) -> &'static str;

    /// Propose up to `k` tokens continuing `ctx`.  `row`/`step` are the
    /// Philox coordinates of the enclosing engine step.  Returning fewer
    /// than `k` tokens (or none) is allowed — the round then degenerates
    /// toward ordinary one-token decode.
    fn draft(&mut self, ctx: &[i32], k: usize, row: u32, step: u32) -> DraftProposal;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposal_bookkeeping() {
        let mut p = DraftProposal::default();
        assert!(p.is_empty());
        p.push(2, vec![f32::NEG_INFINITY, 0.0, 1.0]);
        p.push(1, vec![0.5, 0.25, -1.0]);
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
        assert_eq!(p.tokens, vec![2, 1]);
        assert_eq!(p.logits.len(), 2);
    }
}
