//! Roofline + bandwidth-utilization analysis — Figure 6.
//!
//! The LM-head GEMM's arithmetic intensity is ≈ B flops/byte (weights
//! dominate traffic), so the batch sweep walks along the roofline's
//! memory-bound slope toward the ridge at AI ≈ ops:byte (281 on B200).
//! FlashSampling sits above the baselines on both panels because it moves
//! less data and spends no time in separate kernels.

use super::kernelchain::{chain, ChainCost};
use super::specs::GpuSpec;
use super::{Method, Workload};

/// One roofline point.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    pub batch: usize,
    /// Arithmetic intensity, flops per HBM byte.
    pub intensity: f64,
    /// Achieved compute, FLOP/s.
    pub achieved_flops: f64,
    /// Achieved HBM bandwidth / peak.
    pub bw_utilization: f64,
    /// Fraction of the roofline bound actually attained.
    pub roofline_fraction: f64,
}

/// Roofline ceiling at a given intensity.
pub fn roofline_bound(gpu: &GpuSpec, intensity: f64) -> f64 {
    (intensity * gpu.hbm_bw).min(gpu.bf16_flops)
}

fn point(gpu: &GpuSpec, cost: &ChainCost, batch: usize) -> RooflinePoint {
    let t = cost.total();
    let flops = cost.total_flops();
    let bytes = cost.total_traffic();
    let intensity = flops / bytes;
    let achieved = flops / t;
    RooflinePoint {
        batch,
        intensity,
        achieved_flops: achieved,
        bw_utilization: (bytes / t) / gpu.hbm_bw,
        roofline_fraction: achieved / roofline_bound(gpu, intensity),
    }
}

/// Sweep the batch axis for one method (Figure 6 series).
pub fn sweep(gpu: &GpuSpec, method: Method, w_of: impl Fn(usize) -> Workload,
             batches: &[usize]) -> Vec<RooflinePoint> {
    batches
        .iter()
        .map(|&b| point(gpu, &chain(gpu, method, w_of(b), false), b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::B200;

    const BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

    #[test]
    fn intensity_tracks_batch() {
        // AI ≈ B for the LM-head GEMM (paper Appendix H).
        let pts = sweep(&B200, Method::FlashSampling, Workload::small, &BATCHES);
        for p in &pts {
            assert!(
                (p.intensity / p.batch as f64 - 1.0).abs() < 0.3,
                "B={}: AI={}",
                p.batch,
                p.intensity
            );
        }
    }

    #[test]
    fn memory_bound_slope_then_flattening() {
        let pts = sweep(&B200, Method::FlashSampling, Workload::small, &BATCHES);
        // Achieved flops grow ~linearly while memory-bound...
        let r = pts[4].achieved_flops / pts[0].achieved_flops;
        assert!(r > 10.0, "B=16/B=1 achieved ratio {r}");
        // ...but flatten well below the compute ceiling near the ridge
        // (paper: "performance flattens below the compute ceiling").
        let last = pts.last().unwrap();
        assert!(last.achieved_flops < 0.6 * B200.bf16_flops);
    }

    #[test]
    fn flashsampling_dominates_bandwidth_utilization() {
        // Figure 6 right: FS achieves the highest BW utilization in the
        // decode regime.
        for &b in &[1usize, 8, 64] {
            let fs = sweep(&B200, Method::FlashSampling, Workload::small, &[b])[0];
            for m in Method::BASELINES {
                let base = sweep(&B200, m, Workload::small, &[b])[0];
                assert!(
                    fs.bw_utilization > base.bw_utilization,
                    "B={b} vs {m:?}: {} !> {}",
                    fs.bw_utilization,
                    base.bw_utilization
                );
            }
        }
    }

    #[test]
    fn utilization_is_physical() {
        for m in Method::ALL {
            for p in sweep(&B200, m, Workload::small, &BATCHES) {
                assert!(p.bw_utilization > 0.0 && p.bw_utilization <= 1.0);
                assert!(p.roofline_fraction > 0.0 && p.roofline_fraction <= 1.0);
            }
        }
    }
}
