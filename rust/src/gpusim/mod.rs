//! Analytical GPU performance simulator.
//!
//! The paper's evaluation hardware (H100/H200/B200/B300, NVLink meshes) is
//! not available here, so every table and figure is regenerated through an
//! analytical model of the same quantities the paper's own §3.3 cost model
//! and §4.4 analysis reason about:
//!
//! * **IO model** ([`iomodel`]) — the paper's equations verbatim:
//!   M_baseline = VD + DB + 2VB + B, M_fused = VD + DB + B, predicted
//!   speedup ≈ 1 + 2B/D, logits-store overhead 2B/D (Table 9).
//! * **Kernel-chain model** ([`kernelchain`]) — runtime = per-kernel launch
//!   overhead + max(traffic / effective bandwidth, flops / effective
//!   compute).  Baselines pay a *chain* of sampling kernels over
//!   materialized logits; FlashSampling pays one fused kernel + a tiny
//!   reduction.  This reproduces the §4.4 finding that kernel elimination,
//!   not raw traffic, dominates the speedup (Tables 1, 4, 5; Figures 2, 4).
//! * **Interconnect model** ([`interconnect`]) — all-gather vs overlapped
//!   P2P fan-out across TP ranks (Table 6, Figure 3).
//! * **Roofline** ([`roofline`]) — achieved-vs-peak bandwidth and FLOPs
//!   (Figure 6).
//! * **TPOT model** ([`tpot`]) — whole-decode-step composition for the
//!   vLLM-scale models (Tables 7, 8; Figure 5).
//!
//! Calibration targets the paper's *shape* (who wins, by what factor, where
//! the crossovers are), not its absolute microseconds — see EXPERIMENTS.md
//! for side-by-side numbers.

pub mod interconnect;
pub mod iomodel;
pub mod kernelchain;
pub mod roofline;
pub mod specs;
pub mod tpot;

pub use specs::GpuSpec;

/// A sampling method under comparison (the paper's four lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    FlashSampling,
    /// torch.compiled softmax+multinomial chain (Alg. A.1).
    Multinomial,
    /// FlashInfer top-k/top-p sampling kernel over materialized logits.
    Fi1,
    /// FlashInfer Gumbel-Max over materialized logits.
    Fi2,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::FlashSampling => "FlashSampling",
            Method::Multinomial => "Multinomial",
            Method::Fi1 => "FI1",
            Method::Fi2 => "FI2",
        }
    }

    pub const ALL: [Method; 4] =
        [Method::FlashSampling, Method::Multinomial, Method::Fi1, Method::Fi2];

    pub const BASELINES: [Method; 3] =
        [Method::Multinomial, Method::Fi1, Method::Fi2];
}

/// Workload shape of one kernel microbenchmark point.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub batch: usize,
    pub d: usize,
    pub vocab: usize,
}

impl Workload {
    pub fn new(batch: usize, d: usize, vocab: usize) -> Self {
        Self { batch, d, vocab }
    }

    /// The paper's small config (Qwen3-8B-like): D=4096, V=151936.
    pub fn small(batch: usize) -> Self {
        Self::new(batch, 4096, 151_936)
    }

    /// The paper's large config (Llama3-70B-like): D=8192, V=128256.
    pub fn large(batch: usize) -> Self {
        Self::new(batch, 8192, 128_256)
    }
}
