//! Multi-GPU (tensor-parallel) runtime model — Table 6 / Figure 3.
//!
//! Vocabulary sharding divides the GEMM's weight traffic by TP, but the
//! baselines then pay an **all-gather of the full logits** plus the same
//! separate sampling chain; FlashSampling pays only **per-tile P2P summary
//! writes that overlap with the GEMM** plus a cross-rank barrier.  The
//! model composes `kernelchain` per-rank costs with a collective model:
//!
//!   all_gather(n, bytes) = latency·ceil(log2 n) · 2  +  bytes·(n-1)/n / link_bw
//!   fanout_barrier(n)    = multi-GPU fixed sync + log-depth barrier
//!
//! Overlap: the fan-out's payload is O(B·n_tiles) scalars, far below the
//! link bandwidth·GEMM-time product, so its transfer time hides entirely
//! behind the GEMM (the paper's claim); only the barrier is exposed.

use super::kernelchain;
use super::specs::GpuSpec;
use super::{Method, Workload};

/// Time for a logits all-gather across `n` ranks.
pub fn all_gather_time(gpu: &GpuSpec, n: usize, bytes_full: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let hops = (n as f64).log2().ceil();
    let latency = gpu.collective_latency * hops * 2.0;
    let transfer = bytes_full * ((n - 1) as f64 / n as f64) / gpu.nvlink_bw;
    latency + transfer
}

/// Exposed cost of the FlashSampling P2P fan-out + barrier at TP `n`.
pub fn fanout_barrier_time(_gpu: &GpuSpec, n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    // Fixed multi-GPU dispatch/sync overhead + log-depth barrier.
    20.0e-6 + 4.0e-6 * (n as f64).log2().ceil()
}

/// Per-(method, B, TP) runtime in seconds for the Table 6 workload.
pub fn tp_runtime(gpu: &GpuSpec, method: Method, w: Workload, tp: usize) -> f64 {
    // Each rank's GEMM covers V/tp rows of the vocabulary.
    let shard = Workload { batch: w.batch, d: w.d, vocab: w.vocab / tp };
    match method {
        Method::FlashSampling => {
            // Fused shard kernel (fan-out overlapped) + barrier + stage 2.
            let c = kernelchain::chain(gpu, method, shard, false);
            c.total() + fanout_barrier_time(gpu, tp)
        }
        _ => {
            // Shard GEMM (writes shard logits), all-gather the full logits,
            // then the method's sampling chain over the FULL vocabulary.
            let shard_chain = kernelchain::chain(gpu, method, shard, false);
            let full_chain = kernelchain::chain(gpu, method, w, false);
            let gemm = shard_chain.matmul_time() + gpu.launch_overhead;
            let sampling: f64 = full_chain
                .kernels
                .iter()
                .filter(|k| !k.is_matmul)
                .map(|k| k.device_s + k.gap_s)
                .sum();
            let logits_bytes = (w.batch * w.vocab * 2) as f64; // bf16 gather
            gemm + all_gather_time(gpu, tp, logits_bytes) + sampling
        }
    }
}

/// Ideal scaling reference: TP=1 runtime / tp (the Figure 3 dotted line).
pub fn ideal_runtime(gpu: &GpuSpec, method: Method, w: Workload, tp: usize) -> f64 {
    tp_runtime(gpu, method, w, 1) / tp as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::B200;

    const CFG: Workload = Workload { batch: 16, d: 8192, vocab: 128_256 };

    #[test]
    fn flashsampling_fastest_in_memory_bound_regime() {
        // Paper Fig 3: FS fastest at B in {16, 64} for every TP size.
        for b in [16usize, 64] {
            let w = Workload { batch: b, ..CFG };
            for tp in [1usize, 2, 4, 8] {
                let fs = tp_runtime(&B200, Method::FlashSampling, w, tp);
                for m in Method::BASELINES {
                    let base = tp_runtime(&B200, m, w, tp);
                    assert!(
                        fs < base,
                        "B={b} TP={tp}: FS {fs:.1e} !< {:?} {base:.1e}",
                        m
                    );
                }
            }
        }
    }

    #[test]
    fn runtime_decreases_with_tp() {
        for m in Method::ALL {
            let mut prev = f64::MAX;
            for tp in [1usize, 2, 4, 8] {
                let t = tp_runtime(&B200, m, CFG, tp);
                assert!(t < prev, "{m:?} TP={tp}");
                prev = t;
            }
        }
    }

    #[test]
    fn flashsampling_scales_near_ideal_at_large_batch() {
        // Paper: at B=256, FS closely follows the ideal-speedup line.
        let w = Workload { batch: 256, ..CFG };
        let t8 = tp_runtime(&B200, Method::FlashSampling, w, 8);
        let ideal = ideal_runtime(&B200, Method::FlashSampling, w, 8);
        assert!(t8 / ideal < 1.6, "FS TP8 {t8:.1e} vs ideal {ideal:.1e}");
        // ...while the all-gather baselines sit far above ideal.
        let fi1 = tp_runtime(&B200, Method::Fi1, w, 8);
        let fi1_ideal = ideal_runtime(&B200, Method::Fi1, w, 8);
        assert!(fi1 / fi1_ideal > 2.0, "FI1 {fi1:.1e} vs {fi1_ideal:.1e}");
    }

    #[test]
    fn baselines_pay_vocab_proportional_communication() {
        // All-gather grows with V; the fan-out barrier does not.
        let small_v = all_gather_time(&B200, 8, (16 * 32_000 * 2) as f64);
        let large_v = all_gather_time(&B200, 8, (16 * 256_000 * 2) as f64);
        assert!(large_v > small_v);
        // ...and the fan-out barrier is independent of the payload: it has
        // no vocab term at all (only rank count).
        assert!(fanout_barrier_time(&B200, 8) < small_v);
        assert_eq!(all_gather_time(&B200, 1, 1e9), 0.0);
        assert_eq!(fanout_barrier_time(&B200, 1), 0.0);
    }

    #[test]
    fn table6_shape_fs_tp1_matches_paper_scale() {
        // Sanity anchor: paper Table 6 FS (B=16, TP=1) = 333.8 µs on B200.
        // The model should land within ~25% of that absolute number.
        let t = tp_runtime(&B200, Method::FlashSampling, CFG, 1) * 1e6;
        assert!((250.0..420.0).contains(&t), "FS TP1 = {t:.1} µs");
    }
}
