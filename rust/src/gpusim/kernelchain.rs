//! Kernel-chain runtime model — reproduces the paper's microbenchmarks
//! (Tables 1, 4, 5; Figures 2, 4).
//!
//! Each method is a *chain* of kernels.  A kernel's device time is
//! `max(traffic / achieved_bw, flops / achieved_flops)`; achieved bandwidth
//! ramps with the working set (small kernels can't saturate HBM), and GEMM
//! compute efficiency depends on the library (cuBLAS vs Triton — the §4.4
//! portability trade-off).  Chains additionally pay a per-kernel dispatch
//! gap (launch + host driver + stream dependency), which is what makes the
//! baselines' multi-kernel samplers expensive at small batch even though
//! their traffic is modest — the paper's central §4.4 finding ("the gain is
//! primarily from fusion").
//!
//! Two instruments, like the paper's:
//! * `ChainCost::total()` — wall span including dispatch gaps (what the
//!   speedup tables measure, via CUDA events / CUPTI ranges).
//! * `ChainCost::sampling_fraction_kernel_time()` — pure kernel-time split
//!   (Table 1's percentages, which exclude the gaps).

use super::specs::GpuSpec;
use super::{Method, Workload};

/// Bytes per element of the streamed weight/logit tensors.
const BF16: f64 = 2.0;
const F32: f64 = 4.0;

/// Working-set size at which a streaming kernel reaches ~half of its
/// asymptotic bandwidth (ramp constant; occupancy + DRAM page effects).
const BW_RAMP_BYTES: f64 = 8.0e6;

/// One modeled kernel.
#[derive(Clone, Debug)]
pub struct KernelCost {
    pub name: &'static str,
    /// Device busy time, seconds.
    pub device_s: f64,
    /// Dispatch gap paid before this kernel, seconds.
    pub gap_s: f64,
    pub traffic_bytes: f64,
    pub flops: f64,
    /// Is this the matmul (for Table-1 style splits)?
    pub is_matmul: bool,
}

/// A method's full kernel chain at one workload point.
#[derive(Clone, Debug)]
pub struct ChainCost {
    pub method: Method,
    pub kernels: Vec<KernelCost>,
}

impl ChainCost {
    /// Wall span: device time + dispatch gaps (the speedup instrument).
    pub fn total(&self) -> f64 {
        self.kernels.iter().map(|k| k.device_s + k.gap_s).sum()
    }

    /// Pure device (kernel) time.
    pub fn kernel_time(&self) -> f64 {
        self.kernels.iter().map(|k| k.device_s).sum()
    }

    pub fn matmul_time(&self) -> f64 {
        self.kernels.iter().filter(|k| k.is_matmul).map(|k| k.device_s).sum()
    }

    pub fn sampling_time(&self) -> f64 {
        self.kernels.iter().filter(|k| !k.is_matmul).map(|k| k.device_s).sum()
    }

    /// Table 1's "sampl. %" — sampling share of *kernel* time.
    pub fn sampling_fraction_kernel_time(&self) -> f64 {
        self.sampling_time() / self.kernel_time()
    }

    pub fn total_traffic(&self) -> f64 {
        self.kernels.iter().map(|k| k.traffic_bytes).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }
}

/// Achieved bandwidth for a kernel streaming `bytes` (ramp model).
fn achieved_bw(gpu: &GpuSpec, bytes: f64) -> f64 {
    gpu.hbm_bw * gpu.bw_efficiency * (bytes / (bytes + BW_RAMP_BYTES))
}

/// Streaming bandwidth efficiency of the two GEMM implementations.
///
/// Calibrated from the paper's Table 6 TP=1 column: at B=64 (memory-bound)
/// FlashSampling achieves ~78% of peak HBM BW while the cuBLAS skinny-GEMM
/// baseline achieves ~62% — skinny LM-head GEMMs are not cuBLAS's best
/// regime, while the fused Triton kernel streams W linearly.
const BW_EFF_TRITON: f64 = 0.78;
const BW_EFF_CUBLAS: f64 = 0.62;

/// MXU/tensor-core compute efficiency of a skinny GEMM as a function of
/// batch (rows).  Rises with B (more work per weight tile) and saturates
/// well below peak for LM-head shapes; calibrated so the memory->compute
/// crossover lands where the paper's B=128-256 rows put it.
fn compute_efficiency(batch: usize) -> f64 {
    let b = batch as f64;
    0.45 * b / (b + 64.0)
}

/// Triton-vs-cuBLAS penalty on the compute-bound side (the paper's §4.4
/// portability trade-off).  Hopper Triton loses a lot at large batch
/// (paper Table 5: H100/H200 dip below 1.0 at B>=128); Blackwell Triton is
/// nearly competitive (B200/B300 stay above 1.0).
fn triton_penalty(gpu: &GpuSpec, batch: usize) -> f64 {
    let sat = (batch as f64 / 256.0).min(1.0);
    let max_loss = if gpu.bf16_flops > 2e15 { 0.08 } else { 0.38 };
    1.0 - max_loss * sat
}

/// GEMM device time under the calibrated model.
fn gemm_time(gpu: &GpuSpec, traffic: f64, flops: f64, batch: usize, triton: bool) -> f64 {
    let bw_eff = if triton { BW_EFF_TRITON } else { BW_EFF_CUBLAS };
    let mem = traffic / (gpu.hbm_bw * bw_eff);
    let mut eff = compute_efficiency(batch);
    if triton {
        eff *= triton_penalty(gpu, batch);
    }
    let compute = flops / (gpu.bf16_flops * eff);
    mem.max(compute)
}

/// Device time of a kernel with given traffic and flops.
fn kernel_time(gpu: &GpuSpec, traffic: f64, flops: f64, eff: f64) -> f64 {
    let mem = traffic / achieved_bw(gpu, traffic);
    let compute = flops / (gpu.bf16_flops * eff);
    mem.max(compute)
}

/// Dispatch gap between kernels of a torch.compile'd chain.
const GAP_TORCH: f64 = 14.0e-6;
/// Gap before a FlashInfer sampler call from the vLLM hot path.
const GAP_FLASHINFER: f64 = 11.0e-6;
/// Gap before FlashSampling's stage-2 reduction (same stream, enqueued
/// back-to-back with the fused matmul — no host round-trip).
const GAP_FUSED_STAGE2: f64 = 1.5e-6;

/// Vocabulary tile size of the fused kernel (candidate-buffer sizing).
pub const FUSED_TILE_V: usize = 2048;

/// Build the kernel chain for `method` at workload `w`.
///
/// `store_logits`: the Appendix-K ablation flag (FlashSampling only).
pub fn chain(gpu: &GpuSpec, method: Method, w: Workload, store_logits: bool) -> ChainCost {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    let gemm_flops = 2.0 * b * d * v;
    let logits_bytes = b * v * F32;
    let mut kernels = Vec::new();

    match method {
        Method::FlashSampling => {
            // Fused GEMM + epilogue: streams W and H, writes only the
            // candidate buffer [B, n_tiles] (m, idx).
            let n_tiles = (w.vocab as f64 / FUSED_TILE_V as f64).ceil();
            let mut traffic = v * d * BF16 + b * d * BF16 + b * n_tiles * 8.0;
            let mut device = gemm_time(gpu, traffic, gemm_flops, w.batch, true);
            if store_logits {
                // Appendix-K ablation: the FP32 logits store is an epilogue
                // write that cannot hide behind the MXU (it serializes with
                // the tile loop), at reduced (strided) write efficiency.
                let store = logits_bytes / 0.7;
                traffic += store;
                device += store / (gpu.hbm_bw * BW_EFF_TRITON);
            }
            kernels.push(KernelCost {
                name: "fused_gemm_sample",
                device_s: device,
                gap_s: gpu.launch_overhead,
                traffic_bytes: traffic,
                flops: gemm_flops,
                is_matmul: true,
            });
            // Stage 2: argmax over [B, n_tiles] — a single tiny block
            // (the candidate buffer fits in one SM's registers; it does not
            // pay the multi-CTA bandwidth ramp).
            let red_bytes = b * n_tiles * 8.0 + b * 4.0;
            kernels.push(KernelCost {
                name: "stage2_reduce",
                device_s: 0.3e-6 + red_bytes / (gpu.hbm_bw * 0.5),
                gap_s: GAP_FUSED_STAGE2,
                traffic_bytes: red_bytes,
                flops: 0.0,
                is_matmul: false,
            });
        }
        Method::Multinomial => {
            // cuBLAS GEMM writing logits to HBM...
            let gemm_traffic = v * d * BF16 + b * d * BF16 + logits_bytes;
            kernels.push(KernelCost {
                name: "cublas_gemm",
                device_s: gemm_time(gpu, gemm_traffic, gemm_flops, w.batch, false),
                gap_s: gpu.launch_overhead,
                traffic_bytes: gemm_traffic,
                flops: gemm_flops,
                is_matmul: true,
            });
            // ...then the compiled softmax+multinomial chain (Alg. A.1).
            // torch.compile fuses the eager ~9-kernel chain down to ~5:
            // (max), (exp-sum), (normalize), (cumsum), (search+gather).
            let passes: [(&'static str, f64); 5] = [
                ("reduce_max", 1.0),
                ("exp_sum", 1.0),
                ("normalize", 2.0),
                ("cumsum", 2.0),
                ("search", 1.0),
            ];
            for (name, mult) in passes {
                let t = logits_bytes * mult;
                kernels.push(KernelCost {
                    name,
                    device_s: kernel_time(gpu, t, 0.0, 1.0),
                    gap_s: GAP_TORCH,
                    traffic_bytes: t,
                    flops: 0.0,
                    is_matmul: false,
                });
            }
        }
        Method::Fi1 => {
            let gemm_traffic = v * d * BF16 + b * d * BF16 + logits_bytes;
            kernels.push(KernelCost {
                name: "cublas_gemm",
                device_s: gemm_time(gpu, gemm_traffic, gemm_flops, w.batch, false),
                gap_s: gpu.launch_overhead,
                traffic_bytes: gemm_traffic,
                flops: gemm_flops,
                is_matmul: true,
            });
            // vLLM's top-k/top-p path: a probability prep pass + the
            // FlashInfer sorting-free rejection sampler (several rounds of
            // re-reading the logits => ~3 logical passes) + per-call host
            // sync in the wrapper (larger gap).
            for (name, mult, gap) in [
                ("prob_prep", 2.0, GAP_TORCH),
                ("fi_topk_topp", 3.0, GAP_FLASHINFER + 9.0e-6),
            ] {
                let t = logits_bytes * mult;
                kernels.push(KernelCost {
                    name,
                    device_s: kernel_time(gpu, t, 0.0, 1.0),
                    gap_s: gap,
                    traffic_bytes: t,
                    flops: 0.0,
                    is_matmul: false,
                });
            }
        }
        Method::Fi2 => {
            let gemm_traffic = v * d * BF16 + b * d * BF16 + logits_bytes;
            kernels.push(KernelCost {
                name: "cublas_gemm",
                device_s: gemm_time(gpu, gemm_traffic, gemm_flops, w.batch, false),
                gap_s: gpu.launch_overhead,
                traffic_bytes: gemm_traffic,
                flops: gemm_flops,
                is_matmul: true,
            });
            // FlashInfer Gumbel-Max: ONE pass over materialized logits
            // (closest baseline; remaining gap = materialization + launch).
            kernels.push(KernelCost {
                name: "fi_gumbel_max",
                device_s: kernel_time(gpu, logits_bytes * 1.25, 0.0, 1.0),
                gap_s: GAP_FLASHINFER,
                traffic_bytes: logits_bytes * 1.25,
                flops: 0.0,
                is_matmul: false,
            });
        }
    }
    ChainCost { method, kernels }
}

/// Speedup of FlashSampling over `baseline` at workload `w`.
pub fn speedup(gpu: &GpuSpec, baseline: Method, w: Workload) -> f64 {
    let flash = chain(gpu, Method::FlashSampling, w, false).total();
    let base = chain(gpu, baseline, w, false).total();
    base / flash
}

/// FlashSampling chain with the certified sub-vocabulary LM head
/// (DESIGN.md §16): only `active_frac` of the vocab rows are streamed and
/// scored, so the W-stream traffic, the GEMM flops, and the candidate
/// buffer all scale with the active fraction while the H-stream and the
/// stage-2 structure are unchanged.  The exactness certificate itself is
/// host-side arithmetic (O(V) RNG, no matmul) and is modeled as free
/// device time.
pub fn chain_subvocab(gpu: &GpuSpec, w: Workload, active_frac: f64) -> ChainCost {
    let frac = active_frac.clamp(1.0 / w.vocab as f64, 1.0);
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    let va = v * frac;
    let gemm_flops = 2.0 * b * d * va;
    let n_tiles = (va / FUSED_TILE_V as f64).ceil();
    let traffic = va * d * BF16 + b * d * BF16 + b * n_tiles * 8.0;
    let mut kernels = vec![KernelCost {
        name: "fused_gemm_sample_sub",
        device_s: gemm_time(gpu, traffic, gemm_flops, w.batch, true),
        gap_s: gpu.launch_overhead,
        traffic_bytes: traffic,
        flops: gemm_flops,
        is_matmul: true,
    }];
    let red_bytes = b * n_tiles * 8.0 + b * 4.0;
    kernels.push(KernelCost {
        name: "stage2_reduce",
        device_s: 0.3e-6 + red_bytes / (gpu.hbm_bw * 0.5),
        gap_s: GAP_FUSED_STAGE2,
        traffic_bytes: red_bytes,
        flops: 0.0,
        is_matmul: false,
    });
    ChainCost { method: Method::FlashSampling, kernels }
}

/// Modeled speedup of certified sub-vocab decode over full FlashSampling
/// at the observed `fallback_rate`.  The engine's protocol prices
/// honestly: every step pays the tile-subset pass, and a fallback step
/// pays the full-vocabulary pass ON TOP (the certificate is evaluated
/// after the sub pass returns), so the average step costs
/// `sub + fallback_rate * full`.
pub fn subvocab_speedup(
    gpu: &GpuSpec,
    w: Workload,
    active_frac: f64,
    fallback_rate: f64,
) -> f64 {
    let full = chain(gpu, Method::FlashSampling, w, false).total();
    let sub = chain_subvocab(gpu, w, active_frac).total();
    full / (sub + fallback_rate.clamp(0.0, 1.0) * full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs;

    #[test]
    fn flash_sampling_wins_decode_regime_all_gpus() {
        // Paper: "For B<=64, FlashSampling is faster than all baselines on
        // all GPUs" (both configs).
        for gpu in &specs::DATACENTER {
            for b in [1usize, 2, 4, 8, 16, 32, 64] {
                for base in Method::BASELINES {
                    for w in [Workload::small(b), Workload::large(b)] {
                        let s = speedup(gpu, base, w);
                        assert!(
                            s > 1.0,
                            "{} vs {:?} B={b} D={}: {s:.3}",
                            gpu.name, base, w.d
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn advantage_narrows_at_large_batch() {
        // Paper Table 4/5: speedup at 256 < peak at 64-128.
        for gpu in &specs::DATACENTER {
            let peak = speedup(gpu, Method::Multinomial, Workload::small(64));
            let tail = speedup(gpu, Method::Multinomial, Workload::small(256));
            assert!(tail < peak, "{}: {tail} !< {peak}", gpu.name);
        }
    }

    #[test]
    fn fi2_is_the_closest_baseline() {
        // Paper: "speedups over FI2 are smaller... because FI2 also uses
        // Gumbel-Max" — at every decode-regime point.
        for b in [1usize, 8, 64] {
            let w = Workload::small(b);
            let s_fi2 = speedup(&specs::B200, Method::Fi2, w);
            let s_mult = speedup(&specs::B200, Method::Multinomial, w);
            let s_fi1 = speedup(&specs::B200, Method::Fi1, w);
            assert!(s_fi2 < s_mult, "B={b}");
            assert!(s_fi2 < s_fi1, "B={b}");
        }
    }

    #[test]
    fn larger_hidden_dim_reduces_speedup() {
        // Paper: "smaller models experience larger speedups" (1 + 2B/D).
        for b in [8usize, 64] {
            let s_small = speedup(&specs::B200, Method::Multinomial, Workload::small(b));
            let s_large = speedup(&specs::B200, Method::Multinomial, Workload::large(b));
            assert!(s_large < s_small, "B={b}: {s_large} !< {s_small}");
        }
    }

    #[test]
    fn blackwell_speedups_exceed_hopper() {
        // Faster HBM shrinks the GEMM, so eliminating the fixed sampler
        // chain matters more (paper: peaks on B200/B300).
        for b in [1usize, 16, 64] {
            let s_h100 = speedup(&specs::H100, Method::Multinomial, Workload::small(b));
            let s_b200 = speedup(&specs::B200, Method::Multinomial, Workload::small(b));
            assert!(s_b200 > s_h100, "B={b}: {s_b200} !> {s_h100}");
        }
    }

    #[test]
    fn table1_sampling_fractions() {
        // Paper Table 1 (B200, D=4096 V=152k, kernel-time split):
        // FlashSampling stays ~2-6%; Multinomial grows to ~27-29%;
        // FI2 sits between (~5-12%).
        let gpu = &specs::B200;
        for (b, flash_hi, mult_lo, mult_hi) in
            [(1usize, 0.05, 0.015, 0.10), (64, 0.10, 0.12, 0.40),
             (256, 0.10, 0.12, 0.40)]
        {
            let w = Workload::small(b);
            let f = chain(gpu, Method::FlashSampling, w, false)
                .sampling_fraction_kernel_time();
            assert!(f < flash_hi, "flash B={b}: {f}");
            let m = chain(gpu, Method::Multinomial, w, false)
                .sampling_fraction_kernel_time();
            assert!((mult_lo..mult_hi).contains(&m), "mult B={b}: {m}");
            let f2 = chain(gpu, Method::Fi2, w, false)
                .sampling_fraction_kernel_time();
            assert!(f2 > f && f2 < m, "fi2 B={b}: {f2} (flash {f}, mult {m})");
        }
    }

    #[test]
    fn store_logits_ablation_adds_2b_over_d_traffic() {
        let gpu = &specs::B200;
        for b in [16usize, 64, 256] {
            let w = Workload::large(b);
            let base = chain(gpu, Method::FlashSampling, w, false).total();
            let stored = chain(gpu, Method::FlashSampling, w, true).total();
            let overhead = stored / base - 1.0;
            let predicted = crate::gpusim::iomodel::logits_store_overhead_predicted(w);
            assert!(overhead > predicted * 0.5, "B={b}: {overhead} vs {predicted}");
            assert!(overhead < predicted * 3.0 + 0.01, "B={b}: {overhead} vs {predicted}");
        }
    }

    #[test]
    fn subvocab_chain_models_tile_skipping() {
        let gpu = &specs::B200;
        let w = Workload::small(8);
        // Full active fraction reproduces the plain FlashSampling chain.
        let full = chain(gpu, Method::FlashSampling, w, false).total();
        let same = chain_subvocab(gpu, w, 1.0).total();
        assert!((full - same).abs() < 1e-12, "{full} vs {same}");
        // Skipping most tiles shrinks the W-stream: strictly cheaper, and
        // monotone in the active fraction.
        let quarter = chain_subvocab(gpu, w, 0.25).total();
        let eighth = chain_subvocab(gpu, w, 0.125).total();
        assert!(quarter < full && eighth < quarter, "{eighth} {quarter} {full}");
        // Speedup: > 1 when the certificate mostly admits the skip, and
        // monotone-decreasing in the fallback rate; with every step
        // falling back the sub pass is pure overhead (< 1).
        let s0 = subvocab_speedup(gpu, w, 0.25, 0.0);
        let s_mid = subvocab_speedup(gpu, w, 0.25, 0.3);
        let s1 = subvocab_speedup(gpu, w, 0.25, 1.0);
        assert!(s0 > 1.0, "{s0}");
        assert!(s0 > s_mid && s_mid > s1, "{s0} {s_mid} {s1}");
        assert!(s1 < 1.0, "{s1}");
    }

    #[test]
    fn fig4_sampling_cost_grows_steeply_for_baselines() {
        // Figure 4 left panel: baseline sampling runtime grows with B;
        // FlashSampling's absorbed cost stays negligible.
        let gpu = &specs::RTX3090;
        let s1 = chain(gpu, Method::Multinomial, Workload::small(1), false)
            .sampling_time();
        let s256 = chain(gpu, Method::Multinomial, Workload::small(256), false)
            .sampling_time();
        assert!(s256 > 20.0 * s1);
        let f256 = chain(gpu, Method::FlashSampling, Workload::small(256), false)
            .sampling_time();
        assert!(f256 < 0.1 * s256);
    }
}
