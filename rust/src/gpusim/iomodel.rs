//! The paper's §3.3 IO cost model, verbatim — plus the host-interconnect
//! (PCIe) transfer model the serving layer's swap-vs-recompute preemption
//! policy prices against (DESIGN.md §12).
//!
//! Counts HBM element movement for the baseline (materialize logits, read
//! them back) and the fused kernel (no logits round-trip), in *elements*
//! exactly as the paper writes it (the dtype factor cancels in ratios).

use super::Workload;

/// M_baseline = VD + DB + VB (gemm) + VB + B (sampler).
pub fn baseline_elements(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    v * d + d * b + v * b + v * b + b
}

/// M_fused = VD + DB + B.
pub fn fused_elements(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    v * d + d * b + b
}

/// Exact model speedup M_baseline / M_fused.
pub fn predicted_speedup(w: Workload) -> f64 {
    baseline_elements(w) / fused_elements(w)
}

/// The paper's simplified form 1 + 2B/D.
pub fn predicted_speedup_approx(w: Workload) -> f64 {
    1.0 + 2.0 * w.batch as f64 / w.d as f64
}

/// Predicted overhead of the logits-store ablation (Table 9): storing Y
/// adds VB to M_fused, i.e. relative slowdown ≈ VB / (VD + DB + B) ≈ B/D...
/// the paper quotes 2B/D because the ablation *stores in FP32* while
/// weights stream in BF16 — the write costs 2x per element relative to the
/// BF16-normalized baseline traffic.
pub fn logits_store_overhead_predicted(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    // FP32 store (4 bytes) over BF16-dominated fused traffic (2 bytes/elem)
    (2.0 * v * b) / (v * d + d * b + b)
}

/// "Measured" overhead in the simulator: the store also costs a partial
/// loss of write-combining on the strided tile stores, modeled as a small
/// constant inefficiency per stored element — this is what makes measured
/// overhead sit slightly above 2B/D while tracking it (paper Table 9).
pub fn logits_store_overhead_modeled(w: Workload) -> f64 {
    let pred = logits_store_overhead_predicted(w);
    // Strided FP32 tile stores achieve ~70% write efficiency, plus a fixed
    // epilogue cost worth ~0.4% of kernel time at B=1 shrinking as compute
    // grows.
    pred / 0.7 + 0.004 / (1.0 + w.batch as f64 / 16.0)
}

// ---------------------------------------------------------------------
// PCIe transfer model + swap-vs-recompute policy (DESIGN.md §12)
// ---------------------------------------------------------------------

/// Effective host-link bandwidth of a PCIe Gen5 x16 slot in GB/s.
pub const PCIE_GEN5_X16_GBS: f64 = 64.0;

/// First-order host-interconnect model: fixed launch/doorbell latency plus
/// bytes over sustained bandwidth.  Deliberately ignores contention — the
/// policy only needs relative magnitudes (a KV block is ~100s of KB, a
/// prefill chunk ~100s of µs), not a bus simulator.
#[derive(Clone, Copy, Debug)]
pub struct PcieModel {
    /// Sustained bandwidth in GB/s.
    pub bw_gbs: f64,
    /// Per-transfer fixed latency in µs (DMA setup + completion).
    pub latency_us: f64,
}

impl Default for PcieModel {
    fn default() -> Self {
        Self { bw_gbs: PCIE_GEN5_X16_GBS, latency_us: 10.0 }
    }
}

impl PcieModel {
    /// Bytes of one paged-KV block: K and V, all layers, FP32 (the
    /// simulator's storage dtype).
    pub fn kv_block_bytes(
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
        block_size: usize,
    ) -> usize {
        2 * n_layers * n_heads * head_dim * block_size * 4
    }

    /// One-way transfer time in µs for `bytes` over the link.
    /// GB/s = bytes/ns, so bytes / (bw * 1e3) gives µs.
    pub fn transfer_us(&self, bytes: usize) -> f64 {
        self.latency_us + bytes as f64 / (self.bw_gbs * 1e3)
    }

    /// Cost of re-running prefill over `tokens` at a calibrated per-token
    /// rate — the alternative the swap transfer competes with.
    pub fn recompute_us(&self, tokens: usize, prefill_us_per_token: f64) -> f64 {
        tokens as f64 * prefill_us_per_token
    }
}

/// Operator-facing preemption policy knob (`swap_policy` config key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SwapPolicy {
    /// Price swap (PCIe round-trip) against recompute and pick the
    /// cheaper side.
    #[default]
    Auto,
    /// Always prefer the swap tier when ledger capacity allows.
    Always,
    /// Never swap — legacy finish-early preemption only.
    Never,
}

impl std::str::FromStr for SwapPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(Self::Auto),
            "always" => Ok(Self::Always),
            "never" => Ok(Self::Never),
            other => Err(format!(
                "unknown swap_policy {other:?} (auto|always|never)"
            )),
        }
    }
}

impl std::fmt::Display for SwapPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::Always => "always",
            Self::Never => "never",
        })
    }
}

/// What the engine does with a preemption victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptAction {
    /// Park private KV blocks in the host ledger; resume later.
    Swap,
    /// Drop the sequence's work (finish early / recompute on resubmit).
    Recompute,
}

/// Policy decision: swap out-and-back costs `swap_us` (already a round
/// trip if the caller priced one), recomputing the context costs
/// `recompute_us`.
pub fn choose(policy: SwapPolicy, swap_us: f64, recompute_us: f64) -> PreemptAction {
    match policy {
        SwapPolicy::Always => PreemptAction::Swap,
        SwapPolicy::Never => PreemptAction::Recompute,
        SwapPolicy::Auto => {
            if swap_us <= recompute_us {
                PreemptAction::Swap
            } else {
                PreemptAction::Recompute
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_approximation_is_tight_for_llm_shapes() {
        for b in [1usize, 16, 64, 256] {
            let w = Workload::small(b);
            let exact = predicted_speedup(w);
            let approx = predicted_speedup_approx(w);
            assert!(
                (exact - approx).abs() / exact < 0.02,
                "B={b}: {exact} vs {approx}"
            );
        }
    }

    #[test]
    fn speedup_grows_with_batch_shrinks_with_d() {
        assert!(
            predicted_speedup(Workload::small(64))
                > predicted_speedup(Workload::small(1))
        );
        assert!(
            predicted_speedup(Workload::small(64))
                > predicted_speedup(Workload::large(64))
        );
    }

    #[test]
    fn table9_predicted_column() {
        // Paper Table 9 predicted values: D=8192 V=128k: B=1 -> 0.02%,
        // B=256 -> 6.25%;  D=4096 V=152k: B=64 -> 3.13%.
        let p = |b, d, v| {
            logits_store_overhead_predicted(Workload::new(b, d, v)) * 100.0
        };
        assert!((p(1, 8192, 128_256) - 0.02).abs() < 0.005);
        assert!((p(256, 8192, 128_256) - 6.25).abs() < 0.1);
        assert!((p(64, 4096, 151_936) - 3.13).abs() < 0.05);
    }

    #[test]
    fn modeled_measured_exceeds_predicted_but_tracks() {
        for b in [1usize, 16, 64, 256] {
            let w = Workload::large(b);
            let pred = logits_store_overhead_predicted(w);
            let meas = logits_store_overhead_modeled(w);
            assert!(meas > pred);
            assert!(meas < pred * 1.5 + 0.01, "B={b}: {meas} vs {pred}");
        }
    }

    #[test]
    fn pcie_transfer_time_is_monotone_in_bytes_and_bandwidth() {
        let m = PcieModel::default();
        assert!(m.transfer_us(0) >= m.latency_us);
        assert!(m.transfer_us(1 << 20) < m.transfer_us(1 << 24));
        let fast = PcieModel { bw_gbs: 128.0, ..m };
        assert!(fast.transfer_us(1 << 24) < m.transfer_us(1 << 24));
        // A 2-layer 4-head dh=8 bs=16 block: 2*2*4*8*16*4 = 8192 bytes.
        assert_eq!(PcieModel::kv_block_bytes(2, 4, 8, 16), 8192);
        // Sanity magnitude: 8 KiB over 64 GB/s ≈ latency-dominated.
        assert!(m.transfer_us(8192) < m.latency_us + 1.0);
    }

    #[test]
    fn swap_policy_parses_and_roundtrips() {
        for p in [SwapPolicy::Auto, SwapPolicy::Always, SwapPolicy::Never] {
            assert_eq!(p.to_string().parse::<SwapPolicy>().unwrap(), p);
        }
        assert!("sometimes".parse::<SwapPolicy>().is_err());
        assert_eq!(SwapPolicy::default(), SwapPolicy::Auto);
    }

    #[test]
    fn auto_policy_picks_the_cheaper_side() {
        assert_eq!(choose(SwapPolicy::Auto, 50.0, 100.0), PreemptAction::Swap);
        assert_eq!(
            choose(SwapPolicy::Auto, 100.0, 50.0),
            PreemptAction::Recompute
        );
        assert_eq!(choose(SwapPolicy::Always, 1e9, 0.0), PreemptAction::Swap);
        assert_eq!(
            choose(SwapPolicy::Never, 0.0, 1e9),
            PreemptAction::Recompute
        );
        // A long-context victim with few private blocks should swap under
        // Auto with realistic numbers: 4 blocks of a small model vs 500
        // tokens of recompute at 50 µs/token.
        let m = PcieModel::default();
        let bytes = 4 * PcieModel::kv_block_bytes(4, 8, 64, 16);
        let swap = 2.0 * m.transfer_us(bytes); // out + back in
        let recompute = m.recompute_us(500, 50.0);
        assert_eq!(choose(SwapPolicy::Auto, swap, recompute), PreemptAction::Swap);
    }
}
