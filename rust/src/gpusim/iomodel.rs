//! The paper's §3.3 IO cost model, verbatim.
//!
//! Counts HBM element movement for the baseline (materialize logits, read
//! them back) and the fused kernel (no logits round-trip), in *elements*
//! exactly as the paper writes it (the dtype factor cancels in ratios).

use super::Workload;

/// M_baseline = VD + DB + VB (gemm) + VB + B (sampler).
pub fn baseline_elements(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    v * d + d * b + v * b + v * b + b
}

/// M_fused = VD + DB + B.
pub fn fused_elements(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    v * d + d * b + b
}

/// Exact model speedup M_baseline / M_fused.
pub fn predicted_speedup(w: Workload) -> f64 {
    baseline_elements(w) / fused_elements(w)
}

/// The paper's simplified form 1 + 2B/D.
pub fn predicted_speedup_approx(w: Workload) -> f64 {
    1.0 + 2.0 * w.batch as f64 / w.d as f64
}

/// Predicted overhead of the logits-store ablation (Table 9): storing Y
/// adds VB to M_fused, i.e. relative slowdown ≈ VB / (VD + DB + B) ≈ B/D...
/// the paper quotes 2B/D because the ablation *stores in FP32* while
/// weights stream in BF16 — the write costs 2x per element relative to the
/// BF16-normalized baseline traffic.
pub fn logits_store_overhead_predicted(w: Workload) -> f64 {
    let (b, d, v) = (w.batch as f64, w.d as f64, w.vocab as f64);
    // FP32 store (4 bytes) over BF16-dominated fused traffic (2 bytes/elem)
    (2.0 * v * b) / (v * d + d * b + b)
}

/// "Measured" overhead in the simulator: the store also costs a partial
/// loss of write-combining on the strided tile stores, modeled as a small
/// constant inefficiency per stored element — this is what makes measured
/// overhead sit slightly above 2B/D while tracking it (paper Table 9).
pub fn logits_store_overhead_modeled(w: Workload) -> f64 {
    let pred = logits_store_overhead_predicted(w);
    // Strided FP32 tile stores achieve ~70% write efficiency, plus a fixed
    // epilogue cost worth ~0.4% of kernel time at B=1 shrinking as compute
    // grows.
    pred / 0.7 + 0.004 / (1.0 + w.batch as f64 / 16.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_approximation_is_tight_for_llm_shapes() {
        for b in [1usize, 16, 64, 256] {
            let w = Workload::small(b);
            let exact = predicted_speedup(w);
            let approx = predicted_speedup_approx(w);
            assert!(
                (exact - approx).abs() / exact < 0.02,
                "B={b}: {exact} vs {approx}"
            );
        }
    }

    #[test]
    fn speedup_grows_with_batch_shrinks_with_d() {
        assert!(
            predicted_speedup(Workload::small(64))
                > predicted_speedup(Workload::small(1))
        );
        assert!(
            predicted_speedup(Workload::small(64))
                > predicted_speedup(Workload::large(64))
        );
    }

    #[test]
    fn table9_predicted_column() {
        // Paper Table 9 predicted values: D=8192 V=128k: B=1 -> 0.02%,
        // B=256 -> 6.25%;  D=4096 V=152k: B=64 -> 3.13%.
        let p = |b, d, v| {
            logits_store_overhead_predicted(Workload::new(b, d, v)) * 100.0
        };
        assert!((p(1, 8192, 128_256) - 0.02).abs() < 0.005);
        assert!((p(256, 8192, 128_256) - 6.25).abs() < 0.1);
        assert!((p(64, 4096, 151_936) - 3.13).abs() < 0.05);
    }

    #[test]
    fn modeled_measured_exceeds_predicted_but_tracks() {
        for b in [1usize, 16, 64, 256] {
            let w = Workload::large(b);
            let pred = logits_store_overhead_predicted(w);
            let meas = logits_store_overhead_modeled(w);
            assert!(meas > pred);
            assert!(meas < pred * 1.5 + 0.01, "B={b}: {meas} vs {pred}");
        }
    }
}
