//! GPU specifications (paper Table 3) + microarchitectural constants used
//! by the kernel-chain model.

/// Datacenter GPU spec (paper Table 3, dense BF16).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    pub hbm_gb: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Peak dense BF16, FLOP/s.
    pub bf16_flops: f64,
    /// Per-kernel launch/dispatch overhead, seconds.  Hopper/Blackwell
    /// kernel launches cost ~3-5 µs through the torch dispatcher even under
    /// torch.compile (CUDA-graphless mode); this constant is what makes
    /// multi-kernel sampler chains expensive at small batch — the §4.4
    /// observation.
    pub launch_overhead: f64,
    /// Fraction of peak HBM bandwidth a large streaming kernel achieves.
    pub bw_efficiency: f64,
    /// NVLink per-direction bandwidth per GPU, bytes/s (for TP models).
    pub nvlink_bw: f64,
    /// Base latency of a collective operation (all-gather) at TP=2, s.
    pub collective_latency: f64,
}

impl GpuSpec {
    /// ops:byte ratio (Table 3 row) — the roofline ridge point.
    pub fn ops_per_byte(&self) -> f64 {
        self.bf16_flops / self.hbm_bw
    }
}

/// H100 SXM (Hopper).
pub const H100: GpuSpec = GpuSpec {
    name: "H100",
    hbm_gb: 80.0,
    hbm_bw: 3.35e12,
    bf16_flops: 989e12,
    launch_overhead: 4.0e-6,
    bw_efficiency: 0.83,
    nvlink_bw: 450e9,
    collective_latency: 12.0e-6,
};

/// H200 (Hopper, HBM3e).
pub const H200: GpuSpec = GpuSpec {
    name: "H200",
    hbm_gb: 141.0,
    hbm_bw: 4.8e12,
    bf16_flops: 989e12,
    launch_overhead: 4.0e-6,
    bw_efficiency: 0.83,
    nvlink_bw: 450e9,
    collective_latency: 12.0e-6,
};

/// B200 (Blackwell).
pub const B200: GpuSpec = GpuSpec {
    name: "B200",
    hbm_gb: 192.0,
    hbm_bw: 8.0e12,
    bf16_flops: 2250e12,
    launch_overhead: 4.0e-6,
    bw_efficiency: 0.85,
    nvlink_bw: 900e9,
    collective_latency: 10.0e-6,
};

/// B300 (Blackwell Ultra).
pub const B300: GpuSpec = GpuSpec {
    name: "B300",
    hbm_gb: 288.0,
    hbm_bw: 8.0e12,
    bf16_flops: 2250e12,
    launch_overhead: 4.2e-6,
    bw_efficiency: 0.85,
    nvlink_bw: 900e9,
    collective_latency: 10.0e-6,
};

/// RTX 3090 (the paper's §4.4 profiling box for Figure 4).
pub const RTX3090: GpuSpec = GpuSpec {
    name: "RTX3090",
    hbm_gb: 24.0,
    hbm_bw: 0.936e12,
    bf16_flops: 71e12, // with FP32 accumulate halved in practice; dense
    launch_overhead: 5.0e-6,
    bw_efficiency: 0.80,
    nvlink_bw: 56e9,
    collective_latency: 20.0e-6,
};

/// The paper's four datacenter GPUs (Tables 4-5 columns).
pub const DATACENTER: [GpuSpec; 4] = [H100, H200, B200, B300];

pub fn by_name(name: &str) -> Option<GpuSpec> {
    match name {
        "H100" => Some(H100),
        "H200" => Some(H200),
        "B200" => Some(B200),
        "B300" => Some(B300),
        "RTX3090" => Some(RTX3090),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_byte_ratios_match_table3() {
        // Paper Table 3: H100 295, H200 206, B200/B300 281.
        assert!((H100.ops_per_byte() - 295.0).abs() < 1.0);
        assert!((H200.ops_per_byte() - 206.0).abs() < 1.0);
        assert!((B200.ops_per_byte() - 281.0).abs() < 1.0);
        assert!((B300.ops_per_byte() - 281.0).abs() < 1.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("B200").unwrap().name, "B200");
        assert!(by_name("TPUv4").is_none());
    }
}
