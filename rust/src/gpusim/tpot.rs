//! End-to-end TPOT model — Tables 7/8, Figure 5.
//!
//! TPOT = (attention + FFN decode time) + (LM head + sampling time).
//! FlashSampling only changes the second term, so the achievable reduction
//! is proportional to the LM-head share of decode time — the paper's §4.5
//! "key observation" (small models gain up to ~10%, 32B/70B gain 1-3%).
//!
//! The decode-step composition is modeled from first principles (weight
//! streaming + per-layer kernel dispatch + serving-stack host overhead) on
//! the B200 spec; the LM-head term reuses the calibrated `kernelchain`
//! model, divided across TP ranks with the `interconnect` collective model.

use super::interconnect;
use super::specs::GpuSpec;
use super::{Method, Workload};

/// A served model configuration (paper §4.5 line-up).
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub d_model: usize,
    pub vocab: usize,
    pub n_layers: usize,
    /// Total parameter count (decode weights streamed per step).
    pub params: f64,
    /// Tensor-parallel degree used in the paper's evaluation.
    pub tp: usize,
}

pub const QWEN3_1_7B: ModelSpec = ModelSpec {
    name: "Qwen3-1.7B",
    d_model: 2048,
    vocab: 151_936,
    n_layers: 28,
    params: 1.7e9,
    tp: 1,
};

pub const QWEN3_8B: ModelSpec = ModelSpec {
    name: "Qwen3-8B",
    d_model: 4096,
    vocab: 151_936,
    n_layers: 36,
    params: 8.2e9,
    tp: 1,
};

pub const QWEN3_32B: ModelSpec = ModelSpec {
    name: "Qwen3-32B",
    d_model: 5120,
    vocab: 151_936,
    n_layers: 64,
    params: 32.8e9,
    tp: 2,
};

pub const LLAMA33_70B: ModelSpec = ModelSpec {
    name: "Llama-3.3-70B",
    d_model: 8192,
    vocab: 128_256,
    n_layers: 80,
    params: 70.6e9,
    tp: 2,
};

pub const PAPER_MODELS: [ModelSpec; 4] =
    [QWEN3_1_7B, QWEN3_8B, QWEN3_32B, LLAMA33_70B];

/// Per-layer kernel count in a vLLM decode step (norm, qkv, rope, attn,
/// o-proj, norm, gate/up, down + fusions ≈ 8 dispatches).
const KERNELS_PER_LAYER: f64 = 8.0;
/// Serving-stack host overhead per engine step (scheduler, block tables,
/// python<->C++ crossings) — vLLM v1 measures ~100-200 µs.
const HOST_OVERHEAD: f64 = 130.0e-6;
/// Average KV context read per step.  AIME generations are long but the
/// paper's TPOT barely grows with concurrency, implying modest average
/// live context during the sweep; modern models also use GQA (KV width
/// ~1/4 of d_model), folded into this constant.
const AVG_CONTEXT: f64 = 512.0;
const GQA_KV_FRACTION: f64 = 0.25;
/// Host-side cost of vLLM's sampler module on the baseline path (logits
/// gather, logits processors, python sampler crossing) per engine step.
/// FlashSampling eliminates it: sampling happens inside the LM-head graph.
const SAMPLER_HOST_OVERHEAD: f64 = 80.0e-6;
/// Model-FLOPs utilization of the prefill matmuls (prefill is
/// compute-bound, unlike decode; dense serving stacks typically sustain
/// 40-60% of peak on prompt processing).
const PREFILL_MFU: f64 = 0.5;

impl ModelSpec {
    /// LM-head parameter count (excluded from the per-layer stream term).
    fn lm_head_params(&self) -> f64 {
        (self.d_model * self.vocab) as f64
    }

    /// Attention+FFN decode time at batch `b` on `gpu` (per TP rank).
    pub fn backbone_time(&self, gpu: &GpuSpec, b: usize) -> f64 {
        let weight_bytes =
            (self.params - self.lm_head_params()) * 2.0 / self.tp as f64;
        // KV read: 2 (K+V) * layers * context * d_model * bf16 per sequence.
        let kv_bytes = 2.0
            * self.n_layers as f64
            * AVG_CONTEXT
            * self.d_model as f64
            * GQA_KV_FRACTION
            * 2.0
            * b as f64
            / self.tp as f64;
        let mem = (weight_bytes + kv_bytes) / (gpu.hbm_bw * gpu.bw_efficiency);
        let dispatch =
            self.n_layers as f64 * KERNELS_PER_LAYER * gpu.launch_overhead;
        // TP>1 backbones all-reduce activations twice per layer.
        let comm = if self.tp > 1 {
            self.n_layers as f64
                * 2.0
                * (gpu.collective_latency
                    + (b * self.d_model * 2) as f64 / gpu.nvlink_bw)
        } else {
            0.0
        };
        mem + dispatch + comm + HOST_OVERHEAD
    }

    /// LM head + sampling time at batch `b` for `method`.
    pub fn lm_head_time(&self, gpu: &GpuSpec, b: usize, method: Method) -> f64 {
        let w = Workload::new(b, self.d_model, self.vocab);
        let t = interconnect::tp_runtime(gpu, method, w, self.tp);
        if method == Method::FlashSampling {
            t
        } else {
            t + SAMPLER_HOST_OVERHEAD
        }
    }

    /// Modeled TPOT (seconds/token) at batch `b`.
    pub fn tpot(&self, gpu: &GpuSpec, b: usize, method: Method) -> f64 {
        self.backbone_time(gpu, b) + self.lm_head_time(gpu, b, method)
    }

    /// TPOT reduction of FlashSampling vs the vLLM baseline
    /// (Table 8's percentage: 1 - flash/baseline).
    pub fn tpot_reduction(&self, gpu: &GpuSpec, b: usize) -> f64 {
        let base = self.tpot(gpu, b, Method::Fi1); // vLLM default sampler path
        let flash = self.tpot(gpu, b, Method::FlashSampling);
        1.0 - flash / base
    }

    /// Modeled prefill (prompt-processing) time for one request of
    /// `prompt_tokens`, of which a `cached_fraction` is served by the
    /// automatic prefix cache (DESIGN.md §10) and never recomputed.
    ///
    /// Prefill is compute-bound: `2 · params · uncached_tokens` FLOPs at
    /// [`PREFILL_MFU`], floored by one streaming pass over the weights
    /// (tiny uncached suffixes still read every layer once) plus the
    /// per-layer dispatch chain and host overhead — the irreducible TTFT
    /// term a 100% hit rate converges to.
    pub fn prefill_time(
        &self,
        gpu: &GpuSpec,
        prompt_tokens: usize,
        cached_fraction: f64,
    ) -> f64 {
        let uncached =
            prompt_tokens as f64 * (1.0 - cached_fraction.clamp(0.0, 1.0));
        let flops = 2.0 * self.params * uncached / self.tp as f64;
        let compute = flops / (gpu.bf16_flops * PREFILL_MFU);
        let weight_stream =
            self.params * 2.0 / self.tp as f64 / (gpu.hbm_bw * gpu.bw_efficiency);
        let dispatch =
            self.n_layers as f64 * KERNELS_PER_LAYER * gpu.launch_overhead;
        compute.max(weight_stream) + dispatch + HOST_OVERHEAD
    }

    /// Modeled time-to-first-token: prefill of the uncached prompt
    /// remainder, plus one LM-head + sampling pass for the first output
    /// token (at prefill batch `b`).
    pub fn ttft(
        &self,
        gpu: &GpuSpec,
        b: usize,
        prompt_tokens: usize,
        cached_fraction: f64,
        method: Method,
    ) -> f64 {
        self.prefill_time(gpu, prompt_tokens, cached_fraction)
            + self.lm_head_time(gpu, b, method)
    }

    /// TTFT reduction from prefix caching at a given hit fraction
    /// (`1 - ttft(cached) / ttft(uncached)`), the headline of
    /// `BENCH_prefixcache.json`.
    pub fn ttft_reduction(
        &self,
        gpu: &GpuSpec,
        b: usize,
        prompt_tokens: usize,
        cached_fraction: f64,
    ) -> f64 {
        let base = self.ttft(gpu, b, prompt_tokens, 0.0, Method::FlashSampling);
        let hit =
            self.ttft(gpu, b, prompt_tokens, cached_fraction, Method::FlashSampling);
        1.0 - hit / base
    }

    /// Modeled speculative-decode TPOT (seconds/token) at batch `b`.
    ///
    /// One round = K draft forwards + one batched verify pass, amortized
    /// over the expected emitted tokens.  The verify pass streams the
    /// target weights **once** for all K+1 scored positions — the
    /// spec-decode premise that decode is bandwidth-bound, so scoring a
    /// short token block costs ≈ one decode step — while the LM head +
    /// fused sampling epilogue runs at the inflated batch `b·(K+1)` (every
    /// position of every row samples).  Draft forwards cost
    /// `draft_cost` × one target decode step each.
    pub fn spec_tpot(&self, gpu: &GpuSpec, b: usize, sd: SpecDecodeModel) -> f64 {
        let draft = sd.k as f64 * sd.draft_cost * self.backbone_time(gpu, b);
        let verify = self.backbone_time(gpu, b)
            + self.lm_head_time(gpu, b * (sd.k + 1), Method::FlashSampling);
        (draft + verify) / sd.expected_tokens()
    }

    /// Speedup of speculative decode over plain FlashSampling decode —
    /// the number that says whether a (K, acceptance, draft-cost) point
    /// pays for itself on a given GPU spec.
    pub fn spec_tpot_speedup(
        &self,
        gpu: &GpuSpec,
        b: usize,
        sd: SpecDecodeModel,
    ) -> f64 {
        self.tpot(gpu, b, Method::FlashSampling) / self.spec_tpot(gpu, b, sd)
    }
}

/// Speculative-decode operating point for the TPOT model (DESIGN.md §9):
/// draft length K, per-token acceptance probability α (measured by
/// `ServingMetrics::spec_acceptance_rate` / the `specdec` bench), and the
/// draft model's relative cost.
#[derive(Clone, Copy, Debug)]
pub struct SpecDecodeModel {
    /// Draft length K (`specdec:k=K`).
    pub k: usize,
    /// Per-token draft acceptance probability α in [0, 1].
    pub acceptance: f64,
    /// One draft forward as a fraction of one target decode step
    /// (≈0 for the n-gram drafter, ~0.1–0.3 for a small model head).
    pub draft_cost: f64,
}

impl SpecDecodeModel {
    /// Expected emitted tokens per round under i.i.d. per-token
    /// acceptance: `E = 1 + α + α² + … + α^K` (accepted prefix plus the
    /// residual/bonus token) — 1 at α = 0, K+1 at α = 1.
    pub fn expected_tokens(&self) -> f64 {
        (0..=self.k).map(|i| self.acceptance.powi(i as i32)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::B200;

    const BATCHES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

    #[test]
    fn small_models_gain_most() {
        // Paper Table 8: 1.7B/8B peak ~8-10%; 32B/70B peak ~2-3%.
        for b in [8usize, 32] {
            let small = QWEN3_1_7B.tpot_reduction(&B200, b);
            let large = LLAMA33_70B.tpot_reduction(&B200, b);
            assert!(small > large, "B={b}: {small} !> {large}");
            assert!(small > 0.04 && small < 0.20, "1.7B B={b}: {small}");
            assert!(large > 0.002 && large < 0.06, "70B B={b}: {large}");
        }
    }

    #[test]
    fn reductions_positive_across_sweep() {
        for m in PAPER_MODELS {
            for &b in &BATCHES {
                let r = m.tpot_reduction(&B200, b);
                assert!(r > 0.0, "{} B={b}: {r}", m.name);
            }
        }
    }

    #[test]
    fn tpot_magnitudes_are_plausible() {
        // Paper Table 7 scale anchors (median TPOT, ms): Qwen3-1.7B ≈ 1.8,
        // Qwen3-8B ≈ 3.9, Qwen3-32B ≈ 7.7-8.7, Llama-70B ≈ 14-18.
        let t17 = QWEN3_1_7B.tpot(&B200, 1, Method::Fi1) * 1e3;
        assert!((1.0..3.2).contains(&t17), "1.7B: {t17} ms");
        let t8 = QWEN3_8B.tpot(&B200, 1, Method::Fi1) * 1e3;
        assert!((2.5..5.5).contains(&t8), "8B: {t8} ms");
        let t32 = QWEN3_32B.tpot(&B200, 1, Method::Fi1) * 1e3;
        assert!((5.0..11.0).contains(&t32), "32B: {t32} ms");
        let t70 = LLAMA33_70B.tpot(&B200, 1, Method::Fi1) * 1e3;
        assert!((10.0..20.0).contains(&t70), "70B: {t70} ms");
    }

    #[test]
    fn tpot_grows_with_batch() {
        for m in PAPER_MODELS {
            let a = m.tpot(&B200, 1, Method::FlashSampling);
            let b = m.tpot(&B200, 64, Method::FlashSampling);
            assert!(b > a, "{}", m.name);
        }
    }

    #[test]
    fn ttft_monotone_decreasing_in_cached_fraction() {
        for m in PAPER_MODELS {
            // Strictly decreasing while the uncached suffix stays
            // compute-bound (B200 roofline crossover ~165 tokens)...
            let mut prev = f64::INFINITY;
            for f in [0.0, 0.25, 0.5, 0.75, 0.9] {
                let t = m.ttft(&B200, 4, 2048, f, Method::FlashSampling);
                assert!(t < prev, "{} cached={f}: {t} !< {prev}", m.name);
                assert!(t > 0.0);
                prev = t;
            }
            // ...then plateaus at the weight-stream floor (never rises).
            let t = m.ttft(&B200, 4, 2048, 1.0, Method::FlashSampling);
            assert!(t <= prev, "{}: {t} above the 0.9 point {prev}", m.name);
        }
    }

    #[test]
    fn ttft_magnitudes_and_floor_are_plausible() {
        // 2k-token prompt on Qwen3-8B/B200: ~15 ms modeled prefill at
        // MFU 0.5 (2 * 8.2e9 * 2048 / (2250e12 * 0.5)); the fully-cached
        // floor keeps the weight-stream + dispatch + host terms.
        let cold = QWEN3_8B.prefill_time(&B200, 2048, 0.0);
        assert!((5e-3..50e-3).contains(&cold), "cold: {cold}");
        let floor = QWEN3_8B.prefill_time(&B200, 2048, 1.0);
        assert!(floor > 0.0 && floor < cold / 3.0, "floor: {floor}");
        // The floor never depends on the prompt length.
        assert!(
            (QWEN3_8B.prefill_time(&B200, 64, 1.0) - floor).abs() < 1e-12
        );
    }

    #[test]
    fn ttft_reduction_tracks_the_cached_share() {
        // Long prompts are compute-dominated, so a 90% hit rate recovers
        // most (but never more) of the prefill term.
        for m in PAPER_MODELS {
            let r = m.ttft_reduction(&B200, 4, 4096, 0.9);
            assert!(r > 0.5, "{}: {r}", m.name);
            assert!(r < 0.9 + 1e-9, "{}: {r}", m.name);
            assert!(m.ttft_reduction(&B200, 4, 4096, 0.0).abs() < 1e-12);
        }
        // Short prompts amortize less: the overhead floor dominates.
        let short = QWEN3_8B.ttft_reduction(&B200, 4, 128, 0.9);
        let long = QWEN3_8B.ttft_reduction(&B200, 4, 4096, 0.9);
        assert!(short < long, "{short} !< {long}");
    }

    #[test]
    fn spec_expected_tokens_formula() {
        let e = |k, a| SpecDecodeModel { k, acceptance: a, draft_cost: 0.1 }
            .expected_tokens();
        assert!((e(4, 0.0) - 1.0).abs() < 1e-12); // nothing accepted
        assert!((e(4, 1.0) - 5.0).abs() < 1e-12); // everything accepted
        assert!((e(2, 0.5) - 1.75).abs() < 1e-12); // 1 + 1/2 + 1/4
        // Monotone in both K and acceptance.
        assert!(e(8, 0.8) > e(4, 0.8));
        assert!(e(4, 0.9) > e(4, 0.5));
    }

    #[test]
    fn spec_decode_pays_off_at_high_acceptance_only() {
        for m in PAPER_MODELS {
            for &b in &[1usize, 8] {
                // Cheap drafter at good acceptance: a real win.
                let good = SpecDecodeModel { k: 4, acceptance: 0.8, draft_cost: 0.05 };
                let s = m.spec_tpot_speedup(&B200, b, good);
                assert!(s > 1.0, "{} B={b}: speedup {s}", m.name);
                // Nothing ever accepted: pure overhead, guaranteed loss.
                let bad = SpecDecodeModel { k: 4, acceptance: 0.0, draft_cost: 0.05 };
                let s = m.spec_tpot_speedup(&B200, b, bad);
                assert!(s < 1.0, "{} B={b}: speedup {s}", m.name);
            }
        }
    }

    #[test]
    fn spec_speedup_monotone_in_acceptance() {
        let mk = |a| SpecDecodeModel { k: 4, acceptance: a, draft_cost: 0.1 };
        let mut prev = 0.0;
        for a in [0.0, 0.25, 0.5, 0.75, 0.95] {
            let s = QWEN3_8B.spec_tpot_speedup(&B200, 8, mk(a));
            assert!(s > prev, "acceptance {a}: {s} !> {prev}");
            prev = s;
        }
    }

    #[test]
    fn expensive_drafters_erase_the_win() {
        // At draft_cost → 1 (draft as costly as the target), even perfect
        // acceptance barely breaks even across K forwards.
        let sd = SpecDecodeModel { k: 4, acceptance: 0.8, draft_cost: 1.0 };
        for m in PAPER_MODELS {
            assert!(
                m.spec_tpot_speedup(&B200, 8, sd) < 1.0,
                "{}",
                m.name
            );
        }
    }

    #[test]
    fn lm_head_share_explains_the_gain() {
        // The paper's stated mechanism: reduction ∝ LM-head time share.
        for m in PAPER_MODELS {
            let share = m.lm_head_time(&B200, 8, Method::Fi1)
                / m.tpot(&B200, 8, Method::Fi1);
            let red = m.tpot_reduction(&B200, 8);
            assert!(red < share, "{}: reduction {red} vs share {share}", m.name);
            assert!(red > share * 0.1, "{}", m.name);
        }
    }
}
