//! Minimal property-testing harness (offline substitute for `proptest`).
//!
//! Drives randomized test cases from the crate's own Philox streams so every
//! failure is reproducible from `(seed, case)` — the panic message names the
//! failing case.  Used by unit tests and benches; deliberately tiny.

use crate::sampling::philox::{self, Key};

/// Deterministic per-case value generator.
pub struct Gen {
    key: Key,
    case: u32,
    ctr: u32,
}

impl Gen {
    pub fn new(seed: u64, case: u32) -> Self {
        Self { key: Key::from_seed(seed), case, ctr: 0 }
    }

    fn next_u32(&mut self) -> u32 {
        let out = philox::philox4x32_10(
            [self.ctr, self.case, 0xFEED, 0],
            [self.key.lo, self.key.hi],
        )[0];
        self.ctr += 1;
        out
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform u32 in [lo, hi] inclusive.
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + (self.next_u32() as u64 % (hi as u64 - lo as u64 + 1)) as u32
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u32_in(lo as u32, hi as u32) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + philox::uniform_open01(self.next_u32()) * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f32) -> bool {
        philox::uniform_open01(self.next_u32()) < p
    }
}

/// Engine-mirroring scheduler simulation: the reusable property-test
/// harness behind the chunked-prefill / swap-tier certification suite
/// (rust/tests/chunked_prefill.rs, `repro chunk-identity`).
///
/// Drives the REAL [`crate::coordinator::scheduler::plan`] and the REAL
/// [`crate::kvcache::KvCacheManager`] (admission, registration, swap
/// ledger) through randomized arrival/abort/preempt schedules — only the
/// artifact execution is replaced by Philox *coordinate accounting*: each
/// "sampled token" is a Philox draw over (batch row, consumption step,
/// request id), where the consumption step advances exactly when the
/// engine would bump its Philox step counter (once per sampling prefill
/// batch, once per decode batch — chunk windows advance nothing).  Two
/// schedules with equal outcome maps would therefore produce bit-identical
/// token streams on the real engine; that equality is the replay-identity
/// certificate `assert_chunk_identity` checks.
///
/// Scope note: sticky-chunk identity is certified for closed-loop scripts
/// (all arrivals before the first step).  A mid-window arrival changes the
/// final chunk's batch companions — exactly like `chunk_interleave`, that
/// reshapes coordinates without changing the sampled distribution — so
/// open-loop scripts assert the balance/starvation invariants only.
pub mod schedsim {
    use std::collections::{HashMap, VecDeque};

    use crate::coordinator::request::{
        Request, SamplingParams, SeqState, Sequence,
    };
    use crate::coordinator::scheduler::{plan, Plan, SchedulerConfig};
    use crate::kvcache::{KvCacheConfig, KvCacheManager};
    use crate::metrics::ServingMetrics;
    use crate::sampling::philox::{self, Key};
    use crate::trace::{EventKind, Trace, TraceLevel};

    /// One scripted request.
    #[derive(Clone, Debug)]
    pub struct SimRequest {
        pub id: u64,
        pub prompt_len: usize,
        pub max_new_tokens: usize,
        /// Logical step at which the request is submitted (0 = before the
        /// first step).
        pub arrival_step: u64,
    }

    /// How a simulated request ended.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Finish {
        Done,
        Aborted,
        Rejected,
        /// Finish-early preemption: pool exhausted, no swap capacity.
        Preempted,
        /// Swap tier drained by the livelock guard.
        Abandoned,
    }

    /// Outcome certificate of one request.
    #[derive(Clone, Debug, PartialEq)]
    pub struct SimOutcome {
        /// Philox coordinate draws standing in for sampled tokens.
        pub tokens: Vec<u32>,
        /// (batch row, consumption step) of the first token.
        pub first_token: Option<(usize, u32)>,
        /// Token-weighted time of the first token: a prefill of T tokens
        /// costs T units, a chunk window costs its window, decode and
        /// idle steps cost 1 — the cost model behind the TTFT-under-load
        /// regression test.
        pub ttft_weighted: Option<u64>,
        /// Token-weighted timestamp of EVERY emitted token (first entry ==
        /// `ttft_weighted`); consecutive differences are the inter-token
        /// latencies the serving bench reports.
        pub token_times: Vec<u64>,
        pub finish: Option<Finish>,
    }

    /// Simulator configuration: the REAL scheduler config + pool shape +
    /// scripted fault events.
    #[derive(Clone, Debug)]
    pub struct SimConfig {
        pub sched: SchedulerConfig,
        pub kv_blocks: usize,
        pub kv_block_size: usize,
        /// Swap-ledger capacity in blocks (0 = swap tier off).
        pub swap_blocks: usize,
        pub seed: u64,
        /// Step-count guard: exceeding it fails the run (starvation /
        /// livelock).
        pub max_steps: u64,
        /// Forced preemptions (clock step, request id): swap the victim
        /// out mid-flight when ledger capacity allows.
        pub force_preempt: Vec<(u64, u64)>,
        /// Forced aborts (clock step, request id): cancel wherever the
        /// request currently lives (waiting / partial / running /
        /// swapped).
        pub force_abort: Vec<(u64, u64)>,
        /// Speculative-decode draft depth (0 = ordinary decode).  When
        /// set, decode batches run the burst mirror: `k + 1` consumption
        /// steps per batch, each row emitting 1..=k+1 tokens anchored at
        /// the burst's first step — the shape behind the engine's
        /// `SpecBurst` trace events.
        pub spec_k: usize,
        /// Flight-recorder level for [`Sim::trace`]; `Off` (the default)
        /// records nothing, mirroring the engine's config key.
        pub trace_level: TraceLevel,
    }

    impl SimConfig {
        /// Default testbed mirroring the test artifact shapes
        /// (buckets [1,2,4,8] / t [16,64] / prefill_b 4).
        pub fn small(kv_blocks: usize) -> Self {
            Self {
                sched: SchedulerConfig {
                    decode_buckets: vec![1, 2, 4, 8],
                    prefill_t_buckets: vec![16, 64],
                    prefill_b: 4,
                    max_concurrency: 8,
                    max_tokens_per_step: 1,
                    aging_steps: 0,
                    prefill_chunk_tokens: 0,
                    chunk_interleave: false,
                },
                kv_blocks,
                kv_block_size: 16,
                swap_blocks: 0,
                seed: 0x5C4E_D514,
                max_steps: 20_000,
                force_preempt: Vec::new(),
                force_abort: Vec::new(),
                spec_k: 0,
                trace_level: TraceLevel::Off,
            }
        }
    }

    /// Philox coordinate stand-in for one sampled token: any change to
    /// the (row, consumption-step) coordinates a request samples at shows
    /// up as a different value, so outcome-map equality certifies replay
    /// identity.
    fn coord(key: [u32; 2], row: usize, cstep: u32, id: u64) -> u32 {
        philox::philox4x32_10([row as u32, cstep, 0x57E9, id as u32], key)[0]
    }

    pub struct Sim {
        cfg: SimConfig,
        kv: KvCacheManager,
        key: [u32; 2],
        waiting: VecDeque<Sequence>,
        running: Vec<Sequence>,
        swapped: Vec<Sequence>,
        clock: u64,
        /// Mirror of the engine's Philox step counter (consumption steps).
        cstep: u32,
        /// Token-weighted clock (see [`SimOutcome::ttft_weighted`]).
        wtime: u64,
        pub outcomes: HashMap<u64, SimOutcome>,
        pub chunk_windows: u64,
        pub swap_out_blocks: u64,
        pub swap_in_blocks: u64,
        /// Engine-shaped serving counters, bumped at the same sites the
        /// engine bumps them — the reference side of the trace-vs-metrics
        /// certificate (`repro trace-identity`).
        pub metrics: ServingMetrics,
        /// Flight recorder fed at the same sites as the engine's; with
        /// [`SimConfig::trace_level`] at `Off` every site is one branch.
        pub trace: Trace,
        /// Baseline for per-step KV-delta events (alloc / free / CoW /
        /// radix-evict), as in `Engine::emit_kv_deltas`.
        kv_base: [u64; 4],
    }

    /// Run a script to quiescence and return the outcome map.  Panics on
    /// any invariant violation (block-ledger imbalance, swap-ledger
    /// desync, leak at quiescence, starvation guard).
    pub fn run(
        cfg: SimConfig,
        requests: &[SimRequest],
    ) -> HashMap<u64, SimOutcome> {
        let mut sim = Sim::new(cfg);
        sim.drive(requests);
        sim.outcomes
    }

    impl Sim {
        pub fn new(cfg: SimConfig) -> Self {
            let mut kv = KvCacheManager::new(KvCacheConfig {
                block_size: cfg.kv_block_size,
                num_blocks: cfg.kv_blocks,
                prefix_caching: false,
            });
            kv.set_swap_capacity(cfg.swap_blocks);
            let k = Key::from_seed(cfg.seed);
            let trace = Trace::new(cfg.trace_level);
            Self {
                key: [k.lo, k.hi],
                cfg,
                kv,
                waiting: VecDeque::new(),
                running: Vec::new(),
                swapped: Vec::new(),
                clock: 0,
                cstep: 0,
                wtime: 0,
                outcomes: HashMap::new(),
                chunk_windows: 0,
                swap_out_blocks: 0,
                swap_in_blocks: 0,
                metrics: ServingMetrics::default(),
                trace,
                kv_base: [0; 4],
            }
        }

        fn pending(&self) -> usize {
            self.waiting.len() + self.running.len() + self.swapped.len()
        }

        pub fn drive(&mut self, requests: &[SimRequest]) {
            let mut reqs: Vec<SimRequest> = requests.to_vec();
            reqs.sort_by_key(|r| r.arrival_step);
            let mut next = 0usize;
            let mut steps = 0u64;
            while next < reqs.len() || self.pending() > 0 {
                while next < reqs.len()
                    && reqs[next].arrival_step <= self.clock
                {
                    self.submit(&reqs[next]);
                    next += 1;
                }
                if self.pending() == 0 {
                    // Idle until the next arrival.
                    self.clock += 1;
                    self.wtime += 1;
                    continue;
                }
                let progressed = self.step();
                if !progressed && self.running.is_empty() {
                    self.reject_unschedulable();
                }
                self.assert_balance();
                steps += 1;
                assert!(
                    steps <= self.cfg.max_steps,
                    "no-starvation guard tripped after {steps} steps \
                     (pending={})",
                    self.pending()
                );
            }
            // Quiescence: zero leaks, empty swap tier.
            assert_eq!(
                self.kv.unaccounted_blocks(),
                0,
                "leaked KV blocks at quiescence"
            );
            assert_eq!(self.kv.swapped_blocks(), 0, "stranded swap ledger");
            assert!(self.swapped.is_empty());
        }

        fn submit(&mut self, r: &SimRequest) {
            self.outcomes.insert(
                r.id,
                SimOutcome {
                    tokens: Vec::new(),
                    first_token: None,
                    ttft_weighted: None,
                    token_times: Vec::new(),
                    finish: None,
                },
            );
            // Mirror of the engine's submit-time rejection: oversized
            // prompts are only servable with chunking on.  As in the
            // engine, a submit-time rejection traces `reject` (no
            // `submit`, no `finish` — the request never completes).
            let max_t = *self.cfg.sched.prefill_t_buckets.last().unwrap();
            if self.cfg.sched.prefill_chunk_tokens == 0 && r.prompt_len > max_t
            {
                if self.trace.on() {
                    self.trace.emit(
                        self.clock,
                        r.id,
                        EventKind::Reject {
                            reason: format!(
                                "prompt of {} tokens exceeds the largest \
                                 prefill bucket {max_t}",
                                r.prompt_len
                            ),
                        },
                    );
                }
                self.outcomes.get_mut(&r.id).unwrap().finish =
                    Some(Finish::Rejected);
                return;
            }
            if self.trace.on() {
                self.trace.emit(
                    self.clock,
                    r.id,
                    EventKind::Submit {
                        prompt_len: r.prompt_len,
                        max_new: r.max_new_tokens,
                    },
                );
            }
            let mut s = Sequence::new(Request::new(
                r.id,
                vec![(r.id % 97) as i32 + 1; r.prompt_len],
                SamplingParams {
                    max_new_tokens: r.max_new_tokens,
                    ..Default::default()
                },
            ));
            s.submitted_step = self.clock;
            self.waiting.push_back(s);
        }

        /// One engine step; returns whether any token/completion landed.
        fn step(&mut self) -> bool {
            self.clock += 1;
            self.forced_aborts();
            self.swap_in_ready();
            self.forced_preempts();
            self.waiting.make_contiguous();
            let (waiting, _) = self.waiting.as_slices();
            let mut admission = self.kv.batch_admission();
            let p = plan(
                &self.cfg.sched,
                waiting,
                &self.running,
                |s, burst| admission.admit(&self.kv, &s.prompt, burst),
                |s| self.kv.cached_prefix_tokens(&s.prompt),
                self.clock,
            );
            if self.trace.full() {
                let (outcome, batch) = match &p {
                    Plan::ChunkPrefill { .. } => ("chunk_prefill", 1),
                    Plan::Prefill { seq_ids, .. } => ("prefill", seq_ids.len()),
                    Plan::Decode { seq_ids, .. } => ("decode", seq_ids.len()),
                    Plan::Idle => ("idle", 0),
                };
                self.trace
                    .emit(self.clock, 0, EventKind::Plan { outcome, batch });
                let aging = self.cfg.sched.aging_steps;
                if aging > 0 {
                    let promoted = self
                        .waiting
                        .iter()
                        .filter(|s| {
                            self.clock.saturating_sub(s.submitted_step) >= aging
                        })
                        .count();
                    if promoted > 0 {
                        self.trace.emit(
                            self.clock,
                            0,
                            EventKind::Promote { count: promoted as u64 },
                        );
                    }
                }
            }
            let progressed = match p {
                Plan::ChunkPrefill { seq_id } => {
                    self.do_chunk(seq_id);
                    false
                }
                Plan::Prefill { seq_ids, .. } => self.do_prefill(&seq_ids),
                Plan::Decode { seq_ids, .. } => self.do_decode(&seq_ids),
                Plan::Idle => {
                    self.wtime += 1;
                    false
                }
            };
            if self.trace.full() {
                self.emit_kv_deltas();
            }
            progressed
        }

        /// Mirror of `Engine::emit_kv_deltas`: `Full`-level per-step
        /// deltas of the pool's monotone bookkeeping counters.
        fn emit_kv_deltas(&mut self) {
            let now = [
                self.kv.stat_alloc_blocks(),
                self.kv.stat_freed_blocks(),
                self.kv.stat_cow_forks(),
                self.kv.evicted_blocks(),
            ];
            let d: Vec<u64> = now
                .iter()
                .zip(self.kv_base.iter())
                .map(|(n, b)| n.saturating_sub(*b))
                .collect();
            self.kv_base = now;
            for (i, kind) in [
                EventKind::KvAlloc { blocks: d[0] },
                EventKind::KvFree { blocks: d[1] },
                EventKind::KvCow { blocks: d[2] },
                EventKind::RadixEvict { blocks: d[3] },
            ]
            .into_iter()
            .enumerate()
            {
                if d[i] > 0 {
                    self.trace.emit(self.clock, 0, kind);
                }
            }
        }

        fn forced_aborts(&mut self) {
            let clock = self.clock;
            let ids: Vec<u64> = self
                .cfg
                .force_abort
                .iter()
                .filter(|(at, _)| *at == clock)
                .map(|(_, id)| *id)
                .collect();
            for id in ids {
                self.abort(id);
            }
        }

        fn abort(&mut self, id: u64) {
            if let Some(i) = self.waiting.iter().position(|s| s.id == id) {
                let s = self.waiting.remove(i).unwrap();
                // A partial head IS registered — release or leak.
                if s.prefilled_tokens > 0 {
                    self.kv.release(s.id).expect("partial head registered");
                }
                self.finish(s, Finish::Aborted);
            } else if let Some(i) =
                self.running.iter().position(|s| s.id == id)
            {
                let s = self.running.remove(i);
                self.kv.release(s.id).expect("running seq registered");
                self.finish(s, Finish::Aborted);
            } else if let Some(i) =
                self.swapped.iter().position(|s| s.id == id)
            {
                let s = self.swapped.remove(i);
                self.kv.release(s.id).expect("swapped seq registered");
                self.finish(s, Finish::Aborted);
            }
        }

        fn forced_preempts(&mut self) {
            let clock = self.clock;
            let ids: Vec<u64> = self
                .cfg
                .force_preempt
                .iter()
                .filter(|(at, _)| *at == clock)
                .map(|(_, id)| *id)
                .collect();
            for id in ids {
                let Some(ri) = self.running.iter().position(|s| s.id == id)
                else {
                    continue;
                };
                if let Ok(Some(n)) = self.kv.swap_out(id) {
                    self.swap_out_blocks += n as u64;
                    self.metrics.swap_out_blocks += n as u64;
                    self.metrics.bump("swapped_out_seqs", 1);
                    if self.trace.on() {
                        self.trace.emit(
                            self.clock,
                            id,
                            EventKind::Preempt { kind: "swap" },
                        );
                        self.trace.emit(
                            self.clock,
                            id,
                            EventKind::SwapOut { blocks: n as u64 },
                        );
                    }
                    let mut s = self.running.remove(ri);
                    s.state = SeqState::Preempted;
                    self.swapped.push(s);
                }
            }
        }

        /// Mirror of `Engine::swap_in_ready`, including the one-token
        /// deficit reconcile and the park-it-back fallback.
        fn swap_in_ready(&mut self) {
            while !self.swapped.is_empty()
                && self.running.len() < self.cfg.sched.max_concurrency
            {
                let id = self.swapped[0].id;
                match self.kv.swap_in(id).expect("ledger consistent") {
                    Some(n) => {
                        self.swap_in_blocks += n as u64;
                        self.metrics.swap_in_blocks += n as u64;
                        if self.trace.on() {
                            self.trace.emit(
                                self.clock,
                                id,
                                EventKind::SwapIn { blocks: n as u64 },
                            );
                        }
                        let mut s = self.swapped.remove(0);
                        let table_len =
                            self.kv.table(id).map_or(0, |t| t.len());
                        let mut ok = true;
                        for _ in table_len..s.context_len() {
                            if !self.kv.append_token(id).expect("registered")
                            {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            s.state = SeqState::Running;
                            self.running.push(s);
                        } else {
                            let n = self
                                .kv
                                .swap_out(id)
                                .expect("registered")
                                .expect("capacity was just vacated");
                            self.swap_out_blocks += n as u64;
                            self.metrics.swap_out_blocks += n as u64;
                            // Park-back, not a preemption: no `preempt`
                            // event, no `swapped_out_seqs` bump (the
                            // engine's split exactly).
                            if self.trace.on() {
                                self.trace.emit(
                                    self.clock,
                                    id,
                                    EventKind::SwapOut { blocks: n as u64 },
                                );
                            }
                            self.swapped.insert(0, s);
                            break;
                        }
                    }
                    None => break,
                }
            }
        }

        fn do_chunk(&mut self, seq_id: u64) {
            let idx = self
                .waiting
                .iter()
                .position(|s| s.id == seq_id)
                .expect("planned head vanished");
            let mut s = self.waiting.remove(idx).unwrap();
            if s.prefilled_tokens == 0 {
                match self.kv.register_with_prefix(s.id, &s.prompt) {
                    Ok(a) => {
                        s.prefilled_tokens = a.cached_tokens;
                        if a.cached_tokens > 0 {
                            self.metrics.cached_prefill_tokens +=
                                a.cached_tokens as u64;
                            if self.trace.on() {
                                self.trace.emit(
                                    self.clock,
                                    s.id,
                                    EventKind::RadixAttach {
                                        tokens: a.cached_tokens as u64,
                                    },
                                );
                            }
                        }
                    }
                    Err(_) => {
                        self.waiting.push_front(s);
                        return;
                    }
                }
            }
            let max_t = *self.cfg.sched.prefill_t_buckets.last().unwrap();
            let chunk = self.cfg.sched.prefill_chunk_tokens.min(max_t);
            let take = chunk.min(
                s.prompt
                    .len()
                    .saturating_sub(1)
                    .saturating_sub(s.prefilled_tokens),
            );
            s.prefilled_tokens += take;
            self.chunk_windows += 1;
            self.metrics.chunked_prefill_steps += 1;
            if self.trace.on() {
                self.trace.emit(
                    self.clock,
                    s.id,
                    EventKind::ChunkWindow { take, prefilled: s.prefilled_tokens },
                );
            }
            self.wtime += take.max(1) as u64;
            // No consumption step: chunk windows draw no Philox noise.
            self.waiting.push_front(s);
        }

        fn emit(
            outcomes: &mut HashMap<u64, SimOutcome>,
            wtime: u64,
            s: &mut Sequence,
            tok: u32,
            row: usize,
            cstep: u32,
        ) {
            s.generated.push(tok as i32);
            let o = outcomes.get_mut(&s.id).expect("submitted");
            o.tokens.push(tok);
            o.token_times.push(wtime);
            if o.first_token.is_none() {
                o.first_token = Some((row, cstep));
                o.ttft_weighted = Some(wtime);
            }
        }

        /// Mirror of `Engine::complete_seq`'s accounting: one completion
        /// per finish, the same counter splits, and the same `finish`
        /// reason names the engine's trace carries.
        fn finish(&mut self, s: Sequence, f: Finish) {
            self.metrics.requests_completed += 1;
            let reason = match f {
                Finish::Done => "max_tokens",
                Finish::Aborted => {
                    self.metrics.bump("aborted", 1);
                    "aborted"
                }
                Finish::Rejected => "rejected",
                // Finish-early preemption completes as `max_tokens` with
                // the `preempted` counter bumped at the preempt site.
                Finish::Preempted => "max_tokens",
                Finish::Abandoned => {
                    self.metrics.bump("swap_abandoned", 1);
                    "max_tokens"
                }
            };
            if self.trace.on() {
                self.trace.emit(
                    self.clock,
                    s.id,
                    EventKind::Finish {
                        reason,
                        tokens: s.generated.len() as u64,
                    },
                );
            }
            self.outcomes.get_mut(&s.id).expect("submitted").finish = Some(f);
        }

        /// Append-failure handling shared by prefill and decode: swap the
        /// victim when the ledger takes it, finish early otherwise.
        fn preempt_or_finish(&mut self, mut s: Sequence) {
            match self.kv.swap_out(s.id).expect("registered") {
                Some(n) => {
                    self.swap_out_blocks += n as u64;
                    self.metrics.swap_out_blocks += n as u64;
                    self.metrics.bump("swapped_out_seqs", 1);
                    if self.trace.on() {
                        self.trace.emit(
                            self.clock,
                            s.id,
                            EventKind::Preempt { kind: "swap" },
                        );
                        self.trace.emit(
                            self.clock,
                            s.id,
                            EventKind::SwapOut { blocks: n as u64 },
                        );
                    }
                    s.state = SeqState::Preempted;
                    self.swapped.push(s);
                }
                None => {
                    self.metrics.bump("preempted", 1);
                    if self.trace.on() {
                        self.trace.emit(
                            self.clock,
                            s.id,
                            EventKind::Preempt { kind: "recompute" },
                        );
                    }
                    self.kv.release(s.id).expect("registered");
                    self.finish(s, Finish::Preempted);
                }
            }
        }

        fn do_prefill(&mut self, seq_ids: &[u64]) -> bool {
            let mut seqs: Vec<Sequence> = Vec::with_capacity(seq_ids.len());
            for id in seq_ids {
                let idx = self
                    .waiting
                    .iter()
                    .position(|s| s.id == *id)
                    .expect("planned sequence vanished");
                seqs.push(self.waiting.remove(idx).unwrap());
            }
            let mut admitted: Vec<Sequence> = Vec::new();
            let mut cached: Vec<usize> = Vec::new();
            let mut requeue: Vec<Sequence> = Vec::new();
            for s in seqs {
                if s.prefilled_tokens > 0 {
                    cached.push(s.prefilled_tokens);
                    admitted.push(s);
                    continue;
                }
                match self.kv.register_with_prefix(s.id, &s.prompt) {
                    Ok(a) => {
                        if a.cached_tokens > 0 {
                            self.metrics.cached_prefill_tokens +=
                                a.cached_tokens as u64;
                            if self.trace.on() {
                                self.trace.emit(
                                    self.clock,
                                    s.id,
                                    EventKind::RadixAttach {
                                        tokens: a.cached_tokens as u64,
                                    },
                                );
                            }
                        }
                        cached.push(a.cached_tokens);
                        admitted.push(s);
                    }
                    Err(_) => requeue.push(s),
                }
            }
            for s in requeue.into_iter().rev() {
                self.waiting.push_front(s);
            }
            if admitted.is_empty() {
                return false;
            }
            let longest = admitted
                .iter()
                .zip(&cached)
                .map(|(s, &c)| {
                    s.prompt.len() - c.min(s.prompt.len().saturating_sub(1))
                })
                .max()
                .unwrap();
            self.wtime += longest.max(1) as u64;
            // One sample_hidden per prefill batch: one consumption step,
            // shared by every row.
            let cstep = self.cstep;
            self.cstep += 1;
            let key = self.key;
            for (row, mut s) in admitted.into_iter().enumerate() {
                let tok = coord(key, row, cstep, s.id);
                Self::emit(&mut self.outcomes, self.wtime, &mut s, tok, row, cstep);
                self.metrics.prefill_tokens += s.prompt.len() as u64;
                self.metrics.tokens_generated += 1;
                if self.trace.on() {
                    self.trace.emit(
                        self.clock,
                        s.id,
                        EventKind::Prefill { prompt_len: s.prompt.len() },
                    );
                    self.trace.emit(
                        self.clock,
                        s.id,
                        EventKind::FirstToken { row, cstep, token: tok as i32 },
                    );
                }
                if s.generated.len() >= s.params.max_new_tokens {
                    self.kv.release(s.id).expect("registered");
                    self.finish(s, Finish::Done);
                } else if !self.kv.append_token(s.id).expect("registered") {
                    self.preempt_or_finish(s);
                } else {
                    s.state = SeqState::Running;
                    self.running.push(s);
                }
            }
            true
        }

        fn do_decode(&mut self, seq_ids: &[u64]) -> bool {
            if self.cfg.spec_k > 0 {
                return self.do_spec_decode(seq_ids);
            }
            let rows: Vec<usize> = seq_ids
                .iter()
                .map(|id| {
                    self.running
                        .iter()
                        .position(|s| s.id == *id)
                        .expect("planned sequence vanished")
                })
                .collect();
            self.wtime += 1;
            let cstep = self.cstep;
            self.cstep += 1;
            let key = self.key;
            let wtime = self.wtime;
            let clock = self.clock;
            let mut retired: Vec<(usize, Option<Finish>)> = Vec::new();
            for (slot, &ri) in rows.iter().enumerate() {
                let s = &mut self.running[ri];
                let id = s.id;
                let tok = coord(key, slot, cstep, id);
                Self::emit(&mut self.outcomes, wtime, s, tok, slot, cstep);
                let done = s.generated.len() >= s.params.max_new_tokens;
                self.metrics.tokens_generated += 1;
                if self.trace.on() {
                    self.trace.emit(
                        clock,
                        id,
                        EventKind::DecodeToken {
                            row: slot,
                            cstep,
                            token: tok as i32,
                        },
                    );
                }
                if done {
                    retired.push((ri, Some(Finish::Done)));
                } else if !self.kv.append_token(id).expect("registered") {
                    retired.push((ri, None));
                }
            }
            retired.sort_by(|a, b| b.0.cmp(&a.0));
            for (ri, f) in retired {
                let s = self.running.remove(ri);
                match f {
                    Some(f) => {
                        self.kv.release(s.id).expect("registered");
                        self.finish(s, f);
                    }
                    None => self.preempt_or_finish(s),
                }
            }
            true
        }

        /// Speculative-decode mirror (`spec_k > 0`): one burst per row
        /// per decode batch.  The engine runs `k + 1` verify passes —
        /// `k + 1` Philox consumption steps — and each row emits
        /// `1..=k+1` tokens at coordinates anchored on the burst's first
        /// step, so the sim advances `cstep` by `k + 1` per batch and the
        /// accepted count is itself a deterministic Philox draw (replays
        /// are bit-identical).  Bookkeeping mirrors the engine's:
        /// `spec_draft_tokens` counts planned drafts, `spec_accepted` /
        /// `emitted` count what actually landed, and each non-final token
        /// appends KV (pool exhaustion preempts mid-burst).
        fn do_spec_decode(&mut self, seq_ids: &[u64]) -> bool {
            let rows: Vec<usize> = seq_ids
                .iter()
                .map(|id| {
                    self.running
                        .iter()
                        .position(|s| s.id == *id)
                        .expect("planned sequence vanished")
                })
                .collect();
            self.wtime += 1;
            let cstep0 = self.cstep;
            self.cstep += self.cfg.spec_k as u32 + 1;
            let key = self.key;
            let wtime = self.wtime;
            let clock = self.clock;
            let mut retired: Vec<(usize, Option<Finish>)> = Vec::new();
            for (slot, &ri) in rows.iter().enumerate() {
                let (id, remaining) = {
                    let s = &self.running[ri];
                    (s.id, s.params.max_new_tokens - s.generated.len())
                };
                let drafted = self.cfg.spec_k.min(remaining.saturating_sub(1));
                let planned = if drafted == 0 {
                    1
                } else {
                    coord(key, slot, cstep0, id) as usize % (drafted + 1) + 1
                };
                let mut emitted = 0usize;
                let mut fate: Option<Option<Finish>> = None;
                for t in 0..planned {
                    let cs = cstep0 + t as u32;
                    let tok = coord(key, slot, cs, id);
                    let s = &mut self.running[ri];
                    Self::emit(&mut self.outcomes, wtime, s, tok, slot, cs);
                    emitted += 1;
                    if s.generated.len() >= s.params.max_new_tokens {
                        fate = Some(Some(Finish::Done));
                        break;
                    }
                    if !self.kv.append_token(id).expect("registered") {
                        fate = Some(None);
                        break;
                    }
                }
                self.metrics.tokens_generated += emitted as u64;
                self.metrics.spec_tokens_per_step.push(emitted);
                self.metrics.bump("spec_draft_tokens", drafted as u64);
                self.metrics.bump("spec_accepted_tokens", emitted as u64 - 1);
                if self.trace.on() {
                    self.trace.emit(
                        clock,
                        id,
                        EventKind::SpecBurst {
                            row: slot,
                            cstep: cstep0,
                            drafted: drafted as u64,
                            accepted: emitted as u64 - 1,
                            emitted: emitted as u64,
                        },
                    );
                }
                if let Some(f) = fate {
                    retired.push((ri, f));
                }
            }
            retired.sort_by(|a, b| b.0.cmp(&a.0));
            for (ri, f) in retired {
                let s = self.running.remove(ri);
                match f {
                    Some(f) => {
                        self.kv.release(s.id).expect("registered");
                        self.finish(s, f);
                    }
                    None => self.preempt_or_finish(s),
                }
            }
            true
        }

        /// Mirror of `Engine::reject_unschedulable`, with the partial-head
        /// exemption and the swap-tier livelock guard.
        fn reject_unschedulable(&mut self) {
            if !self.running.is_empty() {
                return;
            }
            if self.waiting.front().is_some_and(|s| s.prefilled_tokens > 0) {
                return;
            }
            if let Some(s) = self.waiting.pop_front() {
                self.finish(s, Finish::Rejected);
                return;
            }
            if !self.swapped.is_empty() {
                let s = self.swapped.remove(0);
                self.kv.release(s.id).expect("registered");
                self.finish(s, Finish::Abandoned);
            }
        }

        /// Per-step ledger invariants: every non-free block is owned by a
        /// registered live sequence (KV balance), and the swap ledger
        /// tracks the swapped set exactly.
        fn assert_balance(&self) {
            let held: usize = self
                .waiting
                .iter()
                .filter(|s| s.prefilled_tokens > 0)
                .chain(self.running.iter())
                .chain(self.swapped.iter())
                .map(|s| self.kv.table(s.id).map_or(0, |t| t.num_blocks()))
                .sum();
            assert_eq!(
                self.kv.unaccounted_blocks(),
                held,
                "KV block ledger out of balance at step {}",
                self.clock
            );
            assert!(
                self.kv.swapped_blocks() <= self.cfg.swap_blocks,
                "swap ledger over capacity"
            );
            assert_eq!(
                self.kv.swapped_sequences(),
                self.swapped.len(),
                "swap ledger desynced from the swapped set"
            );
        }
    }

    /// Replay-identity certificate: run the script with sticky chunking
    /// at `chunk` and with chunking off, and assert every request's
    /// outcome — token values, first-token coordinates, finish — is
    /// identical.  (`ttft_weighted` is excluded: chunking legitimately
    /// reshapes time, never coordinates.)  Scripts must be closed-loop
    /// (`arrival_step == 0`); see the module docs.
    pub fn assert_chunk_identity(
        base: &SimConfig,
        chunk: usize,
        reqs: &[SimRequest],
    ) {
        assert!(
            reqs.iter().all(|r| r.arrival_step == 0),
            "identity certificates require closed-loop scripts"
        );
        let mut unchunked = base.clone();
        unchunked.sched.prefill_chunk_tokens = 0;
        let mut chunked = base.clone();
        chunked.sched.prefill_chunk_tokens = chunk;
        chunked.sched.chunk_interleave = false;
        let a = run(unchunked, reqs);
        let b = run(chunked, reqs);
        assert_eq!(a.len(), b.len());
        for (id, oa) in &a {
            let ob = &b[id];
            assert_eq!(
                oa.tokens, ob.tokens,
                "request {id}: token stream diverged under chunk={chunk}"
            );
            assert_eq!(
                oa.first_token, ob.first_token,
                "request {id}: first-token coordinates moved"
            );
            assert_eq!(oa.finish, ob.finish, "request {id}: finish diverged");
        }
    }
}

/// Run `n` randomized cases; panics identify the failing case id so it can
/// be replayed with `Gen::new(seed, case)`.
pub fn cases(n: u32, seed: u64, f: impl Fn(&mut Gen)) {
    for case in 0..n {
        let mut g = Gen::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed={seed:#x} case={case}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(1, 2);
        let mut b = Gen::new(1, 2);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(3, 4);
        for _ in 0..1000 {
            let x = g.u32_in(5, 9);
            assert!((5..=9).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn cases_run_all() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let count = AtomicU32::new(0);
        cases(17, 0, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 17);
    }

    mod schedsim {
        use crate::testutil::schedsim::*;

        fn script(n: u64, prompt: usize, gen: usize) -> Vec<SimRequest> {
            (0..n)
                .map(|id| SimRequest {
                    id,
                    prompt_len: prompt,
                    max_new_tokens: gen,
                    arrival_step: 0,
                })
                .collect()
        }

        #[test]
        fn sim_is_deterministic_and_completes() {
            let cfg = SimConfig::small(256);
            let a = run(cfg.clone(), &script(5, 24, 6));
            let b = run(cfg, &script(5, 24, 6));
            assert_eq!(a, b);
            for o in a.values() {
                assert_eq!(o.finish, Some(Finish::Done));
                assert_eq!(o.tokens.len(), 6);
                assert!(o.first_token.is_some());
            }
        }

        #[test]
        fn chunked_run_opens_windows_and_matches_baseline() {
            let mut cfg = SimConfig::small(256);
            cfg.sched.prefill_chunk_tokens = 16;
            let mut sim = Sim::new(cfg.clone());
            sim.drive(&script(3, 60, 4));
            assert!(
                sim.chunk_windows > 0,
                "a 60-token prompt must chunk under chunk=16"
            );
            assert_chunk_identity(&SimConfig::small(256), 16, &script(3, 60, 4));
        }

        #[test]
        fn oversized_prompt_rejected_without_chunking_served_with_it() {
            // 100 > max t bucket (64): submit-time rejection mirror.
            let a = run(SimConfig::small(256), &script(1, 100, 3));
            assert_eq!(a[&0].finish, Some(Finish::Rejected));
            let mut cfg = SimConfig::small(256);
            cfg.sched.prefill_chunk_tokens = 16;
            let b = run(cfg, &script(1, 100, 3));
            assert_eq!(b[&0].finish, Some(Finish::Done));
            assert_eq!(b[&0].tokens.len(), 3);
        }

        #[test]
        fn forced_preempt_swaps_out_and_back_in() {
            let mut cfg = SimConfig::small(256);
            cfg.swap_blocks = 64;
            cfg.force_preempt = vec![(3, 0)];
            let mut sim = Sim::new(cfg);
            sim.drive(&script(2, 20, 12));
            assert!(sim.swap_out_blocks > 0, "victim never swapped out");
            assert_eq!(
                sim.swap_out_blocks, sim.swap_in_blocks,
                "every swapped-out block must come back"
            );
            for o in sim.outcomes.values() {
                assert_eq!(o.finish, Some(Finish::Done));
                assert_eq!(o.tokens.len(), 12);
            }
        }

        #[test]
        fn abort_mid_chunk_releases_partial_prefill() {
            let mut cfg = SimConfig::small(256);
            cfg.sched.prefill_chunk_tokens = 16;
            // Step 1 opens the head's first window; abort at step 2 hits
            // a registered-but-partial head.  drive() asserts the zero-
            // leak invariant at quiescence.
            cfg.force_abort = vec![(2, 0)];
            let out = run(cfg, &script(2, 60, 4));
            assert_eq!(out[&0].finish, Some(Finish::Aborted));
            assert!(out[&0].tokens.is_empty());
            assert_eq!(out[&1].finish, Some(Finish::Done));
        }
    }
}
