//! Minimal property-testing harness (offline substitute for `proptest`).
//!
//! Drives randomized test cases from the crate's own Philox streams so every
//! failure is reproducible from `(seed, case)` — the panic message names the
//! failing case.  Used by unit tests and benches; deliberately tiny.

use crate::sampling::philox::{self, Key};

/// Deterministic per-case value generator.
pub struct Gen {
    key: Key,
    case: u32,
    ctr: u32,
}

impl Gen {
    pub fn new(seed: u64, case: u32) -> Self {
        Self { key: Key::from_seed(seed), case, ctr: 0 }
    }

    fn next_u32(&mut self) -> u32 {
        let out = philox::philox4x32_10(
            [self.ctr, self.case, 0xFEED, 0],
            [self.key.lo, self.key.hi],
        )[0];
        self.ctr += 1;
        out
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform u32 in [lo, hi] inclusive.
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + (self.next_u32() as u64 % (hi as u64 - lo as u64 + 1)) as u32
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u32_in(lo as u32, hi as u32) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + philox::uniform_open01(self.next_u32()) * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f32) -> bool {
        philox::uniform_open01(self.next_u32()) < p
    }
}

/// Run `n` randomized cases; panics identify the failing case id so it can
/// be replayed with `Gen::new(seed, case)`.
pub fn cases(n: u32, seed: u64, f: impl Fn(&mut Gen)) {
    for case in 0..n {
        let mut g = Gen::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed={seed:#x} case={case}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(1, 2);
        let mut b = Gen::new(1, 2);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(3, 4);
        for _ in 0..1000 {
            let x = g.u32_in(5, 9);
            assert!((5..=9).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn cases_run_all() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let count = AtomicU32::new(0);
        cases(17, 0, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 17);
    }
}
