//! Serving metrics: counters, streaming histograms, TPOT/TTFT trackers.
//!
//! TPOT (time per output token) is the paper's end-to-end headline metric
//! (§4.5, Tables 7-8).  The tracker records per-token decode latencies per
//! request and reports medians the way `vllm bench sweep serve` does.

use std::collections::HashMap;
use std::time::Duration;

/// Fixed-boundary streaming histogram (log-spaced buckets, microseconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in µs (last bucket is +inf).
    bounds: Vec<u64>,
    counts: Vec<u64>,
    /// All raw samples (µs) — kept for exact quantiles; decode workloads
    /// are small enough that exactness beats streaming approximation.
    samples: Vec<u64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs .. ~67s, x2 per bucket.
        let bounds: Vec<u64> = (0..26).map(|i| 1u64 << i).collect();
        let n = bounds.len() + 1;
        Self { bounds, counts: vec![0; n], samples: Vec::new() }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds.partition_point(|&b| b < us);
        self.counts[idx] += 1;
        self.samples.push(us);
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    /// Exact quantile (0.0..=1.0) in microseconds.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        self.quantiles(&[q]).map(|v| v[0])
    }

    /// Several exact quantiles from ONE sort of the sample pool — callers
    /// wanting p50/p90/p99 together pay the `O(n log n)` once, not per
    /// quantile.
    pub fn quantiles(&self, qs: &[f64]) -> Option<Vec<u64>> {
        if self.samples.is_empty() {
            return None;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        Some(
            qs.iter()
                .map(|&q| s[((s.len() - 1) as f64 * q).round() as usize])
                .collect(),
        )
    }

    /// Cumulative `(le, count)` pairs for the Prometheus `histogram`
    /// exposition: one entry per finite bound plus the trailing `+Inf`
    /// bucket (whose count equals [`Self::count`]).
    pub fn cumulative_buckets(&self) -> Vec<(String, u64)> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                let le = self
                    .bounds
                    .get(i)
                    .map_or_else(|| "+Inf".to_string(), u64::to_string);
                (le, acc)
            })
            .collect()
    }

    /// Sum of all recorded samples in µs (the histogram `_sum` row).
    pub fn sum_us(&self) -> u64 {
        self.samples.iter().sum()
    }

    pub fn median_us(&self) -> Option<u64> {
        self.quantile(0.5)
    }

    pub fn mean_us(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64)
    }
}

/// Per-request decode timing: TTFT + per-token latencies.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    /// Time to first token.
    pub ttft: Option<Duration>,
    /// Inter-token latencies (one per generated token after the first).
    pub token_latencies: Vec<Duration>,
}

impl RequestTiming {
    /// Mean time per output token for this request (vLLM's TPOT definition:
    /// decode-phase latency / decode tokens, excluding the first token).
    pub fn tpot(&self) -> Option<Duration> {
        if self.token_latencies.is_empty() {
            return None;
        }
        let total: Duration = self.token_latencies.iter().sum();
        Some(total / self.token_latencies.len() as u32)
    }
}

/// Aggregated serving metrics for one benchmark run.
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    /// Prefill tokens served from the automatic prefix cache instead of
    /// being recomputed (DESIGN.md §10); always `<= prefill_tokens`.
    pub cached_prefill_tokens: u64,
    /// Intermediate chunk windows executed by chunked prefill
    /// (DESIGN.md §12).  Sampling final chunks run as ordinary prefill
    /// batches and are not counted here.
    pub chunked_prefill_steps: u64,
    /// KV blocks moved into the host-side swap ledger on preemption.
    pub swap_out_blocks: u64,
    /// KV blocks restored from the swap ledger on resume; at quiescence
    /// `<= swap_out_blocks` (aborted-while-swapped blocks never return).
    pub swap_in_blocks: u64,
    pub ttft: Vec<Duration>,
    pub tpot: Vec<Duration>,
    /// Every inter-token (decode) latency across all requests — the
    /// streaming-latency pool behind `inter-token p99` (per-request means
    /// live in `tpot`; this is the raw population, so tail percentiles
    /// reflect individual slow steps, not slow requests).
    pub inter_token: Vec<Duration>,
    /// Per-step decode batch sizes (batch-efficiency diagnostics).
    pub decode_batch_sizes: Vec<usize>,
    /// Per-sequence tokens emitted in one speculative-decode engine step
    /// (1..=K+1).  The ordinary decode path emits exactly 1 and records
    /// nothing here; spec decode pushes one entry per (sequence, step).
    pub spec_tokens_per_step: Vec<usize>,
    /// Wall-clock span of the run.
    pub wall: Duration,
    /// Named counters (preemptions, bucket padding waste, ...).
    pub counters: HashMap<String, u64>,
    /// Named gauges (instantaneous rates/levels).  Rendered as the
    /// `flashsampling_gauge{name="..."}` family with sorted keys;
    /// derived rates like [`Self::subvocab_fallback_rate`] are merged in
    /// at render time.
    pub gauges: HashMap<String, f64>,
    /// TTFT SLO threshold in µs (`slo_ttft_ms` config key, DESIGN.md
    /// §15); 0 disables the classification AND its Prometheus family,
    /// keeping the exposition byte-identical to the pre-SLO stack.
    pub slo_ttft_us: u64,
    /// Inter-token-latency SLO threshold in µs (`slo_itl_ms`); 0
    /// disables.
    pub slo_itl_us: u64,
}

impl ServingMetrics {
    pub fn bump(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn median_tpot(&self) -> Option<Duration> {
        median(&self.tpot)
    }

    pub fn median_ttft(&self) -> Option<Duration> {
        median(&self.ttft)
    }

    /// TTFT quantile (0.0..=1.0) — the streaming serve driver reports
    /// p50/p99.
    pub fn ttft_quantile(&self, q: f64) -> Option<Duration> {
        duration_quantile(&self.ttft, q)
    }

    /// Per-request TPOT quantile.
    pub fn tpot_quantile(&self, q: f64) -> Option<Duration> {
        duration_quantile(&self.tpot, q)
    }

    /// Inter-token latency quantile over the raw population (p99 is the
    /// streaming tail-latency headline).
    pub fn inter_token_quantile(&self, q: f64) -> Option<Duration> {
        duration_quantile(&self.inter_token, q)
    }

    /// Decode throughput in tokens/s over the run.
    pub fn throughput_tps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall.as_secs_f64()
    }

    /// Mean scheduled batch size (padding efficiency indicator).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_batch_sizes.is_empty() {
            return 0.0;
        }
        self.decode_batch_sizes.iter().sum::<usize>() as f64
            / self.decode_batch_sizes.len() as f64
    }

    /// Mean tokens emitted per sequence per spec-decode engine step —
    /// the speculative speedup currency (1.0 = no better than ordinary
    /// decode, K+1 = every draft accepted).  0 when spec decode never ran.
    pub fn mean_spec_tokens_per_step(&self) -> f64 {
        if self.spec_tokens_per_step.is_empty() {
            return 0.0;
        }
        self.spec_tokens_per_step.iter().sum::<usize>() as f64
            / self.spec_tokens_per_step.len() as f64
    }

    /// Fraction of drafted tokens the verifier accepted, from the
    /// `spec_draft_tokens` / `spec_accepted_tokens` counters; `None` when
    /// nothing was drafted (spec decode off, or the drafter never
    /// proposed).
    pub fn spec_acceptance_rate(&self) -> Option<f64> {
        let drafted = self.counters.get("spec_draft_tokens").copied().unwrap_or(0);
        if drafted == 0 {
            return None;
        }
        let accepted =
            self.counters.get("spec_accepted_tokens").copied().unwrap_or(0);
        Some(accepted as f64 / drafted as f64)
    }

    /// Fraction of sub-vocabulary decode steps whose exactness certificate
    /// could NOT admit the tile skip and forced a full-vocabulary fallback
    /// pass, from the `subvocab_steps` / `subvocab_fallbacks` counters
    /// (DESIGN.md §16).  `None` when sub-vocab decoding never ran.
    pub fn subvocab_fallback_rate(&self) -> Option<f64> {
        let steps = self.counters.get("subvocab_steps").copied().unwrap_or(0);
        if steps == 0 {
            return None;
        }
        let fb = self.counters.get("subvocab_fallbacks").copied().unwrap_or(0);
        Some(fb as f64 / steps as f64)
    }

    /// Requests whose TTFT exceeded the `slo_ttft_us` threshold
    /// (`ttft` holds one entry per completed first token, so this is a
    /// per-request classification).  0 when the threshold is disabled.
    pub fn slo_ttft_violations(&self) -> u64 {
        if self.slo_ttft_us == 0 {
            return 0;
        }
        self.ttft
            .iter()
            .filter(|d| d.as_micros() as u64 > self.slo_ttft_us)
            .count() as u64
    }

    /// Inter-token gaps that exceeded the `slo_itl_us` threshold,
    /// counted over the raw decode-latency population (tail stalls, not
    /// slow-on-average requests).  0 when the threshold is disabled.
    pub fn slo_itl_violations(&self) -> u64 {
        if self.slo_itl_us == 0 {
            return 0;
        }
        self.inter_token
            .iter()
            .filter(|d| d.as_micros() as u64 > self.slo_itl_us)
            .count() as u64
    }

    /// Token-level prefix-cache hit rate: the fraction of prefill tokens
    /// served from cached KV blocks instead of recomputed.  `None` before
    /// any prefill ran.
    pub fn prefix_hit_rate(&self) -> Option<f64> {
        if self.prefill_tokens == 0 {
            return None;
        }
        Some(self.cached_prefill_tokens as f64 / self.prefill_tokens as f64)
    }

    /// Exposition families as `(TYPE header, sample lines)` pairs, with
    /// `label` (e.g. `replica="0"`, or `""` for none) merged into every
    /// sample's label set.  The family list and order are fixed per
    /// instance, which is what lets [`render_prometheus_replicas`] zip
    /// several instances into one valid exposition (one TYPE header per
    /// family, samples distinguished by the injected label).
    fn prometheus_families(&self, label: &str) -> Vec<(String, String)> {
        // Merge the instance label with a per-sample label like
        // `quantile="0.5"`; empty pieces produce no braces at all, so the
        // unlabeled render stays byte-identical to the historic format.
        let lbl = |extra: &str| -> String {
            match (label.is_empty(), extra.is_empty()) {
                (true, true) => String::new(),
                (true, false) => format!("{{{extra}}}"),
                (false, true) => format!("{{{label}}}"),
                (false, false) => format!("{{{label},{extra}}}"),
            }
        };
        let mut fams = Vec::new();
        for (name, v) in [
            ("requests_completed", self.requests_completed),
            ("tokens_generated", self.tokens_generated),
            ("prefill_tokens", self.prefill_tokens),
            ("cached_prefill_tokens", self.cached_prefill_tokens),
            ("chunked_prefill_steps", self.chunked_prefill_steps),
            ("swap_out_blocks", self.swap_out_blocks),
            ("swap_in_blocks", self.swap_in_blocks),
        ] {
            fams.push((
                format!("# TYPE flashsampling_{name} counter\n"),
                format!("flashsampling_{name}{} {v}\n", lbl("")),
            ));
        }
        fams.push((
            "# TYPE flashsampling_prefix_hit_rate gauge\n".into(),
            format!(
                "flashsampling_prefix_hit_rate{} {:.6}\n",
                lbl(""),
                self.prefix_hit_rate().unwrap_or(0.0)
            ),
        ));
        fams.push((
            "# TYPE flashsampling_throughput_tokens_per_second gauge\n".into(),
            format!(
                "flashsampling_throughput_tokens_per_second{} {:.6}\n",
                lbl(""),
                self.throughput_tps()
            ),
        ));
        for (name, xs) in [
            ("ttft", &self.ttft),
            ("tpot", &self.tpot),
            ("inter_token", &self.inter_token),
        ] {
            let mut body = String::new();
            if !xs.is_empty() {
                // One sort serves every quantile row of the family.
                let mut sorted = xs.to_vec();
                sorted.sort_unstable();
                for q in [0.5, 0.9, 0.99] {
                    let v = sorted_quantile(&sorted, q);
                    body.push_str(&format!(
                        "flashsampling_{name}_seconds{} {:.6}\n",
                        lbl(&format!("quantile=\"{q}\"")),
                        v.as_secs_f64()
                    ));
                }
            }
            body.push_str(&format!(
                "flashsampling_{name}_seconds_count{} {}\n",
                lbl(""),
                xs.len()
            ));
            fams.push((
                format!("# TYPE flashsampling_{name}_seconds summary\n"),
                body,
            ));
        }
        // Real Prometheus histogram over TTFT (µs): the fixed log-spaced
        // bucket counts `LatencyHistogram` maintains, exported as the
        // cumulative `_bucket{le=...}` series scrape backends aggregate
        // across replicas (summaries can't be aggregated; buckets can).
        let mut hist = LatencyHistogram::default();
        for d in &self.ttft {
            hist.record(*d);
        }
        let mut body = String::new();
        for (le, c) in hist.cumulative_buckets() {
            body.push_str(&format!(
                "flashsampling_ttft_microseconds_bucket{} {c}\n",
                lbl(&format!("le=\"{le}\""))
            ));
        }
        body.push_str(&format!(
            "flashsampling_ttft_microseconds_sum{} {}\n",
            lbl(""),
            hist.sum_us()
        ));
        body.push_str(&format!(
            "flashsampling_ttft_microseconds_count{} {}\n",
            lbl(""),
            hist.count()
        ));
        fams.push((
            "# TYPE flashsampling_ttft_microseconds histogram\n".into(),
            body,
        ));
        // SLO violation counters (DESIGN.md §15), one sample per ENABLED
        // threshold.  Both thresholds default 0 (off), leaving the body
        // empty — the renderers then suppress the family entirely, so
        // legacy scrapes stay byte-identical.  The family holds a fixed
        // slot (before the named counters, which stay last) so the
        // per-replica zip stays aligned.
        let mut body = String::new();
        if self.slo_ttft_us > 0 {
            body.push_str(&format!(
                "flashsampling_slo_violations_total{} {}\n",
                lbl("kind=\"ttft\""),
                self.slo_ttft_violations()
            ));
        }
        if self.slo_itl_us > 0 {
            body.push_str(&format!(
                "flashsampling_slo_violations_total{} {}\n",
                lbl("kind=\"itl\""),
                self.slo_itl_violations()
            ));
        }
        fams.push((
            "# TYPE flashsampling_slo_violations_total counter\n".into(),
            body,
        ));
        // Named gauges (DESIGN.md §16): explicit `set_gauge` values merged
        // with derived rates like the sub-vocab fallback rate, sorted by
        // name.  Like the SLO family, the slot is always pushed (empty
        // body when nothing set) so the per-replica zip stays aligned, and
        // the renderers suppress the dangling TYPE header.
        let mut gauges: Vec<(String, f64)> =
            self.gauges.iter().map(|(k, v)| (k.clone(), *v)).collect();
        if let Some(r) = self.subvocab_fallback_rate() {
            if !self.gauges.contains_key("subvocab_fallback_rate") {
                gauges.push(("subvocab_fallback_rate".into(), r));
            }
        }
        gauges.sort_by(|a, b| a.0.cmp(&b.0));
        let mut body = String::new();
        for (name, v) in &gauges {
            body.push_str(&format!(
                "flashsampling_gauge{} {v:.6}\n",
                lbl(&format!("name=\"{name}\"")),
            ));
        }
        fams.push(("# TYPE flashsampling_gauge gauge\n".into(), body));
        let mut names: Vec<&String> = self.counters.keys().collect();
        names.sort();
        let mut body = String::new();
        for name in names {
            body.push_str(&format!(
                "flashsampling_counter{} {}\n",
                lbl(&format!("name=\"{name}\"")),
                self.counters[name]
            ));
        }
        // The named-counter family keeps its slot even when empty so the
        // per-replica family lists stay zip-alignable; the renderers
        // suppress the dangling TYPE header for empty bodies.
        fams.push(("# TYPE flashsampling_counter counter\n".into(), body));
        fams
    }

    /// Plain-text Prometheus exposition-format dump: counters, gauges, and
    /// TTFT/TPOT summaries, deterministically ordered (named counters
    /// sorted by name) so scrapes — and the format-stability unit test —
    /// see a stable layout.
    pub fn render_prometheus(&self) -> String {
        self.prometheus_families("")
            .into_iter()
            .filter(|(_, body)| !body.is_empty())
            .map(|(header, body)| header + &body)
            .collect()
    }
}

/// Multi-replica Prometheus exposition: each family's TYPE header appears
/// once, followed by every replica's samples tagged `replica="i"` (the
/// router's scrape surface — DESIGN.md §13).  A single replica renders
/// unlabeled, byte-identical to [`ServingMetrics::render_prometheus`], so
/// `--replicas 1` scrapes are indistinguishable from the bare engine's.
pub fn render_prometheus_replicas(replicas: &[&ServingMetrics]) -> String {
    if let [only] = replicas {
        return only.render_prometheus();
    }
    let per: Vec<Vec<(String, String)>> = replicas
        .iter()
        .enumerate()
        .map(|(i, m)| m.prometheus_families(&format!("replica=\"{i}\"")))
        .collect();
    let mut out = String::new();
    let n_fams = per.first().map_or(0, Vec::len);
    for f in 0..n_fams {
        // A family every replica leaves empty (e.g. no named counters
        // anywhere) would expose a dangling TYPE header — skip it.
        if per.iter().all(|fams| fams[f].1.is_empty()) {
            continue;
        }
        out.push_str(&per[0][f].0);
        for fams in &per {
            out.push_str(&fams[f].1);
        }
    }
    out
}

fn median(xs: &[Duration]) -> Option<Duration> {
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    Some(v[v.len() / 2])
}

/// Exact quantile by nearest-rank (the same rule the Prometheus summary
/// rows and `LatencyHistogram::quantile` use).
fn duration_quantile(xs: &[Duration], q: f64) -> Option<Duration> {
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    Some(sorted_quantile(&v, q))
}

/// Nearest-rank quantile over an ALREADY-sorted, non-empty slice — lets
/// the exposition renderer sort each latency pool once and read several
/// quantiles from it.
fn sorted_quantile(sorted: &[Duration], q: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let mut h = LatencyHistogram::default();
        for us in [100u64, 200, 300, 400, 500] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.median_us(), Some(300));
        assert_eq!(h.quantile(0.0), Some(100));
        assert_eq!(h.quantile(1.0), Some(500));
        assert_eq!(h.quantiles(&[0.0, 0.5, 1.0]), Some(vec![100, 300, 500]));
        assert!((h.mean_us().unwrap() - 300.0).abs() < 1e-9);
        // Cumulative exposition buckets: 100→le=128, 200→256, 300/400→512,
        // 500→512; monotone and capped by the +Inf bucket == count.
        let b = h.cumulative_buckets();
        assert_eq!(b.len(), 27);
        assert!(b.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(b.last().unwrap(), &("+Inf".to_string(), 5));
        let at = |le: &str| b.iter().find(|(l, _)| l == le).unwrap().1;
        assert_eq!(at("64"), 0);
        assert_eq!(at("128"), 1);
        assert_eq!(at("256"), 2);
        assert_eq!(at("512"), 5);
        assert_eq!(h.sum_us(), 1500);
    }

    #[test]
    fn empty_histogram_is_none() {
        let h = LatencyHistogram::default();
        assert_eq!(h.median_us(), None);
        assert_eq!(h.mean_us(), None);
    }

    #[test]
    fn tpot_excludes_first_token() {
        let t = RequestTiming {
            ttft: Some(Duration::from_millis(50)),
            token_latencies: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
            ],
        };
        assert_eq!(t.tpot(), Some(Duration::from_millis(15)));
        let empty = RequestTiming::default();
        assert_eq!(empty.tpot(), None);
    }

    #[test]
    fn serving_metrics_aggregation() {
        let mut m = ServingMetrics::default();
        m.tokens_generated = 100;
        m.wall = Duration::from_secs(2);
        assert!((m.throughput_tps() - 50.0).abs() < 1e-9);
        m.bump("preempted", 1);
        m.bump("preempted", 2);
        assert_eq!(m.counters["preempted"], 3);
        m.decode_batch_sizes = vec![2, 4, 6];
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn prefix_hit_rate_is_cached_over_total() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.prefix_hit_rate(), None);
        m.prefill_tokens = 200;
        m.cached_prefill_tokens = 150;
        assert!((m.prefix_hit_rate().unwrap() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn prometheus_rendering_is_format_stable() {
        // Exact-output check: scrape consumers (and this test) rely on the
        // exposition layout not drifting.
        let mut m = ServingMetrics::default();
        m.requests_completed = 3;
        m.tokens_generated = 40;
        m.prefill_tokens = 100;
        m.cached_prefill_tokens = 50;
        m.chunked_prefill_steps = 4;
        m.swap_out_blocks = 6;
        m.swap_in_blocks = 5;
        m.wall = Duration::from_secs(2);
        m.ttft = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        m.tpot = vec![Duration::from_millis(5)];
        m.inter_token = vec![Duration::from_millis(4), Duration::from_millis(6)];
        m.bump("preempted", 2);
        m.bump("decode_cache_hits", 7);
        let expect = "\
# TYPE flashsampling_requests_completed counter
flashsampling_requests_completed 3
# TYPE flashsampling_tokens_generated counter
flashsampling_tokens_generated 40
# TYPE flashsampling_prefill_tokens counter
flashsampling_prefill_tokens 100
# TYPE flashsampling_cached_prefill_tokens counter
flashsampling_cached_prefill_tokens 50
# TYPE flashsampling_chunked_prefill_steps counter
flashsampling_chunked_prefill_steps 4
# TYPE flashsampling_swap_out_blocks counter
flashsampling_swap_out_blocks 6
# TYPE flashsampling_swap_in_blocks counter
flashsampling_swap_in_blocks 5
# TYPE flashsampling_prefix_hit_rate gauge
flashsampling_prefix_hit_rate 0.500000
# TYPE flashsampling_throughput_tokens_per_second gauge
flashsampling_throughput_tokens_per_second 20.000000
# TYPE flashsampling_ttft_seconds summary
flashsampling_ttft_seconds{quantile=\"0.5\"} 0.020000
flashsampling_ttft_seconds{quantile=\"0.9\"} 0.030000
flashsampling_ttft_seconds{quantile=\"0.99\"} 0.030000
flashsampling_ttft_seconds_count 3
# TYPE flashsampling_tpot_seconds summary
flashsampling_tpot_seconds{quantile=\"0.5\"} 0.005000
flashsampling_tpot_seconds{quantile=\"0.9\"} 0.005000
flashsampling_tpot_seconds{quantile=\"0.99\"} 0.005000
flashsampling_tpot_seconds_count 1
# TYPE flashsampling_inter_token_seconds summary
flashsampling_inter_token_seconds{quantile=\"0.5\"} 0.006000
flashsampling_inter_token_seconds{quantile=\"0.9\"} 0.006000
flashsampling_inter_token_seconds{quantile=\"0.99\"} 0.006000
flashsampling_inter_token_seconds_count 2
# TYPE flashsampling_ttft_microseconds histogram
flashsampling_ttft_microseconds_bucket{le=\"1\"} 0
flashsampling_ttft_microseconds_bucket{le=\"2\"} 0
flashsampling_ttft_microseconds_bucket{le=\"4\"} 0
flashsampling_ttft_microseconds_bucket{le=\"8\"} 0
flashsampling_ttft_microseconds_bucket{le=\"16\"} 0
flashsampling_ttft_microseconds_bucket{le=\"32\"} 0
flashsampling_ttft_microseconds_bucket{le=\"64\"} 0
flashsampling_ttft_microseconds_bucket{le=\"128\"} 0
flashsampling_ttft_microseconds_bucket{le=\"256\"} 0
flashsampling_ttft_microseconds_bucket{le=\"512\"} 0
flashsampling_ttft_microseconds_bucket{le=\"1024\"} 0
flashsampling_ttft_microseconds_bucket{le=\"2048\"} 0
flashsampling_ttft_microseconds_bucket{le=\"4096\"} 0
flashsampling_ttft_microseconds_bucket{le=\"8192\"} 0
flashsampling_ttft_microseconds_bucket{le=\"16384\"} 1
flashsampling_ttft_microseconds_bucket{le=\"32768\"} 3
flashsampling_ttft_microseconds_bucket{le=\"65536\"} 3
flashsampling_ttft_microseconds_bucket{le=\"131072\"} 3
flashsampling_ttft_microseconds_bucket{le=\"262144\"} 3
flashsampling_ttft_microseconds_bucket{le=\"524288\"} 3
flashsampling_ttft_microseconds_bucket{le=\"1048576\"} 3
flashsampling_ttft_microseconds_bucket{le=\"2097152\"} 3
flashsampling_ttft_microseconds_bucket{le=\"4194304\"} 3
flashsampling_ttft_microseconds_bucket{le=\"8388608\"} 3
flashsampling_ttft_microseconds_bucket{le=\"16777216\"} 3
flashsampling_ttft_microseconds_bucket{le=\"33554432\"} 3
flashsampling_ttft_microseconds_bucket{le=\"+Inf\"} 3
flashsampling_ttft_microseconds_sum 60000
flashsampling_ttft_microseconds_count 3
# TYPE flashsampling_counter counter
flashsampling_counter{name=\"decode_cache_hits\"} 7
flashsampling_counter{name=\"preempted\"} 2
";
        assert_eq!(m.render_prometheus(), expect);
        // Empty metrics still render (no quantile lines, zero counts) —
        // except the named-counter family, whose TYPE header would dangle
        // with no samples under it.
        let empty = ServingMetrics::default().render_prometheus();
        assert!(empty.contains("flashsampling_ttft_seconds_count 0"));
        assert!(empty.contains("flashsampling_prefix_hit_rate 0.000000"));
        assert!(empty.contains("flashsampling_ttft_microseconds_count 0"));
        assert!(!empty.contains("quantile"));
        assert!(!empty.contains("# TYPE flashsampling_counter counter"));
        // SLO thresholds default off: the family must be absent so the
        // exact-output check above (no slo lines) keeps holding.
        assert!(!empty.contains("slo_violations"));
        // Enabling a threshold adds exactly the new family, in its slot
        // BEFORE the named counters, without disturbing anything else.
        let mut slo = m.clone();
        slo.slo_ttft_us = 15_000; // 15ms: 20ms and 30ms TTFTs violate
        slo.slo_itl_us = 5_000; // 5ms: the 6ms inter-token gap violates
        let rendered = slo.render_prometheus();
        let expect_slo = "\
# TYPE flashsampling_slo_violations_total counter
flashsampling_slo_violations_total{kind=\"ttft\"} 2
flashsampling_slo_violations_total{kind=\"itl\"} 1
# TYPE flashsampling_counter counter
";
        assert!(rendered.contains(expect_slo));
        assert_eq!(
            rendered.replace(
                "# TYPE flashsampling_slo_violations_total counter
flashsampling_slo_violations_total{kind=\"ttft\"} 2
flashsampling_slo_violations_total{kind=\"itl\"} 1
",
                ""
            ),
            expect
        );
        // One enabled threshold renders only its kind.
        let mut ttft_only = m.clone();
        ttft_only.slo_ttft_us = 15_000;
        let rendered = ttft_only.render_prometheus();
        assert!(rendered.contains("{kind=\"ttft\"} 2\n"));
        assert!(!rendered.contains("kind=\"itl\""));
        // Gauge family (DESIGN.md §16): absent by default (the exact
        // check above has no gauge lines), and when sub-vocab decode ran
        // the derived fallback rate appears with a `# TYPE ... gauge`
        // header, merged with explicit gauges in sorted-name order, in
        // its slot BEFORE the named counters.
        assert!(!m.render_prometheus().contains("flashsampling_gauge"));
        let mut g = m.clone();
        g.bump("subvocab_steps", 8);
        g.bump("subvocab_fallbacks", 2);
        g.set_gauge("kv_util", 0.5);
        let rendered = g.render_prometheus();
        let expect_gauge = "\
# TYPE flashsampling_gauge gauge
flashsampling_gauge{name=\"kv_util\"} 0.500000
flashsampling_gauge{name=\"subvocab_fallback_rate\"} 0.250000
# TYPE flashsampling_counter counter
";
        assert!(rendered.contains(expect_gauge));
        // The subvocab counters themselves land in the named-counter
        // family like any other bump.
        assert!(rendered.contains("flashsampling_counter{name=\"subvocab_steps\"} 8\n"));
        // An explicit gauge under the derived name wins (no double line).
        g.set_gauge("subvocab_fallback_rate", 0.125);
        assert_eq!(
            g.render_prometheus()
                .matches("flashsampling_gauge{name=\"subvocab_fallback_rate\"}")
                .count(),
            1
        );
        assert!(g
            .render_prometheus()
            .contains("flashsampling_gauge{name=\"subvocab_fallback_rate\"} 0.125000\n"));
    }

    #[test]
    fn subvocab_fallback_rate_from_counters() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.subvocab_fallback_rate(), None);
        m.bump("subvocab_steps", 10);
        assert!((m.subvocab_fallback_rate().unwrap() - 0.0).abs() < 1e-9);
        m.bump("subvocab_fallbacks", 4);
        assert!((m.subvocab_fallback_rate().unwrap() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn slo_violation_counting() {
        let mut m = ServingMetrics::default();
        m.ttft = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        m.inter_token = vec![Duration::from_millis(4), Duration::from_millis(6)];
        // Disabled thresholds count nothing.
        assert_eq!(m.slo_ttft_violations(), 0);
        assert_eq!(m.slo_itl_violations(), 0);
        // Strictly-greater-than semantics: a sample AT the threshold
        // meets the SLO.
        m.slo_ttft_us = 20_000;
        m.slo_itl_us = 6_000;
        assert_eq!(m.slo_ttft_violations(), 1);
        assert_eq!(m.slo_itl_violations(), 0);
        m.slo_ttft_us = 1;
        m.slo_itl_us = 1;
        assert_eq!(m.slo_ttft_violations(), 3);
        assert_eq!(m.slo_itl_violations(), 2);
    }

    #[test]
    fn prometheus_replica_labels() {
        let mut a = ServingMetrics::default();
        a.requests_completed = 2;
        a.ttft = vec![Duration::from_millis(10)];
        a.bump("preempted", 1);
        let mut b = ServingMetrics::default();
        b.requests_completed = 5;
        let multi = render_prometheus_replicas(&[&a, &b]);
        // One TYPE header per family, then one labeled sample per replica.
        assert_eq!(
            multi.matches("# TYPE flashsampling_requests_completed counter").count(),
            1
        );
        assert!(multi.contains("flashsampling_requests_completed{replica=\"0\"} 2\n"));
        assert!(multi.contains("flashsampling_requests_completed{replica=\"1\"} 5\n"));
        // Per-sample labels merge after the replica label.
        assert!(multi.contains(
            "flashsampling_ttft_seconds{replica=\"0\",quantile=\"0.5\"} 0.010000\n"
        ));
        assert!(multi.contains("flashsampling_ttft_seconds_count{replica=\"1\"} 0\n"));
        // Histogram buckets carry the replica label before `le`.
        assert!(multi.contains(
            "flashsampling_ttft_microseconds_bucket{replica=\"0\",le=\"16384\"} 1\n"
        ));
        assert!(multi.contains(
            "flashsampling_ttft_microseconds_bucket{replica=\"1\",le=\"+Inf\"} 0\n"
        ));
        assert!(multi
            .contains("flashsampling_counter{replica=\"0\",name=\"preempted\"} 1\n"));
        // SLO family: off everywhere → suppressed; enabled on one
        // replica → one TYPE header, replica-labeled samples.
        assert!(!multi.contains("slo_violations"));
        let mut c = a.clone();
        c.slo_ttft_us = 5_000; // 10ms TTFT violates
        let slo_multi = render_prometheus_replicas(&[&c, &b]);
        assert_eq!(
            slo_multi
                .matches("# TYPE flashsampling_slo_violations_total counter")
                .count(),
            1
        );
        assert!(slo_multi.contains(
            "flashsampling_slo_violations_total{replica=\"0\",kind=\"ttft\"} 1\n"
        ));
        // No replica has named counters → the family header is suppressed
        // in the zipped render too.
        let empty_multi = render_prometheus_replicas(&[
            &ServingMetrics::default(),
            &ServingMetrics::default(),
        ]);
        assert!(!empty_multi.contains("# TYPE flashsampling_counter counter"));
        // A single replica renders unlabeled and byte-identical to the
        // instance method — `--replicas 1` scrapes don't change shape.
        assert_eq!(render_prometheus_replicas(&[&a]), a.render_prometheus());
        assert!(!render_prometheus_replicas(&[&a]).contains("replica="));
    }

    #[test]
    fn streaming_quantiles_use_nearest_rank() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.ttft_quantile(0.5), None);
        assert_eq!(m.inter_token_quantile(0.99), None);
        m.ttft = (1..=100).map(Duration::from_millis).collect();
        m.inter_token = (1..=100).map(Duration::from_millis).collect();
        m.tpot = vec![Duration::from_millis(7)];
        // Nearest-rank over 100 samples: idx = round(99q).
        assert_eq!(m.ttft_quantile(0.5), Some(Duration::from_millis(51)));
        assert_eq!(m.ttft_quantile(0.99), Some(Duration::from_millis(99)));
        assert_eq!(m.inter_token_quantile(1.0), Some(Duration::from_millis(100)));
        assert_eq!(m.tpot_quantile(0.99), Some(Duration::from_millis(7)));
    }

    #[test]
    fn spec_decode_metrics() {
        let mut m = ServingMetrics::default();
        // Nothing recorded: neutral values, no division by zero.
        assert_eq!(m.mean_spec_tokens_per_step(), 0.0);
        assert_eq!(m.spec_acceptance_rate(), None);
        // 3 spec steps emitting 5, 1, 3 tokens; 12 drafted, 6 accepted.
        m.spec_tokens_per_step = vec![5, 1, 3];
        m.bump("spec_draft_tokens", 12);
        m.bump("spec_accepted_tokens", 6);
        assert!((m.mean_spec_tokens_per_step() - 3.0).abs() < 1e-9);
        assert!((m.spec_acceptance_rate().unwrap() - 0.5).abs() < 1e-9);
    }
}
