//! [`SimReplica`] — an accounting-level [`EngineBackend`] for CPU-only
//! certification of the router (DESIGN.md §13).
//!
//! The authoring/CI boxes carry no AOT artifacts, so the router's
//! system-level claims (replay-stable dispatch, zero KV/prefix-ref leaks
//! under randomized aborts, affinity beating least-loaded on hit rate,
//! drained event queues at quiescence) are certified against this
//! replica: **everything above model execution is real** — the real
//! [`crate::kvcache::KvCacheManager`] with the real radix prefix cache,
//! real [`RequestHandle`] event queues, real typed [`EngineError`]s —
//! and only the transformer step is replaced by a deterministic token
//! formula.  `Router<SimReplica>` therefore exercises the identical
//! router code paths that `Router<Engine>` runs on a toolbox, with the
//! identical dispatch decisions (the policy function is pure and reads
//! only accounting state).
//!
//! Scheduling is a FIFO mirror of the engine's continuous batcher, the
//! same shape `python/tests/sim_serving_bench.py` mirrors: admit up to
//! `prefill_b` admissible waiting heads when concurrency allows, else
//! decode the first `decode_max_b` running sequences one token.  Cost
//! model ("weighted time", the bench's latency unit): a prefill batch
//! costs its longest *uncached suffix* in tokens — exactly the quantity
//! the `prefill_cached` artifacts make the real cost proportional to —
//! and a decode step costs 1.  The Python mirror
//! (`python/tests/sim_router_bench.py`) reproduces this replica's
//! accounting bit-for-bit; keep both in lockstep when editing.
//!
//! Probe note: `probe().headroom` answers with the allocator's free
//! blocks.  The sim regime sizes pools so prefix-cache eviction never
//! engages (the mirror does not model eviction), making free blocks the
//! exact headroom; the real engine answers with free + evictable.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::stream::{RequestHandle, RequestOutput, SharedStream, StreamState};
use crate::coordinator::{Completion, EngineError, FinishReason, Request};
use crate::kvcache::{KvCacheConfig, KvCacheManager};
use crate::metrics::ServingMetrics;
use crate::prefixcache::BlockKv;
use crate::trace::{EventKind, Trace, TraceLevel};

use super::backend::EngineBackend;
use super::policy::{DispatchPolicy, ReplicaProbe};
use super::Router;

/// Shape of one simulated replica.  Defaults mirror the serving bench
/// sim: engine-default concurrency over a pool big enough that eviction
/// never engages (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct SimReplicaConfig {
    pub block_size: usize,
    pub num_blocks: usize,
    pub prefix_caching: bool,
    pub max_concurrency: usize,
    /// Max sequences per prefill batch (the engine's `prefill_b`).
    pub prefill_b: usize,
    /// Max sequences per decode step (the engine's largest decode bucket).
    pub decode_max_b: usize,
    /// Flight-recorder level (`Off` by default, as in the engine config).
    pub trace_level: TraceLevel,
    /// Model the certified sub-vocabulary decode head (DESIGN.md §16):
    /// each decode step emits one deterministic skip-or-fallback event
    /// and bumps the `subvocab_steps` / `subvocab_fallbacks` counters,
    /// so `Router<SimReplica>` certifies the same trace/metrics contract
    /// `Router<Engine>` exports with `subvocab = true`.
    pub subvocab: bool,
}

impl Default for SimReplicaConfig {
    fn default() -> Self {
        Self {
            block_size: 16,
            num_blocks: 4096,
            prefix_caching: true,
            max_concurrency: 8,
            prefill_b: 4,
            decode_max_b: 8,
            trace_level: TraceLevel::Off,
            subvocab: false,
        }
    }
}

/// The deterministic stand-in for model execution: token `index` of
/// request `id` (0-based over generated tokens).  Values are irrelevant
/// to everything the sim certifies — only determinism matters (replay
/// identity compares full token streams) — but they flow through the
/// real KV/radix accounting like real tokens.
pub fn sim_token(id: u64, index: usize) -> i32 {
    (((id as i64) * 31 + (index as i64 + 1) * 7) % 2039) as i32
}

struct SimSeq {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    generated: Vec<i32>,
    /// Owner-replica weighted time at submission (TTFT anchor).
    submit_w: u64,
}

/// One simulated serving replica.
pub struct SimReplica {
    cfg: SimReplicaConfig,
    kv: KvCacheManager,
    waiting: VecDeque<SimSeq>,
    running: Vec<SimSeq>,
    streams: HashMap<u64, SharedStream>,
    clock: u64,
    /// Weighted busy time (token units — the bench's latency clock).
    wtime: u64,
    pub metrics: ServingMetrics,
    /// Flight recorder: per-replica lifecycle events (submit / prefill /
    /// decode / finish / dispatch), so `Router<SimReplica>` certifies the
    /// same trace contract `Router<Engine>` exports.
    pub trace: Trace,
    /// Batch counter standing in for the engine's Philox step counter
    /// (one per prefill batch, one per decode step) — the `cstep`
    /// coordinate on this replica's token events.
    cstep: u32,
}

impl SimReplica {
    pub fn new(cfg: SimReplicaConfig) -> Self {
        let kv = KvCacheManager::new(KvCacheConfig {
            block_size: cfg.block_size,
            num_blocks: cfg.num_blocks,
            prefix_caching: cfg.prefix_caching,
        });
        let trace = Trace::new(cfg.trace_level);
        Self {
            cfg,
            kv,
            waiting: VecDeque::new(),
            running: Vec::new(),
            streams: HashMap::new(),
            clock: 0,
            wtime: 0,
            metrics: ServingMetrics::default(),
            trace,
            cstep: 0,
        }
    }

    /// Weighted busy time so far (the bench's makespan component).
    pub fn wtime(&self) -> u64 {
        self.wtime
    }

    fn emit_token(&mut self, seq_idx_id: u64, index: usize, token: i32) {
        if let Some(st) = self.streams.get(&seq_idx_id).filter(|st| Arc::strong_count(st) > 1)
        {
            st.lock().expect("stream mutex").queue.push_back(RequestOutput {
                request_id: seq_idx_id,
                token: Some(token),
                index,
                text_len: index + 1,
                step: self.clock,
                ttft_steps: (index == 0).then_some(self.clock),
                inter_token_steps: (index > 0).then_some(1),
                finish: None,
            });
        }
    }

    fn complete_seq(&mut self, s: SimSeq, reason: FinishReason) -> Completion {
        let ttft = (!s.generated.is_empty())
            .then(|| Duration::from_micros(self.wtime.saturating_sub(s.submit_w)));
        let c = Completion {
            id: s.id,
            prompt_len: s.prompt.len(),
            tokens: s.generated,
            finish: reason,
            timing: crate::metrics::RequestTiming {
                ttft,
                token_latencies: Vec::new(),
            },
        };
        self.metrics.requests_completed += 1;
        if let Some(t) = ttft {
            self.metrics.ttft.push(t);
        }
        if reason == FinishReason::Aborted {
            self.metrics.bump("aborted", 1);
        }
        if self.trace.on() {
            let name = match reason {
                FinishReason::MaxTokens => "max_tokens",
                FinishReason::StopToken => "stop_token",
                FinishReason::Rejected => "rejected",
                FinishReason::Aborted => "aborted",
            };
            self.trace.emit(
                self.clock,
                c.id,
                EventKind::Finish { reason: name, tokens: c.tokens.len() as u64 },
            );
        }
        if let Some(st) = self.streams.remove(&c.id) {
            if Arc::strong_count(&st) > 1 {
                let mut g = st.lock().expect("stream mutex");
                g.queue.push_back(RequestOutput::terminal(
                    c.id,
                    c.tokens.len(),
                    self.clock,
                    reason,
                ));
                g.finished = Some(reason);
                g.completion = Some(c.clone());
            }
        }
        c
    }

    /// Run one prefill batch: FIFO admission of up to `prefill_b`
    /// admissible heads.  Mirrors the engine: register (attaching any
    /// cached prefix), publish full blocks, sample the first token.
    fn do_prefill(&mut self) -> Result<Vec<Completion>, EngineError> {
        let mut batch = Vec::new();
        while batch.len() < self.cfg.prefill_b
            && self.running.len() + batch.len() < self.cfg.max_concurrency
        {
            let Some(head) = self.waiting.front() else { break };
            if !self.kv.can_allocate_prefill(&head.prompt, 0) {
                break;
            }
            batch.push(self.waiting.pop_front().expect("front exists"));
        }
        debug_assert!(!batch.is_empty(), "caller checked admissibility");
        let mut cost = 1u64;
        let mut done = Vec::new();
        let mut admitted = Vec::new();
        let cstep = self.cstep;
        self.cstep += 1;
        for (row, mut s) in batch.into_iter().enumerate() {
            let attach = self.kv.register_with_prefix(s.id, &s.prompt)?;
            self.metrics.prefill_tokens += s.prompt.len() as u64;
            self.metrics.cached_prefill_tokens += attach.cached_tokens as u64;
            cost = cost.max((s.prompt.len() - attach.cached_tokens) as u64);
            self.kv.insert_prefix(s.id, &s.prompt, |_| BlockKv::default())?;
            // Prefill samples the sequence's first token (engine
            // semantics: TTFT lands at prefill completion).
            let tok = sim_token(s.id, 0);
            s.generated.push(tok);
            self.metrics.tokens_generated += 1;
            if self.trace.on() {
                if attach.cached_tokens > 0 {
                    self.trace.emit(
                        self.clock,
                        s.id,
                        EventKind::RadixAttach {
                            tokens: attach.cached_tokens as u64,
                        },
                    );
                }
                self.trace.emit(
                    self.clock,
                    s.id,
                    EventKind::Prefill { prompt_len: s.prompt.len() },
                );
                self.trace.emit(
                    self.clock,
                    s.id,
                    EventKind::FirstToken { row, cstep, token: tok },
                );
            }
            admitted.push(s);
        }
        self.wtime += cost;
        for s in admitted {
            self.emit_token(s.id, 0, s.generated[0]);
            if s.max_new == 1 {
                self.kv.release(s.id)?;
                done.push(self.complete_seq(s, FinishReason::MaxTokens));
            } else {
                self.running.push(s);
            }
        }
        Ok(done)
    }

    /// Decode one token for the first `decode_max_b` running sequences.
    fn do_decode(&mut self) -> Result<Vec<Completion>, EngineError> {
        let b = self.running.len().min(self.cfg.decode_max_b);
        self.wtime += 1;
        let cstep = self.cstep;
        self.cstep += 1;
        if self.cfg.subvocab {
            // Deterministic stand-in for the certified sub-vocab head:
            // every 4th batch counter forces a certificate fallback, the
            // rest admit the skip; the event is attributed to the first
            // running row (the engine attributes its batch-level event to
            // `seq_ids[0]`) with the default tile shape, 4 candidate
            // tiles of 16.  `python/tests/sim_subvocab_bench.py` mirrors
            // this rule bit-for-bit — keep in lockstep.
            let id = self.running[0].id;
            let (active, skipped) = (4u64, 12u64);
            self.metrics.bump("subvocab_steps", 1);
            let ev = if cstep % 4 == 0 {
                self.metrics.bump("subvocab_fallbacks", 1);
                EventKind::SubvocabFallback { active, skipped }
            } else {
                EventKind::SubvocabSkip { active, skipped }
            };
            if self.trace.on() {
                self.trace.emit(self.clock, id, ev);
            }
        }
        let mut done = Vec::new();
        let mut emitted = Vec::new();
        for (row, s) in self.running.iter_mut().take(b).enumerate() {
            if !self.kv.append_token(s.id)? {
                // Pool exhausted mid-decode: the sim regime sizes pools
                // to make this unreachable (no preemption mirror).
                return Err(EngineError::Internal(anyhow::anyhow!(
                    "SimReplica KV pool exhausted — size num_blocks for the workload"
                )));
            }
            let idx = s.generated.len();
            let tok = sim_token(s.id, idx);
            s.generated.push(tok);
            emitted.push((s.id, row, idx, tok));
        }
        for (id, row, idx, tok) in emitted {
            self.metrics.tokens_generated += 1;
            if self.trace.on() {
                self.trace.emit(
                    self.clock,
                    id,
                    EventKind::DecodeToken { row, cstep, token: tok },
                );
            }
            self.emit_token(id, idx, tok);
        }
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].generated.len() >= self.running[i].max_new {
                let s = self.running.remove(i);
                self.kv.release(s.id)?;
                done.push(self.complete_seq(s, FinishReason::MaxTokens));
            } else {
                i += 1;
            }
        }
        Ok(done)
    }
}

impl EngineBackend for SimReplica {
    fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError> {
        if self.streams.contains_key(&req.id) {
            return Err(EngineError::DuplicateRequestId { id: req.id });
        }
        if req.prompt.is_empty() {
            if self.trace.on() {
                self.trace.emit(
                    self.clock,
                    req.id,
                    EventKind::Reject { reason: "empty prompt".into() },
                );
            }
            return Err(EngineError::AdmissionRejected {
                id: req.id,
                reason: "empty prompt".into(),
            });
        }
        let id = req.id;
        if self.trace.on() {
            self.trace.emit(
                self.clock,
                id,
                EventKind::Submit {
                    prompt_len: req.prompt.len(),
                    max_new: req.params.max_new_tokens.max(1),
                },
            );
        }
        let state = Arc::new(Mutex::new(StreamState::default()));
        self.streams.insert(id, state.clone());
        self.waiting.push_back(SimSeq {
            id,
            prompt: req.prompt,
            max_new: req.params.max_new_tokens.max(1),
            generated: Vec::new(),
            submit_w: self.wtime,
        });
        Ok(RequestHandle::new(id, state))
    }

    fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError> {
        if let Some(idx) = self.waiting.iter().position(|s| s.id == request_id) {
            let s = self.waiting.remove(idx).expect("position in range");
            // Waiting sim sequences are unregistered (registration
            // happens at prefill admission) — nothing to release.
            return Ok(self.complete_seq(s, FinishReason::Aborted));
        }
        if let Some(idx) = self.running.iter().position(|s| s.id == request_id) {
            let s = self.running.remove(idx);
            self.kv.release(s.id)?;
            return Ok(self.complete_seq(s, FinishReason::Aborted));
        }
        Err(EngineError::UnknownRequest { id: request_id })
    }

    fn step(&mut self) -> Result<Vec<Completion>, EngineError> {
        self.clock += 1;
        let can_prefill = self.running.len() < self.cfg.max_concurrency
            && self
                .waiting
                .front()
                .is_some_and(|s| self.kv.can_allocate_prefill(&s.prompt, 0));
        if can_prefill {
            self.do_prefill()
        } else if !self.running.is_empty() {
            self.do_decode()
        } else {
            Ok(Vec::new())
        }
    }

    fn reject_unschedulable(&mut self) -> Option<Completion> {
        if !self.running.is_empty() {
            return None;
        }
        let head_stuck = self
            .waiting
            .front()
            .is_some_and(|s| !self.kv.can_allocate_prefill(&s.prompt, 0));
        if head_stuck {
            let s = self.waiting.pop_front().expect("front exists");
            return Some(self.complete_seq(s, FinishReason::Rejected));
        }
        None
    }

    fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    fn clock(&self) -> u64 {
        self.clock
    }

    fn kv_block_size(&self) -> usize {
        self.cfg.block_size
    }

    fn probe(&self, prompt: &[i32]) -> ReplicaProbe {
        ReplicaProbe {
            pending: self.pending(),
            headroom: self.kv.free_blocks(),
            blocks_needed: self.kv.prefill_blocks_needed(prompt, 0),
            cached_tokens: self.kv.cached_prefix_tokens(prompt),
        }
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    fn kv_unaccounted_blocks(&self) -> usize {
        self.kv.unaccounted_blocks()
    }

    fn prefix_attached_refs(&self) -> usize {
        self.kv.prefix_attached_refs()
    }

    fn trace_dispatch(
        &mut self,
        id: u64,
        policy: &'static str,
        replica: usize,
        affinity_rank: usize,
        spill: bool,
    ) {
        if self.trace.on() {
            self.trace.emit(
                self.clock,
                id,
                EventKind::Dispatch { policy, replica, affinity_rank, spill },
            );
        }
    }

    fn trace(&self) -> Option<&Trace> {
        Some(&self.trace)
    }
}

/// N simulated replicas under one router — the CPU certification and
/// bench vehicle.
pub fn sim_router(
    n: usize,
    policy: DispatchPolicy,
    cfg: SimReplicaConfig,
) -> Router<SimReplica> {
    Router::new((0..n).map(|_| SimReplica::new(cfg)).collect(), policy)
        .expect("n >= 1 and uniform block size by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SamplingParams;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::new(
            id,
            prompt,
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    fn drain_all(r: &mut Router<SimReplica>) -> Vec<Completion> {
        let mut done = Vec::new();
        let mut idle = 0;
        while r.pending() > 0 {
            let step = r.step().expect("sim step");
            if step.is_empty() {
                idle += 1;
                if idle > 8 {
                    if let Some(c) = r.reject_unschedulable() {
                        done.push(c);
                        idle = 0;
                        continue;
                    }
                }
                assert!(idle < 64, "sim livelock");
            } else {
                idle = 0;
            }
            done.extend(step);
        }
        done
    }

    #[test]
    fn sim_replica_serves_and_balances_kv() {
        let mut r = sim_router(2, DispatchPolicy::RoundRobin, SimReplicaConfig::default());
        let mut handles = Vec::new();
        for id in 0..6u64 {
            let prompt: Vec<i32> = (0..40).map(|j| (id as i32 * 3 + j) % 97).collect();
            handles.push(r.submit(req(id, prompt, 5)).unwrap());
        }
        let done = drain_all(&mut r);
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, FinishReason::MaxTokens);
            // Token streams are the deterministic sim formula.
            for (i, &t) in c.tokens.iter().enumerate() {
                assert_eq!(t, sim_token(c.id, i));
            }
        }
        // Zero-leak quiescence across replicas.
        assert_eq!(r.kv_unaccounted_blocks(), 0);
        assert_eq!(r.prefix_attached_refs(), 0);
        // Every handle drains fully and ends terminal.
        for h in &handles {
            let events = h.drain();
            assert!(!events.is_empty());
            assert!(events.last().unwrap().finish.is_some());
            assert!(h.is_finished());
        }
    }

    #[test]
    fn shared_prefix_sessions_raise_hit_rate_under_affinity() {
        let sys: Vec<i32> = (0..32).map(|j| j * 13 % 211).collect();
        let mk = |turn: usize, session: i32| -> Vec<i32> {
            let mut p = sys.clone();
            for t in 0..=turn {
                p.extend((0..16).map(|j| session * 59 + t as i32 * 31 + j));
            }
            p
        };
        let run = |policy: DispatchPolicy| -> f64 {
            let mut r = sim_router(2, policy, SimReplicaConfig::default());
            for turn in 0..3u64 {
                // Rotated arrival order: with a fixed order and drained
                // waves, least-loaded's deterministic tiebreaks pin each
                // session to one replica (accidental perfect affinity)
                // and the policies tie.
                for k in 0..6u64 {
                    let session = (turn + k) % 6;
                    let id = turn * 6 + session;
                    r.submit(req(id, mk(turn as usize, session as i32), 4)).unwrap();
                }
                let _ = drain_all(&mut r);
            }
            r.prefix_hit_rate().expect("prefills ran")
        };
        let affinity = run(DispatchPolicy::PrefixAffinity);
        let least = run(DispatchPolicy::LeastLoaded);
        // Affinity routes later turns onto the replica holding their
        // session prefix; least-loaded scatters them.
        assert!(
            affinity > least,
            "affinity {affinity:.3} should beat least-loaded {least:.3}"
        );
    }

    #[test]
    fn abort_releases_everything_mid_flight() {
        let mut r = sim_router(2, DispatchPolicy::PrefixAffinity, SimReplicaConfig::default());
        for id in 0..4u64 {
            let prompt: Vec<i32> = (0..48).map(|j| (id as i32 + j) % 89).collect();
            r.submit(req(id, prompt, 32)).unwrap();
        }
        r.step().unwrap(); // prefill somewhere
        r.step().unwrap();
        r.abort(0).unwrap();
        r.abort(3).unwrap();
        let done = drain_all(&mut r);
        assert_eq!(done.len(), 2);
        assert_eq!(r.kv_unaccounted_blocks(), 0);
        assert_eq!(r.prefix_attached_refs(), 0);
    }

    #[test]
    fn subvocab_mode_emits_deterministic_events_and_counters() {
        let cfg = SimReplicaConfig {
            trace_level: TraceLevel::Lifecycle,
            subvocab: true,
            ..Default::default()
        };
        let run = || {
            let mut r = sim_router(1, DispatchPolicy::LeastLoaded, cfg);
            for id in 0..3u64 {
                let prompt: Vec<i32> =
                    (0..24).map(|j| (id as i32 * 5 + j) % 61).collect();
                r.submit(req(id, prompt, 6)).unwrap();
            }
            let done = drain_all(&mut r);
            assert_eq!(done.len(), 3);
            // Tokens are untouched by the subvocab model (exactness).
            for c in &done {
                for (i, &t) in c.tokens.iter().enumerate() {
                    assert_eq!(t, sim_token(c.id, i));
                }
            }
            let rep = &r.replicas()[0];
            let steps =
                rep.metrics.counters.get("subvocab_steps").copied().unwrap_or(0);
            let fb = rep
                .metrics
                .counters
                .get("subvocab_fallbacks")
                .copied()
                .unwrap_or(0);
            assert!(steps > 0, "decode steps ran");
            assert!(fb < steps, "cstep % 4 rule admits most steps");
            assert_eq!(rep.metrics.subvocab_fallback_rate(), Some(fb as f64 / steps as f64));
            // One event per decode step, kinds matching the counters.
            let mut skip_ev = 0u64;
            let mut fb_ev = 0u64;
            for e in rep.trace.events() {
                match &e.kind {
                    EventKind::SubvocabSkip { active, skipped } => {
                        assert_eq!((*active, *skipped), (4, 12));
                        skip_ev += 1;
                    }
                    EventKind::SubvocabFallback { .. } => fb_ev += 1,
                    _ => {}
                }
            }
            assert_eq!(skip_ev + fb_ev, steps);
            assert_eq!(fb_ev, fb);
            (steps, fb)
        };
        // Deterministic across runs.
        assert_eq!(run(), run());
        // And off by default: no events, no counters.
        let mut r = sim_router(
            1,
            DispatchPolicy::LeastLoaded,
            SimReplicaConfig { trace_level: TraceLevel::Lifecycle, ..Default::default() },
        );
        r.submit(req(9, vec![1, 2, 3], 4)).unwrap();
        drain_all(&mut r);
        assert!(!r.replicas()[0].metrics.counters.contains_key("subvocab_steps"));
    }

    #[test]
    fn reject_unschedulable_unsticks_an_oversized_head() {
        let cfg = SimReplicaConfig { num_blocks: 4, ..Default::default() };
        let mut r = sim_router(1, DispatchPolicy::LeastLoaded, cfg);
        // 5 blocks worth of prompt can never fit a 4-block pool.
        let big: Vec<i32> = (0..(16 * 5)).map(|j| j % 71).collect();
        r.submit(req(1, big, 4)).unwrap();
        assert!(r.step().unwrap().is_empty());
        let c = r.reject_unschedulable().expect("head is unschedulable");
        assert_eq!(c.finish, FinishReason::Rejected);
        assert_eq!(r.pending(), 0);
        assert_eq!(r.kv_unaccounted_blocks(), 0);
    }
}
