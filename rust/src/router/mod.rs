//! Multi-replica serving router: prefix-affinity dispatch over N engines
//! (DESIGN.md §13; ROADMAP item 1).
//!
//! FlashSampling's exactness is per-engine — the fused kernel (and its
//! TP factorization) fixes the token stream given Philox coordinates —
//! so everything ABOVE an engine is free to scale out without touching
//! the sampling contract.  This module is that layer: a [`Router`] owns
//! N replicas behind the existing handle-based front door
//! (`submit() → RequestHandle` / `abort()` / `step()`, same typed
//! [`EngineError`]s, same per-token event semantics), which makes
//! `serve --replicas N` a drop-in upgrade on the PR 5 serving loop.
//!
//! Dispatch is pluggable ([`DispatchPolicy`]): round-robin, least-loaded
//! (by pending count + KV headroom probes), and **prefix-affinity** —
//! route on the radix chain hash of the prompt's cacheable prefix
//! ([`crate::prefixcache::prefix_home_hash`]) so multi-turn sessions land
//! on the replica whose radix tree is warm, with least-loaded spillover
//! under KV pressure or pathological imbalance.  The policy function is
//! pure ([`policy::pick_replica`]) and mirrored bit-for-bit by the
//! Python bench sim, so routing decisions are replay-stable and
//! certifiable off-box (`repro router-identity`).
//!
//! Identity argument, in brief: the router never reorders, rewrites, or
//! re-times anything *within* a replica — it only chooses which replica
//! a request enters, then steps all replicas in fixed index order.  With
//! one replica every policy degenerates to "replica 0", so a 1-replica
//! router is the bare engine — byte-identical tokens, same Philox
//! coordinates, same events.  With N replicas, per-request streams stay
//! exact (each is a single-engine stream); what changes is placement,
//! which is deterministic in (policy, submission order, probe state).
//!
//! Replicas are anything implementing [`EngineBackend`]: a plain
//! [`Engine`] or a TP-sharded one (`EngineConfig::tp`) whose decode fans
//! out through `tp::TpOrchestrator` — see `backend.rs`.

pub mod backend;
pub mod policy;
pub mod sim;

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::coordinator::{Completion, Engine, EngineError, Request, RequestHandle};
use crate::prefixcache::prefix_home_hash;

pub use backend::EngineBackend;
pub use policy::{pick_replica, DispatchPolicy, ReplicaProbe, SPILL_PENDING_MARGIN};
pub use sim::{sim_router, sim_token, SimReplica, SimReplicaConfig};

/// N serving replicas behind one handle-based front door.
pub struct Router<B: EngineBackend = Engine> {
    replicas: Vec<B>,
    policy: DispatchPolicy,
    /// Monotone successful-submission counter — the round-robin cursor
    /// and the replay-stability anchor (advances only on accepted
    /// requests, so a rejected submit does not shift later placements).
    rr_next: u64,
    /// Live request id → replica index.  Insert at submit, remove at
    /// completion/abort/rejection; membership doubles as the
    /// router-level duplicate-id check (an id live on replica 2 must be
    /// refused even if replica 0 would accept it).
    owner: HashMap<u64, usize>,
}

impl<B: EngineBackend> Router<B> {
    /// Wrap `replicas` (>= 1) under `policy`.  All replicas must agree
    /// on the KV block size — it is the prefix-affinity key width.
    pub fn new(replicas: Vec<B>, policy: DispatchPolicy) -> Result<Self> {
        ensure!(!replicas.is_empty(), "router needs >= 1 replica");
        let bs = replicas[0].kv_block_size();
        ensure!(
            replicas.iter().all(|r| r.kv_block_size() == bs),
            "replicas disagree on kv_block_size — the affinity key width"
        );
        Ok(Self { replicas, policy, rr_next: 0, owner: HashMap::new() })
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    pub fn replicas(&self) -> &[B] {
        &self.replicas
    }

    /// Mutable replica access (wall-clock stamping, per-replica metric
    /// export).  Routing state (ownership map, cursor) is not exposed.
    pub fn replicas_mut(&mut self) -> &mut [B] {
        &mut self.replicas
    }

    /// Which replica owns live request `id` (None once finished).
    pub fn owner_of(&self, id: u64) -> Option<usize> {
        self.owner.get(&id).copied()
    }

    /// Sequences waiting/running/swapped across all replicas.
    pub fn pending(&self) -> usize {
        self.replicas.iter().map(|r| r.pending()).sum()
    }

    /// The logical step clock.  `step()` steps every replica exactly
    /// once, so all replica clocks stay equal; replica 0's is canonical.
    pub fn clock(&self) -> u64 {
        self.replicas[0].clock()
    }

    /// Pool-balance diagnostic summed over replicas (0 at quiescence).
    pub fn kv_unaccounted_blocks(&self) -> usize {
        self.replicas.iter().map(|r| r.kv_unaccounted_blocks()).sum()
    }

    /// Prefix-cache attachment refs summed over replicas (0 at
    /// quiescence).
    pub fn prefix_attached_refs(&self) -> usize {
        self.replicas.iter().map(|r| r.prefix_attached_refs()).sum()
    }

    /// Aggregate prefix-cache hit rate: cached prefill tokens over total
    /// prefill tokens, summed across replicas (None before any
    /// prefill).  The quantity the affinity-vs-least-loaded acceptance
    /// bound is stated over.
    pub fn prefix_hit_rate(&self) -> Option<f64> {
        let (cached, total) = self.replicas.iter().fold((0u64, 0u64), |(c, t), r| {
            let m = r.metrics();
            (c + m.cached_prefill_tokens, t + m.prefill_tokens)
        });
        (total > 0).then(|| cached as f64 / total as f64)
    }

    /// Merge every replica's flight recorder into one Chrome trace-event
    /// JSON document (DESIGN.md §14): one `pid` per replica, one `tid`
    /// per request.  Replicas without a recorder are skipped.
    pub fn chrome_trace(&self) -> String {
        let traces: Vec<(usize, &crate::trace::Trace)> = self
            .replicas
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.trace().map(|t| (i, t)))
            .collect();
        crate::trace::chrome_export(&traces)
    }

    /// Prometheus exposition over all replicas: one TYPE header per
    /// family, samples tagged `replica="i"` (ISSUE satellite; DESIGN.md
    /// §13).  At one replica the output is byte-identical to the bare
    /// engine's [`crate::metrics::ServingMetrics::render_prometheus`].
    pub fn render_prometheus(&self) -> String {
        let ms: Vec<&crate::metrics::ServingMetrics> =
            self.replicas.iter().map(|r| r.metrics()).collect();
        crate::metrics::render_prometheus_replicas(&ms)
    }

    /// Route and submit one request.  The returned handle is the owning
    /// replica's own — per-token events, terminal semantics, and typed
    /// errors are exactly the single-engine contract.
    pub fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError> {
        if self.owner.contains_key(&req.id) {
            return Err(EngineError::DuplicateRequestId { id: req.id });
        }
        let probes: Vec<ReplicaProbe> =
            self.replicas.iter().map(|r| r.probe(&req.prompt)).collect();
        let home = prefix_home_hash(&req.prompt, self.replicas[0].kv_block_size());
        let idx = pick_replica(self.policy, self.rr_next, &probes, home);
        let id = req.id;
        let handle = self.replicas[idx].submit(req)?;
        // Flight-recorder dispatch record (DESIGN.md §14), landed in the
        // chosen replica's own trace: which policy sent the request here,
        // how many replicas were warmer (`affinity_rank` = probes with
        // strictly more cached prefix tokens), and whether the choice
        // spilled away from the warmest replica.
        let policy = match self.policy {
            DispatchPolicy::RoundRobin => "round_robin",
            DispatchPolicy::LeastLoaded => "least_loaded",
            DispatchPolicy::PrefixAffinity => "prefix_affinity",
        };
        let warmest = probes.iter().map(|p| p.cached_tokens).max().unwrap_or(0);
        let affinity_rank = probes
            .iter()
            .filter(|p| p.cached_tokens > probes[idx].cached_tokens)
            .count();
        let spill = probes[idx].cached_tokens < warmest;
        self.replicas[idx].trace_dispatch(id, policy, idx, affinity_rank, spill);
        self.owner.insert(id, idx);
        self.rr_next += 1;
        Ok(handle)
    }

    /// Cancel a live request on whichever replica owns it.
    pub fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError> {
        let Some(&idx) = self.owner.get(&request_id) else {
            return Err(EngineError::UnknownRequest { id: request_id });
        };
        let c = self.replicas[idx].abort(request_id)?;
        self.owner.remove(&request_id);
        Ok(c)
    }

    /// One scheduler iteration on EVERY replica, in index order.
    /// Returns all completions finished this step (replica order, then
    /// each replica's own order — deterministic).
    pub fn step(&mut self) -> Result<Vec<Completion>, EngineError> {
        let mut done = Vec::new();
        for r in &mut self.replicas {
            done.extend(r.step()?);
        }
        for c in &done {
            self.owner.remove(&c.id);
        }
        Ok(done)
    }

    /// Open-loop backstop: ask replicas in index order to reject their
    /// unschedulable waiting head; first rejection wins.
    pub fn reject_unschedulable(&mut self) -> Option<Completion> {
        for r in &mut self.replicas {
            if let Some(c) = r.reject_unschedulable() {
                self.owner.remove(&c.id);
                return Some(c);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;
    use std::sync::{Arc, Mutex};

    use super::*;
    use crate::coordinator::stream::StreamState;
    use crate::coordinator::{FinishReason, SamplingParams};
    use crate::metrics::{RequestTiming, ServingMetrics};

    /// Accounting-only replica: enough of the engine contract to
    /// exercise the router's ownership, duplicate, and fan-in logic on a
    /// CPU-only box (no artifacts).  `steps_left` drains one per step.
    struct MockBackend {
        bs: usize,
        clock: u64,
        queue: VecDeque<(u64, usize)>,
        /// Prompt prefixes this replica pretends to have cached, as
        /// (tokens, cached_token_count).
        warm: Vec<(Vec<i32>, usize)>,
        headroom: usize,
        metrics: ServingMetrics,
    }

    impl MockBackend {
        fn new(bs: usize) -> Self {
            Self {
                bs,
                clock: 0,
                queue: VecDeque::new(),
                warm: Vec::new(),
                headroom: 64,
                metrics: ServingMetrics::default(),
            }
        }
    }

    fn complete(id: u64) -> Completion {
        Completion {
            id,
            prompt_len: 1,
            tokens: vec![7],
            finish: FinishReason::MaxTokens,
            timing: RequestTiming::default(),
        }
    }

    impl EngineBackend for MockBackend {
        fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError> {
            // The router already refused router-level duplicates; mirror
            // the engine-level check anyway.
            if self.queue.iter().any(|&(id, _)| id == req.id) {
                return Err(EngineError::DuplicateRequestId { id: req.id });
            }
            self.queue.push_back((req.id, req.params.max_new_tokens));
            Ok(RequestHandle::new(
                req.id,
                Arc::new(Mutex::new(StreamState::default())),
            ))
        }

        fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError> {
            match self.queue.iter().position(|&(id, _)| id == request_id) {
                Some(i) => {
                    self.queue.remove(i);
                    Ok(Completion { finish: FinishReason::Aborted, ..complete(request_id) })
                }
                None => Err(EngineError::UnknownRequest { id: request_id }),
            }
        }

        fn step(&mut self) -> Result<Vec<Completion>, EngineError> {
            self.clock += 1;
            for slot in self.queue.iter_mut() {
                slot.1 = slot.1.saturating_sub(1);
            }
            let mut done = Vec::new();
            let mut i = 0;
            while i < self.queue.len() {
                if self.queue[i].1 == 0 {
                    let (id, _) = self.queue.remove(i).expect("index in range");
                    done.push(complete(id));
                } else {
                    i += 1;
                }
            }
            Ok(done)
        }

        fn reject_unschedulable(&mut self) -> Option<Completion> {
            None
        }

        fn pending(&self) -> usize {
            self.queue.len()
        }

        fn clock(&self) -> u64 {
            self.clock
        }

        fn kv_block_size(&self) -> usize {
            self.bs
        }

        fn probe(&self, prompt: &[i32]) -> ReplicaProbe {
            let cached = self
                .warm
                .iter()
                .filter(|(p, _)| prompt.starts_with(p))
                .map(|&(_, n)| n)
                .max()
                .unwrap_or(0);
            ReplicaProbe {
                pending: self.queue.len(),
                headroom: self.headroom,
                blocks_needed: prompt.len().div_ceil(self.bs),
                cached_tokens: cached,
            }
        }

        fn metrics(&self) -> &ServingMetrics {
            &self.metrics
        }

        fn kv_unaccounted_blocks(&self) -> usize {
            0
        }

        fn prefix_attached_refs(&self) -> usize {
            0
        }
    }

    fn req(id: u64, prompt: Vec<i32>, steps: usize) -> Request {
        Request::new(
            id,
            prompt,
            SamplingParams { max_new_tokens: steps, ..Default::default() },
        )
    }

    fn router(n: usize, policy: DispatchPolicy) -> Router<MockBackend> {
        Router::new((0..n).map(|_| MockBackend::new(4)).collect(), policy).unwrap()
    }

    #[test]
    fn construction_rejects_empty_and_mismatched_block_sizes() {
        assert!(Router::<MockBackend>::new(Vec::new(), DispatchPolicy::RoundRobin).is_err());
        let mixed = vec![MockBackend::new(4), MockBackend::new(8)];
        assert!(Router::new(mixed, DispatchPolicy::RoundRobin).is_err());
    }

    #[test]
    fn round_robin_spreads_submissions_and_owner_map_tracks_them() {
        let mut r = router(3, DispatchPolicy::RoundRobin);
        for id in 0..6u64 {
            r.submit(req(id, vec![1, 2, 3, 4], 2)).unwrap();
        }
        for id in 0..6u64 {
            assert_eq!(r.owner_of(id), Some((id % 3) as usize));
        }
        assert_eq!(r.pending(), 6);
        assert_eq!(r.replicas()[0].pending(), 2);
    }

    #[test]
    fn duplicate_ids_are_refused_across_replicas() {
        let mut r = router(2, DispatchPolicy::RoundRobin);
        r.submit(req(1, vec![1, 2, 3, 4], 2)).unwrap();
        // Round-robin would place the duplicate on the OTHER replica,
        // which would happily accept it — the router must refuse first.
        let err = r.submit(req(1, vec![9, 9, 9, 9], 2)).unwrap_err();
        assert!(matches!(err, EngineError::DuplicateRequestId { id: 1 }));
        // The failed submit must not advance the round-robin cursor.
        r.submit(req(2, vec![1, 2, 3, 4], 2)).unwrap();
        assert_eq!(r.owner_of(2), Some(1));
    }

    #[test]
    fn abort_routes_to_the_owning_replica() {
        let mut r = router(2, DispatchPolicy::RoundRobin);
        r.submit(req(1, vec![1, 2, 3, 4], 5)).unwrap();
        r.submit(req(2, vec![1, 2, 3, 4], 5)).unwrap();
        let c = r.abort(2).unwrap();
        assert_eq!(c.finish, FinishReason::Aborted);
        assert_eq!(r.owner_of(2), None);
        assert_eq!(r.replicas()[1].pending(), 0);
        assert_eq!(r.replicas()[0].pending(), 1);
        assert!(matches!(r.abort(2), Err(EngineError::UnknownRequest { id: 2 })));
    }

    #[test]
    fn step_concatenates_in_replica_order_and_frees_ids_for_reuse() {
        let mut r = router(2, DispatchPolicy::RoundRobin);
        r.submit(req(10, vec![1, 2, 3, 4], 1)).unwrap(); // replica 0
        r.submit(req(11, vec![1, 2, 3, 4], 1)).unwrap(); // replica 1
        let done = r.step().unwrap();
        assert_eq!(done.iter().map(|c| c.id).collect::<Vec<_>>(), vec![10, 11]);
        assert_eq!(r.pending(), 0);
        assert_eq!(r.owner_of(10), None);
        // Finished ids are reusable — exactly the engine's liveness rule.
        r.submit(req(10, vec![1, 2, 3, 4], 1)).unwrap();
    }

    #[test]
    fn prefix_affinity_keeps_a_session_on_its_warm_replica() {
        let mut r = router(3, DispatchPolicy::PrefixAffinity);
        r.replicas[1].warm.push((vec![5, 5, 5, 5], 4));
        // All turns of the session (shared 4-token first block) land on
        // the warm replica regardless of submission index.
        for id in 0..4u64 {
            r.submit(req(id, vec![5, 5, 5, 5, id as i32 + 1], 3)).unwrap();
            assert_eq!(r.owner_of(id), Some(1));
        }
        // A KV-exhausted warm replica forfeits to least-loaded.
        r.replicas[1].headroom = 0;
        r.submit(req(9, vec![5, 5, 5, 5, 6], 3)).unwrap();
        assert_ne!(r.owner_of(9), Some(1));
    }

    #[test]
    fn clock_is_uniform_across_replicas() {
        let mut r = router(3, DispatchPolicy::LeastLoaded);
        r.submit(req(1, vec![1, 2, 3, 4], 2)).unwrap();
        for _ in 0..4 {
            r.step().unwrap();
        }
        assert_eq!(r.clock(), 4);
        assert!(r.replicas().iter().all(|b| b.clock() == 4));
    }

    #[test]
    fn prometheus_export_labels_replicas() {
        let mut r = router(2, DispatchPolicy::RoundRobin);
        r.replicas[0].metrics.requests_completed = 3;
        r.replicas[1].metrics.requests_completed = 4;
        let text = r.render_prometheus();
        assert!(text.contains("flashsampling_requests_completed{replica=\"0\"} 3\n"));
        assert!(text.contains("flashsampling_requests_completed{replica=\"1\"} 4\n"));
        // One replica: unlabeled, byte-identical to the bare export.
        let solo = router(1, DispatchPolicy::RoundRobin);
        assert_eq!(
            solo.render_prometheus(),
            solo.replicas[0].metrics.render_prometheus()
        );
    }
}
