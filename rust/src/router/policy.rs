//! Dispatch policies — the pure routing brain of the multi-replica
//! router (DESIGN.md §13).
//!
//! `pick_replica` is a total, deterministic function of the replica
//! probes and the request's prefix-affinity key: no clocks, no
//! randomness, no interior state beyond the caller-held round-robin
//! cursor.  That purity is the certification surface — the same function
//! drives the real [`crate::router::Router`], the accounting-level
//! [`crate::router::SimReplica`] harness, and the Python bench mirror
//! (`python/tests/sim_router_bench.py`), so `repro router-identity` can
//! assert replay stability and the bench numbers are reproducible
//! bit-for-bit off-box.

use anyhow::{bail, Result};

/// How the router maps an incoming request onto one of N replicas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle through replicas in submission order.  Ignores all probes —
    /// the baseline every other policy is benched against.
    RoundRobin,
    /// Send each request to the replica with the fewest pending
    /// sequences, breaking ties toward more free+evictable KV headroom,
    /// then lower index.  Balances load but scatters shared-prefix
    /// sessions, so each replica re-prefills the same system prompt.
    LeastLoaded,
    /// Route on the radix chain hash of the request's cacheable prefix
    /// so multi-turn sessions land where their KV is warm
    /// (vLLM/SGLang-style cache-aware routing), spilling over to
    /// least-loaded when the preferred replica is out of KV headroom or
    /// pathologically behind.  The default: prefix caching defaults on,
    /// and affinity is free when nothing is shared.
    #[default]
    PrefixAffinity,
}

impl std::str::FromStr for DispatchPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim() {
            "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "least-loaded" => Ok(DispatchPolicy::LeastLoaded),
            "prefix-affinity" => Ok(DispatchPolicy::PrefixAffinity),
            other => bail!(
                "unknown dispatch policy '{other}' (expected \
                 round-robin|least-loaded|prefix-affinity)"
            ),
        }
    }
}

impl std::fmt::Display for DispatchPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
            DispatchPolicy::PrefixAffinity => "prefix-affinity",
        })
    }
}

/// One replica's answer to "how would this request land on you?" —
/// everything `pick_replica` is allowed to see.  Built from the engine's
/// existing admission probes (`prefill_headroom`, `prefill_blocks_needed`,
/// `cached_prefix_tokens`), all pure with respect to engine state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaProbe {
    /// Sequences queued, running, or swapped on this replica.
    pub pending: usize,
    /// Free + evictable KV blocks available to admit this prompt.
    pub headroom: usize,
    /// New KV blocks this prompt needs beyond its cached prefix.
    pub blocks_needed: usize,
    /// Tokens of this prompt already resident in the replica's radix
    /// cache (0 on a cold replica).
    pub cached_tokens: usize,
}

/// Pending-count slack before prefix affinity abandons a warm replica:
/// the home replica may run up to this many sequences deeper than the
/// emptiest one before a request spills to least-loaded.  Small enough
/// that no replica starves (the no-starvation property test), large
/// enough that a session isn't bounced off its warm cache by ordinary
/// queue jitter.
pub const SPILL_PENDING_MARGIN: usize = 4;

/// Index of the least-loaded replica: fewest pending, ties broken by
/// more headroom, then lower index.
fn least_loaded(probes: &[ReplicaProbe]) -> usize {
    let mut best = 0;
    for (i, p) in probes.iter().enumerate().skip(1) {
        let b = &probes[best];
        if (p.pending, std::cmp::Reverse(p.headroom)) < (b.pending, std::cmp::Reverse(b.headroom))
        {
            best = i;
        }
    }
    best
}

/// Choose the replica for one request.  Deterministic in its inputs:
/// `rr_next` is the caller's monotone submission counter (consumed by
/// `RoundRobin` only), `probes` has one entry per replica (must be
/// non-empty), and `home_hash` is the request's
/// [`crate::prefixcache::prefix_home_hash`] — `None` when the prompt is
/// shorter than one KV block and therefore has no cacheable prefix.
pub fn pick_replica(
    policy: DispatchPolicy,
    rr_next: u64,
    probes: &[ReplicaProbe],
    home_hash: Option<u64>,
) -> usize {
    assert!(!probes.is_empty(), "router needs >= 1 replica");
    let n = probes.len();
    match policy {
        DispatchPolicy::RoundRobin => (rr_next % n as u64) as usize,
        DispatchPolicy::LeastLoaded => least_loaded(probes),
        DispatchPolicy::PrefixAffinity => {
            // Warm path: the replica holding the longest cached prefix.
            // Ties (several replicas cached the same shared prefix) break
            // toward the emptiest, then lowest index.
            let warm = (0..n)
                .filter(|&i| probes[i].cached_tokens > 0)
                .min_by_key(|&i| {
                    (std::cmp::Reverse(probes[i].cached_tokens), probes[i].pending, i)
                });
            // Cold path: a deterministic home derived from the prefix
            // hash, so every future request sharing this first block
            // lands on the same replica and builds the cache there.
            let chosen = match (warm, home_hash) {
                (Some(i), _) => i,
                (None, Some(h)) => (h % n as u64) as usize,
                // No cacheable prefix at all: affinity has nothing to
                // say; place by load.
                (None, None) => return least_loaded(probes),
            };
            // Spillover: a warm or home replica that cannot admit the
            // prompt (KV exhausted) or has fallen pathologically behind
            // the emptiest replica forfeits the request to least-loaded
            // — cache locality is a tiebreak, not a starvation license.
            let min_pending = probes.iter().map(|p| p.pending).min().unwrap();
            let c = &probes[chosen];
            if c.headroom < c.blocks_needed
                || c.pending > min_pending + SPILL_PENDING_MARGIN
            {
                least_loaded(probes)
            } else {
                chosen
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(pending: usize, headroom: usize, needed: usize, cached: usize) -> ReplicaProbe {
        ReplicaProbe { pending, headroom, blocks_needed: needed, cached_tokens: cached }
    }

    #[test]
    fn policy_parses_and_round_trips() {
        assert_eq!(DispatchPolicy::default(), DispatchPolicy::PrefixAffinity);
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::PrefixAffinity,
        ] {
            let back: DispatchPolicy = p.to_string().parse().unwrap();
            assert_eq!(back, p);
        }
        assert!(" least-loaded ".parse::<DispatchPolicy>().is_ok()); // trimmed
        assert!("random".parse::<DispatchPolicy>().is_err());
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let probes = vec![probe(9, 0, 1, 0), probe(0, 64, 1, 0), probe(0, 64, 1, 0)];
        let picks: Vec<usize> = (0..6)
            .map(|i| pick_replica(DispatchPolicy::RoundRobin, i, &probes, None))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_fewest_pending_then_headroom_then_index() {
        let probes = vec![probe(3, 64, 1, 0), probe(1, 2, 1, 0), probe(1, 8, 1, 0)];
        assert_eq!(pick_replica(DispatchPolicy::LeastLoaded, 0, &probes, None), 2);
        // Full tie falls to the lowest index.
        let tied = vec![probe(1, 8, 1, 0), probe(1, 8, 1, 0)];
        assert_eq!(pick_replica(DispatchPolicy::LeastLoaded, 7, &tied, None), 0);
    }

    #[test]
    fn affinity_follows_the_warm_cache() {
        // Replica 2 holds the longest cached prefix; load is comparable.
        let probes =
            vec![probe(2, 64, 4, 0), probe(1, 64, 4, 16), probe(2, 64, 2, 48)];
        assert_eq!(
            pick_replica(DispatchPolicy::PrefixAffinity, 0, &probes, Some(99)),
            2
        );
        // Equal cached depth: the emptier warm replica wins.
        let tied =
            vec![probe(5, 64, 4, 32), probe(1, 64, 4, 32), probe(0, 64, 4, 0)];
        assert_eq!(
            pick_replica(DispatchPolicy::PrefixAffinity, 0, &tied, Some(99)),
            1
        );
    }

    #[test]
    fn affinity_cold_start_routes_by_home_hash() {
        let probes = vec![probe(0, 64, 4, 0); 3];
        for h in [0u64, 1, 2, 3, 100] {
            assert_eq!(
                pick_replica(DispatchPolicy::PrefixAffinity, 0, &probes, Some(h)),
                (h % 3) as usize
            );
        }
        // No cacheable prefix at all (sub-block prompt): place by load.
        let uneven = vec![probe(4, 64, 1, 0), probe(0, 64, 1, 0)];
        assert_eq!(pick_replica(DispatchPolicy::PrefixAffinity, 0, &uneven, None), 1);
    }

    #[test]
    fn affinity_spills_over_under_kv_pressure_and_imbalance() {
        // Warm replica 0 cannot admit the prompt (headroom < needed).
        let pressured =
            vec![probe(1, 1, 4, 32), probe(2, 64, 4, 0), probe(3, 64, 4, 0)];
        assert_eq!(
            pick_replica(DispatchPolicy::PrefixAffinity, 0, &pressured, Some(0)),
            1
        );
        // Warm replica 0 is more than SPILL_PENDING_MARGIN deeper than
        // the emptiest.
        let behind = vec![
            probe(SPILL_PENDING_MARGIN + 1, 64, 4, 32),
            probe(0, 64, 4, 0),
        ];
        assert_eq!(
            pick_replica(DispatchPolicy::PrefixAffinity, 0, &behind, Some(0)),
            1
        );
        // Exactly at the margin: affinity holds.
        let at_margin =
            vec![probe(SPILL_PENDING_MARGIN, 64, 4, 32), probe(0, 64, 4, 0)];
        assert_eq!(
            pick_replica(DispatchPolicy::PrefixAffinity, 0, &at_margin, Some(0)),
            0
        );
    }

    #[test]
    fn single_replica_is_always_picked() {
        let one = vec![probe(7, 0, 9, 0)];
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::PrefixAffinity,
        ] {
            for rr in 0..3 {
                assert_eq!(pick_replica(policy, rr, &one, Some(42)), 0);
                assert_eq!(pick_replica(policy, rr, &one, None), 0);
            }
        }
    }
}
