//! [`EngineBackend`] — the replica abstraction the router is generic
//! over (ROADMAP item 1's unification).
//!
//! A replica is anything that speaks the handle-based serving protocol:
//! `submit() → RequestHandle`, `abort()`, `step() → completions`, plus
//! the pure admission probes dispatch policies read.  Two production
//! shapes exist, both the SAME type:
//!
//! * a plain [`Engine`] (`EngineConfig::tp = None`) — single-shard
//!   decode through the fused `decode_sample_b{B}` artifacts;
//! * a TP-sharded engine (`EngineConfig::tp = Some(TpDecode { .. })`) —
//!   decode runs the `decode_hidden_b{B}` transformer artifact, then
//!   fans the hidden states out through [`crate::tp::TpOrchestrator`]
//!   over the `gpusim` interconnect model.  Exact by the paper's
//!   hierarchical-factorization argument: the distributed sampler
//!   consumes the same Philox `(row, counter-step)` coordinates as the
//!   fused single-device kernel (`rust/tests/integration_tp.rs::
//!   fanout_matches_single_device_kernel`), so shard count is invisible
//!   in the token stream and every stream-identity certificate carries
//!   over.
//!
//! The trait exists so the router's ownership/accounting logic is
//! testable without artifacts (a mock backend in `router::tests`) and so
//! future replica shapes (remote engines, processes) slot in behind the
//! same front door.

use anyhow::Result;

use crate::coordinator::{Completion, Engine, EngineError, Request, RequestHandle};
use crate::metrics::ServingMetrics;

use super::policy::ReplicaProbe;

/// One serving replica behind the router.  Mirrors the public `Engine`
/// surface the serving front-end already drives, plus the pure probes
/// dispatch needs; implementors must preserve the engine's semantics —
/// typed [`EngineError`]s, per-token events on the returned handle,
/// terminal events at completion/abort.
pub trait EngineBackend {
    /// Submit a request; events stream on the returned handle.
    fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError>;
    /// Cancel a live request (zero-leak KV/prefix release).
    fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError>;
    /// One scheduler iteration; returns completions finished this step.
    fn step(&mut self) -> Result<Vec<Completion>, EngineError>;
    /// Open-loop backstop: reject the unschedulable waiting head (see
    /// [`Engine::reject_unschedulable`]).
    fn reject_unschedulable(&mut self) -> Option<Completion>;
    /// Sequences waiting, running, or swapped.
    fn pending(&self) -> usize;
    /// The replica's logical step clock.
    fn clock(&self) -> u64;
    /// KV block size in token positions (affinity-key width; the router
    /// requires all replicas to agree).
    fn kv_block_size(&self) -> usize;
    /// The admission probe dispatch policies read, answered for one
    /// prompt.  Must be pure with respect to replica state.
    fn probe(&self, prompt: &[i32]) -> ReplicaProbe;
    /// Serving metrics (per-replica labels in the Prometheus export).
    fn metrics(&self) -> &ServingMetrics;
    /// Pool-balance diagnostic: blocks neither free nor cache-resident
    /// (0 at quiescence — the router leak test sums this over replicas).
    fn kv_unaccounted_blocks(&self) -> usize;
    /// Live prefix-cache attachment refs (0 at quiescence).
    fn prefix_attached_refs(&self) -> usize;
    /// Router dispatch hook (DESIGN.md §14): record which policy sent a
    /// request here and how warm the choice was.  Default no-op so
    /// backends without a flight recorder compile unchanged.
    fn trace_dispatch(
        &mut self,
        _id: u64,
        _policy: &'static str,
        _replica: usize,
        _affinity_rank: usize,
        _spill: bool,
    ) {
    }
    /// The replica's flight recorder, when it has one (per-replica
    /// tracks in the router's Chrome-trace export).
    fn trace(&self) -> Option<&crate::trace::Trace> {
        None
    }
}

impl EngineBackend for Engine {
    fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError> {
        Engine::submit(self, req)
    }

    fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError> {
        Engine::abort(self, request_id)
    }

    fn step(&mut self) -> Result<Vec<Completion>, EngineError> {
        Engine::step(self)
    }

    fn reject_unschedulable(&mut self) -> Option<Completion> {
        Engine::reject_unschedulable(self)
    }

    fn pending(&self) -> usize {
        Engine::pending(self)
    }

    fn clock(&self) -> u64 {
        Engine::clock(self)
    }

    fn kv_block_size(&self) -> usize {
        Engine::kv_block_size(self)
    }

    fn probe(&self, prompt: &[i32]) -> ReplicaProbe {
        ReplicaProbe {
            pending: self.pending(),
            headroom: self.prefill_headroom(prompt),
            blocks_needed: self.prefill_blocks_needed(prompt),
            cached_tokens: self.cached_prefix_tokens(prompt),
        }
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    fn kv_unaccounted_blocks(&self) -> usize {
        Engine::kv_unaccounted_blocks(self)
    }

    fn prefix_attached_refs(&self) -> usize {
        Engine::prefix_attached_refs(self)
    }

    fn trace_dispatch(
        &mut self,
        id: u64,
        policy: &'static str,
        replica: usize,
        affinity_rank: usize,
        spill: bool,
    ) {
        if self.trace.on() {
            self.trace.emit(
                self.clock(),
                id,
                crate::trace::EventKind::Dispatch { policy, replica, affinity_rank, spill },
            );
        }
    }

    fn trace(&self) -> Option<&crate::trace::Trace> {
        Some(&self.trace)
    }
}
