//! FlashSampling launcher CLI.
//!
//! ```text
//! flashsampling serve   [--config F] [--set k=v]...   open-loop serving run
//! flashsampling repro   <id|all|stats> [--out DIR]    regenerate paper tables
//! flashsampling trace   [--out DIR] [--replicas N] [--subvocab]   flight-recorder demo run
//! flashsampling profile [--out DIR] [--replicas N] [--subvocab]   modeled-time profile
//! flashsampling benchdiff OLD.json NEW.json [--tolerance F]  perf gate
//! flashsampling bench-kernel [--set k=v]...           PJRT kernel A/B timing
//! flashsampling selfcheck [--set k=v]...              load artifacts, smoke-run
//! ```
//!
//! (Arg parsing is hand-rolled: the offline image carries no clap.)

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use flashsampling::config::{parse_pairs, Config};
use flashsampling::coordinator::{Engine, Request, RequestHandle, SamplingParams};
use flashsampling::router::Router;
use flashsampling::runtime::{Runtime, Tensor};
use flashsampling::sampling::Key;
use flashsampling::workload::WorkloadGen;

fn usage() -> ! {
    eprintln!(
        "usage: flashsampling <serve|repro|trace|profile|benchdiff|bench-kernel|selfcheck> [args]\n\
         \n\
         serve        [--replicas N] --config FILE | --set key=value ...\n\
         repro        <table1|table4|...|fig6|chisq|hetero-chisq|specdec-chisq|prefix-identity|stream-identity|chunk-identity|router-identity|trace-identity|profile-identity|subvocab-identity|e2e-quality|all|stats> [--out DIR]\n\
         trace        [--out DIR] [--replicas N] [--subvocab] [--set trace_level=lifecycle|full]\n\
         profile      [--out DIR] [--replicas N] [--subvocab]\n\
         benchdiff    OLD.json NEW.json [--tolerance FRACTION]\n\
         bench-kernel [--set key=value ...]\n\
         selfcheck    [--set key=value ...]"
    );
    std::process::exit(2);
}

fn parse_overrides(args: &[String]) -> Result<(Config, Vec<String>)> {
    let mut cfg = Config::default();
    let mut pairs = BTreeMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                let path = args.get(i + 1).context("--config needs a path")?;
                cfg = Config::from_file(std::path::Path::new(path))?;
                i += 2;
            }
            "--set" => {
                let kv = args.get(i + 1).context("--set needs key=value")?;
                for (k, v) in parse_pairs(kv)? {
                    pairs.insert(k, v);
                }
                i += 2;
            }
            "--out" => {
                let dir = args.get(i + 1).context("--out needs a dir")?;
                pairs.insert("out_dir".into(), dir.clone());
                i += 2;
            }
            "--replicas" => {
                let n = args.get(i + 1).context("--replicas needs a count")?;
                pairs.insert("replicas".into(), n.clone());
                i += 2;
            }
            "--subvocab" => {
                pairs.insert("subvocab".into(), "true".into());
                i += 1;
            }
            other if other.starts_with("--") => bail!("unknown flag {other}"),
            other => {
                positional.push(other.to_string());
                i += 1;
            }
        }
    }
    cfg.apply_pairs(pairs)?;
    Ok((cfg, positional))
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    // The serving front door is ALWAYS the router (DESIGN.md §13):
    // `replicas = 1` (the default) degenerates to the bare engine —
    // every policy picks replica 0 and the router adds no reordering, so
    // token streams are byte-identical to the pre-router stack (`repro
    // router-identity` is the certificate).  Replicas share the session
    // seed; at N >= 2, placement shifts batch composition and per-engine
    // step counters, so streams are exact and replay-stable rather than
    // equal to the single-engine run.
    let engines = (0..cfg.replicas)
        .map(|_| Engine::new(&cfg.artifacts_dir, cfg.engine_config()))
        .collect::<Result<Vec<_>>>()?;
    let mut router = Router::new(engines, cfg.dispatch_policy)?;
    let vocab = router.replicas()[0].runtime().manifest().model.vocab;
    let mut gen = WorkloadGen::new(cfg.seed, cfg.request_rate, vocab);
    gen.temperature = cfg.temperature;
    gen.temperature_choices = cfg.temperature_choices.clone();
    gen.priority_choices = cfg.priority_choices.clone();
    gen.prompt_len = flashsampling::workload::LengthDist::Uniform(8, 48);
    gen.output_len = flashsampling::workload::LengthDist::Fixed(cfg.max_new_tokens);
    let sampler_desc = if let flashsampling::sampling::SamplerSpec::SpecDecode {
        k,
        ngram,
    } = cfg.engine_config().sampler
    {
        format!(
            "speculative decode (coupled verification over decode_sample, \
             K={k}, n-gram order {ngram})"
        )
    } else if cfg.engine_config().uses_baseline_artifact() {
        "baseline multinomial (decode_baseline artifact)".to_string()
    } else {
        format!("FlashSampling (decode_sample artifact, spec `{}`)", cfg.sampler)
    };
    println!(
        "[serve] open-loop streaming: {} requests, Poisson rate {}/s, \
         sampler = {sampler_desc}",
        cfg.num_requests, cfg.request_rate,
    );
    if cfg.replicas > 1 {
        println!(
            "[serve] router: {} replicas, dispatch = {}",
            cfg.replicas, cfg.dispatch_policy
        );
    }

    // Streaming drive of the handle API (DESIGN.md §11): submit each
    // request at its Poisson arrival offset, step the engine
    // continuously, and consume per-token events from the handles as
    // they appear — the per-token latency percentiles below come from
    // this live stream, not from post-hoc completion records.
    let start = std::time::Instant::now();
    let mut arrivals = gen.arrivals().take(cfg.num_requests).peekable();
    // Only in-flight handles are polled; a handle is dropped from the
    // active set once its terminal event arrives.
    let mut active: Vec<RequestHandle> = Vec::new();
    let mut submitted = 0usize;
    let mut streamed_tokens = 0u64;
    let mut finished = 0usize;
    while submitted < cfg.num_requests || router.pending() > 0 {
        let now = start.elapsed().as_secs_f64();
        while arrivals.peek().is_some_and(|s| s.arrival_s <= now) {
            let s = arrivals.next().expect("peeked");
            active.push(router.submit(Request {
                id: s.id,
                prompt: s.prompt,
                params: SamplingParams {
                    temperature: s.temperature,
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
                priority: s.priority,
            })?);
            submitted += 1;
        }
        if router.pending() == 0 {
            if let Some(next) = arrivals.peek() {
                let wait = next.arrival_s - start.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        wait.min(0.05),
                    ));
                }
            }
            continue;
        }
        let completions = router.step()?;
        let mut progressed = !completions.is_empty();
        active.retain(|h| {
            let mut done = false;
            for ev in h.drain() {
                progressed = true;
                if ev.token.is_some() {
                    streamed_tokens += 1;
                }
                if ev.finish.is_some() {
                    finished += 1;
                    done = true;
                }
            }
            !done
        });
        if !progressed {
            // Nothing ran and nothing streamed: some waiting head can
            // never be admitted on its replica — reject it instead of
            // spinning on Plan::Idle forever (no-op while work runs).
            // The completion is consumed via the handle's terminal event.
            let _ = router.reject_unschedulable();
        }
    }
    // Terminal events queued by a final rejection land here.
    for h in &active {
        for ev in h.drain() {
            if ev.token.is_some() {
                streamed_tokens += 1;
            }
            if ev.finish.is_some() {
                finished += 1;
            }
        }
    }
    let wall = start.elapsed();
    for e in router.replicas_mut() {
        e.metrics.wall = wall;
    }
    let agg_tps: f64 =
        router.replicas().iter().map(|e| e.metrics.throughput_tps()).sum();
    println!(
        "[serve] completed {} requests | {} streamed tokens | wall {:.2}s | \
         {:.1} tok/s",
        finished,
        streamed_tokens,
        wall.as_secs_f64(),
        agg_tps
    );
    let ms = |d: Option<std::time::Duration>| {
        d.map(|d| d.as_secs_f64() * 1e3).unwrap_or(f64::NAN)
    };
    for (i, e) in router.replicas().iter().enumerate() {
        let m = &e.metrics;
        // Single-replica runs keep the legacy line format.
        let tag = if cfg.replicas > 1 {
            format!("[serve] replica {i}: ")
        } else {
            "[serve] ".to_string()
        };
        println!(
            "{tag}TTFT p50 {:.1} ms | TTFT p99 {:.1} ms | inter-token p99 \
             {:.2} ms | median TPOT {:.2} ms | mean batch {:.2}",
            ms(m.ttft_quantile(0.5)),
            ms(m.ttft_quantile(0.99)),
            ms(m.inter_token_quantile(0.99)),
            ms(m.median_tpot()),
            m.mean_batch()
        );
        if !m.spec_tokens_per_step.is_empty() {
            // Acceptance is None when the drafter never proposed (e.g. no
            // suffix repeats); the spec path still ran, so still report it.
            let acc = m
                .spec_acceptance_rate()
                .map_or("n/a (no drafts)".to_string(), |a| {
                    format!("{:.1}%", a * 100.0)
                });
            println!(
                "{tag}spec decode: acceptance {acc} | {:.2} tokens/step",
                m.mean_spec_tokens_per_step()
            );
        }
        for (k, v) in &m.counters {
            println!("{tag}counter {k} = {v}");
        }
    }
    if let Some(rate) = router.prefix_hit_rate() {
        let (cached, total) = router.replicas().iter().fold((0u64, 0u64), |a, e| {
            (a.0 + e.metrics.cached_prefill_tokens, a.1 + e.metrics.prefill_tokens)
        });
        println!(
            "[serve] prefix cache: {:.1}% token hit rate ({cached} of {total} \
             prefill tokens served from cache)",
            rate * 100.0
        );
    }
    // Per-replica-labeled Prometheus exposition on demand (scrape-file
    // sink; replicas=1 writes the bare-engine unlabeled format).
    if let Ok(path) = std::env::var("FS_PROM_OUT") {
        std::fs::write(&path, router.render_prometheus())?;
        println!("[serve] wrote Prometheus metrics to {path}");
    }
    Ok(())
}

/// Statistical reports flag failures with these sentinels; the CLI exits
/// nonzero when one appears so CI's repro smoke step fails the workflow on
/// a statistical regression, not just the testbed.
fn check_repro_verdicts(id: &str, md: &str) -> Result<()> {
    for sentinel in ["REJECTED", "MISMATCH", "SIGNIFICANT DIFFERENCE"] {
        if md.contains(sentinel) {
            bail!("repro {id} reports {sentinel} — statistical regression");
        }
    }
    Ok(())
}

fn cmd_repro(cfg: &Config, what: &str) -> Result<()> {
    match what {
        "all" => flashsampling::repro::run_all(&cfg.out_dir)?,
        "stats" => {
            for id in flashsampling::repro::STATS {
                let md = flashsampling::repro::run(id, &cfg.out_dir)?;
                println!("=== {id} ===\n{md}");
                check_repro_verdicts(id, &md)?;
            }
        }
        id => {
            let md = flashsampling::repro::run(id, &cfg.out_dir)?;
            println!("{md}");
            check_repro_verdicts(id, &md)?;
        }
    }
    println!("[repro] wrote results under {}", cfg.out_dir.display());
    Ok(())
}

/// Drive the deterministic multi-turn session workload (the
/// router-identity shape: 6 sessions over 4 shared system prompts, 3
/// turns, one mid-run abort for event variety) through
/// `Router<SimReplica>` — no artifacts needed — with tracing on.
/// Shared by `trace` (event-log export) and `profile` (modeled-time
/// attribution over the same events).
fn drive_traced_session_demo(
    cfg: &Config,
) -> Result<flashsampling::router::Router<flashsampling::router::SimReplica>> {
    use flashsampling::router::{sim_router, SimReplicaConfig};
    use flashsampling::trace::TraceLevel;
    // These subcommands exist to consume a trace, so `off` (the serving
    // default) escalates to `full`; an explicit lifecycle/full sticks.
    let level = if cfg.trace_level == TraceLevel::Off {
        TraceLevel::Full
    } else {
        cfg.trace_level
    };
    let replicas = cfg.replicas.max(1);
    // `--subvocab` turns on the replica's certified sub-vocab event
    // model, so skipped-tile / fallback spans land in the Perfetto
    // export alongside prefill/decode.
    let mut router = sim_router(
        replicas,
        cfg.dispatch_policy,
        SimReplicaConfig {
            trace_level: level,
            subvocab: cfg.subvocab,
            ..Default::default()
        },
    );
    let sys = |s: u64| -> Vec<i32> {
        (0..32).map(|j| ((s * 97 + j * 13 + 5) % 2048) as i32).collect()
    };
    for turn in 0..3u64 {
        for k in 0..6u64 {
            let session = (turn + k) % 6;
            let mut p = sys(session % 4);
            for t in 0..=turn {
                p.extend((0..16u64).map(|j| {
                    ((session * 59 + t * 31 + j * 7 + 11) % 2048) as i32
                }));
            }
            let _ = router.submit(Request::new(
                turn * 6 + session,
                p,
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            ))?;
        }
        if turn == 1 && router.owner_of(7).is_some() {
            let _ = router.abort(7)?;
        }
        let mut idle = 0;
        while router.pending() > 0 {
            if router.step()?.is_empty() {
                idle += 1;
                if idle > 8 && router.reject_unschedulable().is_some() {
                    idle = 0;
                    continue;
                }
                anyhow::ensure!(idle < 64, "trace demo sim livelock");
            } else {
                idle = 0;
            }
        }
    }
    Ok(router)
}

/// Flight-recorder demonstration run (DESIGN.md §14): export the demo
/// workload's event log as Chrome-trace JSON (`trace.json`, loadable at
/// ui.perfetto.dev) plus per-replica canonical JSONL
/// (`trace-r{i}.jsonl`).  Replays print bit-identical digests.
fn cmd_trace(cfg: &Config) -> Result<()> {
    let router = drive_traced_session_demo(cfg)?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    let chrome = router.chrome_trace();
    std::fs::write(cfg.out_dir.join("trace.json"), &chrome)?;
    for (i, e) in router.replicas().iter().enumerate() {
        std::fs::write(
            cfg.out_dir.join(format!("trace-r{i}.jsonl")),
            e.trace.to_jsonl(),
        )?;
        println!(
            "[trace] replica {i}: {} events | digest {:#018x} | level {}",
            e.trace.total(),
            e.trace.digest(),
            e.trace.level()
        );
    }
    println!(
        "[trace] wrote {}/trace.json ({} bytes) — load at ui.perfetto.dev \
         or chrome://tracing — and per-replica trace-r*.jsonl",
        cfg.out_dir.display(),
        chrome.len()
    );
    Ok(())
}

/// Modeled-time profile of the demo workload (DESIGN.md §15): fold each
/// replica's flight-recorder stream through the canonical `gpusim`
/// price table and export per-request phase attribution
/// (`profile.md`) plus a Chrome trace whose `ts`/`dur` are modeled
/// microseconds (`profile.json`, loadable at ui.perfetto.dev).  The
/// conservation checks (`repro profile-identity`) run inline, and the
/// integer-only digest is replay-stable.
fn cmd_profile(cfg: &Config) -> Result<()> {
    use flashsampling::profile::{profile_tracks, slo_violations, PriceTable};
    let router = drive_traced_session_demo(cfg)?;
    let tracks: Vec<(usize, &flashsampling::trace::Trace)> = router
        .replicas()
        .iter()
        .enumerate()
        .map(|(i, e)| (i, &e.trace))
        .collect();
    let profile = profile_tracks(&tracks, &PriceTable::canonical())?;
    profile.check().context("profile conservation check")?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    let chrome = profile.chrome_json();
    std::fs::write(cfg.out_dir.join("profile.json"), &chrome)?;
    let md = profile.to_markdown();
    std::fs::write(cfg.out_dir.join("profile.md"), &md)?;
    print!("{md}");
    if cfg.slo_ttft_ms > 0 || cfg.slo_itl_ms > 0 {
        let (ttft, itl) = slo_violations(
            &profile,
            cfg.slo_ttft_ms * 1000,
            cfg.slo_itl_ms * 1000,
        );
        println!(
            "[profile] modeled SLO violations: ttft {ttft} (> {} ms) | \
             itl {itl} (> {} ms)",
            cfg.slo_ttft_ms, cfg.slo_itl_ms
        );
    }
    println!(
        "[profile] wrote {}/profile.json ({} bytes, modeled-µs Chrome \
         trace — load at ui.perfetto.dev) and profile.md",
        cfg.out_dir.display(),
        chrome.len()
    );
    Ok(())
}

/// Perf-regression gate: compare two `BENCH_*.json` reports in the
/// shared provenance-stamped schema and exit nonzero on any metric
/// regressing beyond the noise band (DESIGN.md §15).
fn cmd_benchdiff(args: &[String]) -> Result<()> {
    use flashsampling::profile::benchdiff::{diff_reports, DEFAULT_TOLERANCE};
    let mut tolerance = DEFAULT_TOLERANCE;
    let mut files = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--tolerance" => {
                tolerance = args
                    .get(i + 1)
                    .context("--tolerance needs a fraction (e.g. 0.05)")?
                    .parse()?;
                i += 2;
            }
            other if other.starts_with("--") => bail!("unknown flag {other}"),
            f => {
                files.push(f.to_string());
                i += 1;
            }
        }
    }
    let [old_path, new_path] = files.as_slice() else {
        bail!("usage: flashsampling benchdiff OLD.json NEW.json [--tolerance F]");
    };
    let old = std::fs::read_to_string(old_path)
        .with_context(|| format!("reading {old_path}"))?;
    let new = std::fs::read_to_string(new_path)
        .with_context(|| format!("reading {new_path}"))?;
    let diff = diff_reports(&old, &new, tolerance)?;
    print!("{}", diff.to_markdown(tolerance));
    if diff.is_regression() {
        bail!(
            "benchdiff: {} regression(s) beyond the ±{:.1}% band",
            diff.regressions.len(),
            tolerance * 100.0
        );
    }
    Ok(())
}

/// A/B the fused vs baseline LM-head artifacts through PJRT with wall-clock
/// timing (the measurable half of the paper's microbenchmarks; the modeled
/// half lives in `repro`).
fn cmd_bench_kernel(cfg: &Config) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    let key = Key::from_seed(cfg.seed);
    println!("| artifact | B | D | V | median µs over 30 reps |");
    println!("|---|---|---|---|---|");
    let mut specs: Vec<_> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| {
            matches!(a.kind.as_str(),
                "flash_sample" | "baseline_multinomial" | "baseline_gumbel")
        })
        .cloned()
        .collect();
    specs.sort_by(|a, b| a.name.cmp(&b.name));
    for spec in specs {
        let b = spec.meta_usize("B")?;
        let d = spec.meta_usize("D")?;
        let v = spec.meta_usize("V")?;
        let h = Tensor::F32(vec![0.1; b * d], vec![b, d]);
        let w = Tensor::F32(vec![0.01; v * d], vec![v, d]);
        // tau: [B] (ABI v2) — uniform here, per-row in the engine.
        let inputs = [h, w, Tensor::seed(key), Tensor::scalar_u32(0),
                      Tensor::F32(vec![cfg.temperature; b], vec![b])];
        // warmup
        for _ in 0..3 {
            rt.run(&spec.name, &inputs)?;
        }
        let mut times: Vec<f64> = (0..30)
            .map(|_| {
                rt.run_timed(&spec.name, &inputs)
                    .map(|(_, dt)| dt.as_secs_f64() * 1e6)
            })
            .collect::<Result<_>>()?;
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!("| {} | {b} | {d} | {v} | {:.0} |", spec.name, times[times.len() / 2]);
    }
    Ok(())
}

fn cmd_selfcheck(cfg: &Config) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    println!(
        "[selfcheck] platform {} | {} artifacts | {} weight tensors",
        rt.platform(),
        rt.manifest().artifacts.len(),
        rt.manifest().weights.len()
    );
    let m = &rt.manifest().model;
    println!(
        "[selfcheck] model: vocab={} d={} layers={} heads={} max_seq={}",
        m.vocab, m.d_model, m.n_layers, m.n_heads, m.max_seq
    );
    // Compile + run one fused sampler and verify against the Rust oracle.
    let spec = rt
        .manifest()
        .by_kind("flash_sample")
        .first()
        .context("no flash_sample artifact")?
        .name
        .clone();
    let a = rt.manifest().find(&spec)?.clone();
    let (b, d, v) = (
        a.meta_usize("B")?,
        a.meta_usize("D")?,
        a.meta_usize("V")?,
    );
    let h: Vec<f32> = (0..b * d).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
    let w: Vec<f32> = (0..v * d).map(|i| ((i % 17) as f32 - 8.0) * 0.01).collect();
    let key = Key::from_seed(cfg.seed);
    let out = rt.run(
        &spec,
        &[
            Tensor::F32(h.clone(), vec![b, d]),
            Tensor::F32(w.clone(), vec![v, d]),
            Tensor::seed(key),
            Tensor::scalar_u32(0),
            Tensor::F32(vec![1.0; b], vec![b]),
        ],
    )?;
    let got = out[0].as_i32()?;
    // Native oracle.
    let mut logits = vec![0.0f32; b * v];
    for bi in 0..b {
        for vi in 0..v {
            let mut acc = 0.0;
            for di in 0..d {
                acc += h[bi * d + di] * w[vi * d + di];
            }
            logits[bi * v + vi] = acc;
        }
    }
    let expect = flashsampling::sampling::gumbel::sample_batch(
        &logits,
        v,
        &flashsampling::sampling::Transform::default(),
        key,
        0,
    );
    for (bi, e) in expect.iter().enumerate() {
        anyhow::ensure!(
            got[bi] as u32 == e.unwrap().index,
            "selfcheck MISMATCH at row {bi}"
        );
    }
    println!("[selfcheck] {spec}: fused XLA kernel == native Gumbel-Max OK");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    // benchdiff takes two file paths (not config overrides) — parse its
    // args directly.
    if cmd == "benchdiff" {
        return cmd_benchdiff(&args[1..]);
    }
    let (cfg, positional) = parse_overrides(&args[1..])?;
    match cmd.as_str() {
        "serve" => cmd_serve(&cfg),
        "repro" => {
            let what = positional.first().map(|s| s.as_str()).unwrap_or("all");
            cmd_repro(&cfg, what)
        }
        "trace" => cmd_trace(&cfg),
        "profile" => cmd_profile(&cfg),
        "bench-kernel" => cmd_bench_kernel(&cfg),
        "selfcheck" => cmd_selfcheck(&cfg),
        _ => usage(),
    }
}
