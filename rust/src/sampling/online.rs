//! Online (streaming) Group-Gumbel-Max (paper Algorithm I.3, Lemma D.3).
//!
//! Streams groups one at a time keeping O(group) working memory: a running
//! log-mass and a running sample.  Each new nonzero-mass group replaces the
//! running sample with probability exp(L_k - L_new) — the binary merge rule,
//! exact by induction (Theorem D.4).

use super::grouped::GroupSummary;
use super::philox::{self, Key};
use super::{log_add_exp, Draw, ExactSampler, RowCtx, Transform};

/// Running state of the online sampler: (L_run, z) of Algorithm I.3.
#[derive(Clone, Copy, Debug)]
pub struct OnlineState {
    /// Running log-mass of everything streamed so far.
    pub log_mass: f32,
    /// Current sample (global vocab index), exact for the streamed prefix.
    pub sample: u32,
    groups_seen: u32,
}

impl OnlineState {
    /// Initialize from the first nonzero-mass group.
    pub fn new(first: GroupSummary) -> Self {
        Self {
            log_mass: first.log_mass,
            sample: first.local_sample,
            groups_seen: 1,
        }
    }

    /// Merge the next group (Alg. I.3 lines 8-15).
    ///
    /// The replace-Bernoulli consumes the GROUP_SELECT stream at counter
    /// i = `group_index`, so merges are reproducible and independent of the
    /// Gumbels used inside groups.
    pub fn merge(
        &mut self,
        next: GroupSummary,
        group_index: u32,
        key: Key,
        row: u32,
        step: u32,
    ) {
        let l_new = log_add_exp(self.log_mass, next.log_mass);
        // p_replace = exp(L_k - L_new) = 1 / (1 + exp(L_run - L_k))
        let p_replace = (next.log_mass - l_new).exp();
        let u = philox::uniform_at(
            key,
            group_index,
            row,
            philox::STREAM_GROUP_SELECT,
            step,
        );
        if u < p_replace {
            self.sample = next.local_sample;
        }
        self.log_mass = l_new;
        self.groups_seen += 1;
    }

    /// Number of groups merged so far.
    pub fn groups_seen(&self) -> u32 {
        self.groups_seen
    }
}

/// Full Algorithm I.3 over one row: stream `group_size` chunks.
///
/// Returns (sample, log_Z).  Working memory is O(group_size) — the whole
/// point of the online variant ("when memory is the primary constraint").
pub fn sample_row(
    logits: &[f32],
    group_size: usize,
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
) -> Option<(u32, f32)> {
    assert!(group_size > 0);
    let mut state: Option<OnlineState> = None;
    for (k, chunk) in logits.chunks(group_size).enumerate() {
        let base = k * group_size;
        let Some(summary) =
            super::grouped::group_summary(chunk, base, transform, key, row, step)
        else {
            continue; // zero-mass group: skip (§D.1)
        };
        match &mut state {
            None => state = Some(OnlineState::new(summary)),
            Some(s) => s.merge(summary, k as u32, key, row, step),
        }
    }
    state.map(|s| (s.sample, s.log_mass))
}

/// [`ExactSampler`] adapter over Algorithm I.3 — registry name `online`.
/// Spec example: `"online:group=64"`.
#[derive(Clone, Copy, Debug)]
pub struct OnlineSampler {
    /// Vocabulary positions streamed per group (the working-set bound).
    pub group_size: usize,
}

impl Default for OnlineSampler {
    fn default() -> Self {
        Self { group_size: super::grouped::DEFAULT_GROUP }
    }
}

impl ExactSampler for OnlineSampler {
    fn name(&self) -> &'static str {
        "online"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        sample_row(
            logits,
            self.group_size,
            ctx.transform,
            ctx.key,
            ctx.row,
            ctx.step,
        )
        .map(|(index, log_z)| Draw { index, log_z: Some(log_z) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::log_sum_exp;
    use crate::testutil;

    fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
        let key = Key::from_seed(seed ^ 0x0411_13E5);
        (0..n)
            .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect()
    }

    #[test]
    fn running_mass_is_exact() {
        let l = toy_logits(200, 1);
        let t = Transform::default();
        let (_, lz) = sample_row(&l, 33, &t, Key::new(2, 3), 0, 0).unwrap();
        assert!((lz - log_sum_exp(&l)).abs() < 1e-4);
    }

    #[test]
    fn zero_mass_groups_skipped_mid_stream() {
        let l = vec![0.0f32; 96];
        let mut bias = vec![0.0f32; 96];
        for b in bias[32..64].iter_mut() {
            *b = f32::NEG_INFINITY; // middle group dead
        }
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        for step in 0..30 {
            let (s, _) = sample_row(&l, 32, &t, Key::new(6, 6), 0, step).unwrap();
            assert!(!(32..64).contains(&(s as usize)));
        }
    }

    #[test]
    fn chi_squared_distribution_exact() {
        let v = 48;
        let l = toy_logits(v, 9);
        let t = Transform::default();
        let p = super::super::multinomial::probs(&l, &t);
        let n = 40_000u32;
        let mut counts = vec![0u64; v];
        let key = Key::new(0x11, 0x22);
        for step in 0..n {
            let (s, _) = sample_row(&l, 16, &t, key, 0, step).unwrap();
            counts[s as usize] += 1;
        }
        let pval = super::super::stats::chi_squared_pvalue(&counts, &p, n as u64);
        assert!(pval > 1e-3, "Alg I.3 GoF rejected: p={pval}");
    }

    /// Degenerate inputs: an empty stream has no groups to initialize the
    /// state from, and an all-masked stream skips every group — both `None`.
    #[test]
    fn empty_and_all_masked_streams_are_none() {
        let t = Transform::default();
        assert_eq!(sample_row(&[], 8, &t, Key::new(1, 1), 0, 0), None);
        let l = vec![0.0f32; 48];
        let masked = Transform {
            temperature: 1.0,
            bias: Some(vec![f32::NEG_INFINITY; 48]),
        };
        assert_eq!(sample_row(&l, 16, &masked, Key::new(1, 1), 0, 0), None);
    }

    /// A zero-mass *leading* group must not initialize the running state:
    /// the stream starts at the first live group and stays exact.
    #[test]
    fn zero_mass_leading_group_skipped() {
        let l = vec![0.0f32; 96];
        let mut bias = vec![0.0f32; 96];
        for b in bias[..32].iter_mut() {
            *b = f32::NEG_INFINITY; // first group dead
        }
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        for step in 0..30 {
            let (s, lz) = sample_row(&l, 32, &t, Key::new(8, 8), 0, step).unwrap();
            assert!((32..96).contains(&(s as usize)), "step {step}: {s}");
            assert!((lz - log_sum_exp(&l[32..])).abs() < 1e-4);
        }
    }

    /// The trait adapter draws from the same Philox streams as the module
    /// function (pathwise identity across the `ExactSampler` boundary).
    #[test]
    fn trait_adapter_matches_module_fn() {
        let l = toy_logits(180, 6);
        let t = Transform::default();
        let key = Key::new(21, 22);
        let s = OnlineSampler { group_size: 40 };
        for step in 0..20 {
            let ctx = RowCtx { transform: &t, key, row: 1, step };
            let via_trait = s.sample_row(&l, ctx).unwrap();
            let (idx, lz) = sample_row(&l, 40, &t, key, 1, step).unwrap();
            assert_eq!(via_trait.index, idx);
            assert_eq!(via_trait.log_z, Some(lz));
        }
    }

    #[test]
    fn merge_probability_extremes() {
        // A group with -inf mass never replaces; an overwhelming one always.
        let mut st = OnlineState::new(GroupSummary { local_sample: 1, log_mass: 0.0 });
        st.merge(
            GroupSummary { local_sample: 99, log_mass: f32::NEG_INFINITY },
            1, Key::new(0, 0), 0, 0,
        );
        assert_eq!(st.sample, 1);
        st.merge(
            GroupSummary { local_sample: 42, log_mass: 60.0 },
            2, Key::new(0, 0), 0, 0,
        );
        assert_eq!(st.sample, 42); // p_replace ≈ 1 - e^-60
    }

    /// log_Z bookkeeping is exact for any grouping/stream order.
    #[test]
    fn prop_mass_bookkeeping_invariant() {
        testutil::cases(96, 0x71, |g| {
            let n = g.usize_in(1, 256);
            let gs = g.usize_in(1, 50);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let (_, lz) = sample_row(&l, gs, &t, Key::from_seed(seed), 0, 0).unwrap();
            assert!((lz - log_sum_exp(&l)).abs() < 1e-3);
        });
    }

    /// groups_seen counts exactly the streamed groups.
    #[test]
    fn prop_groups_seen_counts() {
        testutil::cases(64, 0x72, |g| {
            let n = g.usize_in(1, 200);
            let gs = g.usize_in(1, 64);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let key = Key::from_seed(seed);
            let mut state: Option<OnlineState> = None;
            for (k, chunk) in l.chunks(gs).enumerate() {
                if let Some(s) = super::super::grouped::group_summary(
                    chunk, k * gs, &t, key, 0, 0,
                ) {
                    match &mut state {
                        None => state = Some(OnlineState::new(s)),
                        Some(st) => st.merge(s, k as u32, key, 0, 0),
                    }
                }
            }
            assert_eq!(
                state.unwrap().groups_seen() as usize,
                l.chunks(gs).count()
            );
        });
    }
}
