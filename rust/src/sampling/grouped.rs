//! Parallel Group-Gumbel-Max (paper Algorithm I.2, Lemmas D.1-D.2).
//!
//! Partition the vocabulary into groups; each group yields an exact local
//! sample plus its log-mass L_k = logsumexp(group logits); an outer
//! Gumbel-Max over {L_k} (fresh Gumbels, max-stability) picks the winning
//! group.  Exact in distribution by hierarchical factorization.

use super::philox::{self, Key};
use super::{log_sum_exp, Draw, ExactSampler, RowCtx, Transform};

/// Default group size of the registry's `grouped`/`online` specs — matches
/// the fused kernel's vocabulary tile (`gpusim::kernelchain::FUSED_TILE_V`).
pub const DEFAULT_GROUP: usize = 2048;

/// Per-group summary: what each "threadblock" (or rank) reports upward.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GroupSummary {
    /// Exact local sample, as a *global* vocabulary index.
    pub local_sample: u32,
    /// Group log-mass L_k = logsumexp over the group's transformed logits.
    pub log_mass: f32,
}

/// Compute one group's summary (lines 2-4 of Alg. I.2).
///
/// `base` is the group's starting global vocab index; Gumbel positions are
/// global so local samples are reproducible across regroupings.
/// Returns `None` for a zero-mass group (skipped per §D.1).
pub fn group_summary(
    logits: &[f32],
    base: usize,
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
) -> Option<GroupSummary> {
    let mut best = f32::NEG_INFINITY;
    let mut best_i: i64 = -1;
    let mut transformed = Vec::with_capacity(logits.len());
    for (j, &l) in logits.iter().enumerate() {
        let i = base + j;
        let y = transform.apply(l, i);
        transformed.push(y);
        if y == f32::NEG_INFINITY {
            continue;
        }
        let s = y + philox::gumbel_at(key, i as u32, row, step);
        if s > best {
            best = s;
            best_i = i as i64;
        }
    }
    (best_i >= 0).then(|| GroupSummary {
        local_sample: best_i as u32,
        log_mass: log_sum_exp(&transformed),
    })
}

/// Outer selection (lines 6-7): Gumbel-Max over group log-masses with fresh
/// Gumbels on the GROUP_SELECT stream, counter = group index `k`.
///
/// `summaries` are (group index, summary) pairs for nonzero-mass groups.
pub fn select_group(
    summaries: &[(u32, GroupSummary)],
    key: Key,
    row: u32,
    step: u32,
) -> Option<(u32, GroupSummary)> {
    summaries
        .iter()
        .map(|&(k, s)| {
            let g = philox::gumbel_group_select(key, k, row, step);
            (s.log_mass + g, k, s)
        })
        .reduce(|a, b| if b.0 > a.0 { b } else { a })
        .map(|(_, k, s)| (k, s))
}

/// Full Algorithm I.2 over one row: group, summarize, select.
///
/// Returns (sample, log_Z) — log_Z is the optional log-normalizer output
/// (Appendix L), free as a byproduct of the group masses.
pub fn sample_row(
    logits: &[f32],
    group_size: usize,
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
) -> Option<(u32, f32)> {
    assert!(group_size > 0);
    let mut summaries = Vec::with_capacity(logits.len().div_ceil(group_size));
    for (k, chunk) in logits.chunks(group_size).enumerate() {
        if let Some(s) =
            group_summary(chunk, k * group_size, transform, key, row, step)
        {
            summaries.push((k as u32, s));
        }
    }
    let masses: Vec<f32> = summaries.iter().map(|(_, s)| s.log_mass).collect();
    let log_z = log_sum_exp(&masses);
    select_group(&summaries, key, row, step).map(|(_, s)| (s.local_sample, log_z))
}

/// [`ExactSampler`] adapter over Algorithm I.2 — registry name `grouped`.
/// Spec example: `"grouped:group=64"`.
#[derive(Clone, Copy, Debug)]
pub struct GroupedSampler {
    /// Vocabulary positions per group (the "threadblock" width).
    pub group_size: usize,
}

impl Default for GroupedSampler {
    fn default() -> Self {
        Self { group_size: DEFAULT_GROUP }
    }
}

impl ExactSampler for GroupedSampler {
    fn name(&self) -> &'static str {
        "grouped"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        sample_row(
            logits,
            self.group_size,
            ctx.transform,
            ctx.key,
            ctx.row,
            ctx.step,
        )
        .map(|(index, log_z)| Draw { index, log_z: Some(log_z) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
        let key = Key::from_seed(seed ^ 0x5EED);
        (0..n)
            .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect()
    }

    #[test]
    fn log_z_is_grouping_invariant() {
        let l = toy_logits(256, 7);
        let t = Transform::default();
        let key = Key::new(1, 2);
        let reference = log_sum_exp(&l);
        for gs in [1usize, 8, 17, 64, 256, 999] {
            let (_, lz) = sample_row(&l, gs, &t, key, 0, 0).unwrap();
            assert!(
                (lz - reference).abs() < 1e-4,
                "gs={gs}: {lz} vs {reference}"
            );
        }
    }

    #[test]
    fn zero_mass_groups_are_skipped() {
        let l = vec![0.0f32; 64];
        let mut bias = vec![f32::NEG_INFINITY; 64];
        for i in 0..16 {
            bias[i] = 0.0; // only group 0 alive (group_size 16)
        }
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        for step in 0..30 {
            let (s, _) = sample_row(&l, 16, &t, Key::new(4, 4), 0, step).unwrap();
            assert!((s as usize) < 16);
        }
    }

    #[test]
    fn all_zero_mass_returns_none() {
        let l = vec![0.0f32; 32];
        let t = Transform { temperature: 1.0, bias: Some(vec![f32::NEG_INFINITY; 32]) };
        assert!(sample_row(&l, 8, &t, Key::new(1, 1), 0, 0).is_none());
    }

    /// Degenerate inputs: an empty row has no groups at all (not even a
    /// zero-mass one) and must sample to `None` without panicking; an empty
    /// group summary is likewise `None`.
    #[test]
    fn empty_row_and_empty_group_are_none() {
        let t = Transform::default();
        assert_eq!(sample_row(&[], 8, &t, Key::new(1, 1), 0, 0), None);
        assert_eq!(group_summary(&[], 0, &t, Key::new(1, 1), 0, 0), None);
        assert_eq!(select_group(&[], Key::new(1, 1), 0, 0), None);
    }

    /// A zero-mass group yields no summary, and its log-mass never enters
    /// log_Z: masking half the vocabulary leaves log_Z equal to the live
    /// half's logsumexp exactly.
    #[test]
    fn zero_mass_groups_excluded_from_log_z() {
        let l = toy_logits(64, 3);
        let mut bias = vec![0.0f32; 64];
        for b in bias[32..].iter_mut() {
            *b = f32::NEG_INFINITY;
        }
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        let (_, lz) = sample_row(&l, 16, &t, Key::new(2, 2), 0, 0).unwrap();
        assert!((lz - log_sum_exp(&l[..32])).abs() < 1e-4);
    }

    /// The trait adapter draws from the same Philox streams as the module
    /// function (pathwise identity across the `ExactSampler` boundary).
    #[test]
    fn trait_adapter_matches_module_fn() {
        let l = toy_logits(200, 5);
        let t = Transform::default();
        let key = Key::new(11, 12);
        let s = GroupedSampler { group_size: 48 };
        for step in 0..20 {
            let ctx = RowCtx { transform: &t, key, row: 3, step };
            let via_trait = s.sample_row(&l, ctx).unwrap();
            let (idx, lz) = sample_row(&l, 48, &t, key, 3, step).unwrap();
            assert_eq!(via_trait.index, idx);
            assert_eq!(via_trait.log_z, Some(lz));
        }
    }

    #[test]
    fn peaked_group_always_wins() {
        let mut l = vec![-20.0f32; 128];
        l[70] = 20.0;
        let t = Transform::default();
        for step in 0..40 {
            let (s, _) = sample_row(&l, 32, &t, Key::new(9, 1), 0, step).unwrap();
            assert_eq!(s, 70);
        }
    }

    /// Chi-squared GoF for Alg. I.2 against exact probabilities — the Rust
    /// half of the paper's §4.6 kernel-level verification.
    #[test]
    fn distribution_is_exact_chi_squared() {
        let v = 64;
        let l = toy_logits(v, 42);
        let t = Transform::default();
        let p = super::super::multinomial::probs(&l, &t);
        let n = 40_000u32;
        let mut counts = vec![0u64; v];
        let key = Key::new(0xAA, 0xBB);
        for step in 0..n {
            let (s, _) = sample_row(&l, 16, &t, key, 0, step).unwrap();
            counts[s as usize] += 1;
        }
        let pval = super::super::stats::chi_squared_pvalue(&counts, &p, n as u64);
        assert!(pval > 1e-3, "Alg I.2 GoF rejected: p={pval}");
    }

    /// Group-size invariance of log_Z (Lemma D.1 factorization).
    #[test]
    fn prop_log_z_invariant() {
        testutil::cases(96, 0x61, |g| {
            let n = g.usize_in(1, 200);
            let gs = g.usize_in(1, 64);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let (_, lz) = sample_row(&l, gs, &t, Key::from_seed(seed), 0, 0).unwrap();
            assert!((lz - log_sum_exp(&l)).abs() < 1e-3);
        });
    }

    /// Samples always land in a nonzero-mass category.
    #[test]
    fn prop_sample_in_support() {
        testutil::cases(96, 0x62, |g| {
            let n = g.usize_in(2, 128);
            let gs = g.usize_in(1, 40);
            let seed = g.u64();
            let lo = g.usize_in(0, 64).min(n - 1);
            let l = toy_logits(n, seed);
            let mut bias = vec![0.0f32; n];
            for b in bias.iter_mut().take(lo) {
                *b = f32::NEG_INFINITY;
            }
            let t = Transform { temperature: 1.0, bias: Some(bias) };
            let (s, _) = sample_row(&l, gs, &t, Key::from_seed(seed), 0, 1).unwrap();
            assert!((s as usize) >= lo && (s as usize) < n);
        });
    }
}
