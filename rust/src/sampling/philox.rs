//! Philox4x32-10 counter-based RNG — bit-exact mirror of
//! `python/compile/philox.py`.
//!
//! FlashSampling's exactness contract requires every Gumbel variate to be a
//! deterministic function of (seed, logical position): the Pallas kernel,
//! the pure-jnp oracle, and the Rust samplers in this module all draw from
//! the *same* streams, so a Rust-side Gumbel-Max over materialized logits is
//! pathwise identical to the fused kernel's output.  The shared counter
//! layout is
//!
//! ```text
//!   ctr = (i, b, stream, step)      key = (seed_lo, seed_hi)
//! ```
//!
//! with `stream` a domain separator (Gumbel epilogue / baseline row uniforms
//! / outer group selection).  Known-answer vectors from the Random123
//! distribution pin both implementations to the published algorithm.

/// Round multiplier M0 (Salmon et al., SC'11).
const PHILOX_M0: u32 = 0xD251_1F53;
/// Round multiplier M1.
const PHILOX_M1: u32 = 0xCD9E_8D57;
/// Key bump W0 (golden ratio).
const PHILOX_W0: u32 = 0x9E37_79B9;
/// Key bump W1 (sqrt(3) - 1).
const PHILOX_W1: u32 = 0xBB67_AE85;

/// Stream id of the Gumbel epilogue draws (must match `philox.py`).
pub const STREAM_GUMBEL: u32 = 0;
/// Stream id of the baseline sampler's per-row uniforms.
pub const STREAM_ROW_UNIFORM: u32 = 1;
/// Stream id of the grouped/distributed outer selection draws.
pub const STREAM_GROUP_SELECT: u32 = 2;
/// Stream id of the speculative-decode accept/reject uniforms (counter
/// `i` = draft position, so one verify round consumes at most K uniforms
/// at `(0..K, row, step)` — see `crate::specdec::verify`).
pub const STREAM_SPEC_ACCEPT: u32 = 16;
/// Base stream id of a speculative drafter's own Gumbel draws: draft
/// position `j` draws its vocab-indexed Gumbels on stream
/// `STREAM_SPEC_DRAFT + j`.  Keeping the drafter on its own stream family
/// makes the proposal independent of the verifier's accept uniforms AND of
/// the target's own [`STREAM_GUMBEL`] epilogue draws at the same
/// `(row, step)` — the independence the Chen et al. accept/reject proof
/// requires.
pub const STREAM_SPEC_DRAFT: u32 = 32;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

#[inline(always)]
fn round(c: [u32; 4], k: [u32; 2]) -> [u32; 4] {
    let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
    let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
    [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0]
}

/// Philox4x32: 128-bit counter + 64-bit key -> 128 random bits.
#[inline]
pub fn philox4x32(mut ctr: [u32; 4], mut key: [u32; 2], rounds: u32) -> [u32; 4] {
    for r in 0..rounds {
        ctr = round(ctr, key);
        if r + 1 < rounds {
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
    }
    ctr
}

/// The default 10-round variant used everywhere in this crate.
#[inline]
pub fn philox4x32_10(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    philox4x32(ctr, key, 10)
}

/// Map a u32 word to the open interval (0, 1).
///
/// Identical to `philox.uniform_open01`: top-23-bit mapping
/// `u = (r >> 9 + 0.5) * 2^-23`.  `(r >> 9) + 0.5` needs at most 24
/// mantissa bits so it is exactly representable in f32, confining u to
/// `[2^-24, 1 - 2^-24]` — never 0 or 1, so the Gumbel transform is finite
/// (paper Appendix J's stability requirement).
#[inline(always)]
pub fn uniform_open01(x0: u32) -> f32 {
    ((x0 >> 9) as f32 + 0.5) * (1.0 / 8_388_608.0)
}

/// RNG key (the `seed` input of every artifact).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Key {
    pub lo: u32,
    pub hi: u32,
}

impl Key {
    pub fn new(lo: u32, hi: u32) -> Self {
        Self { lo, hi }
    }

    /// Derive a key from a u64 seed.
    pub fn from_seed(seed: u64) -> Self {
        Self { lo: seed as u32, hi: (seed >> 32) as u32 }
    }

    #[inline(always)]
    fn words(self) -> [u32; 2] {
        [self.lo, self.hi]
    }
}

/// Uniform(0,1) draw at logical position (b, i) on `stream` at decode `step`.
#[inline]
pub fn uniform_at(key: Key, i: u32, b: u32, stream: u32, step: u32) -> f32 {
    uniform_open01(philox4x32_10([i, b, stream, step], key.words())[0])
}

/// Standard Gumbel(0,1) draw at logical position (b, i) at decode `step`.
///
/// Exact-math mode (paper Appendix J): plain `ln`, FP32 like the kernel.
#[inline]
pub fn gumbel_at(key: Key, i: u32, b: u32, step: u32) -> f32 {
    let u = uniform_at(key, i, b, STREAM_GUMBEL, step);
    -(-(u.ln())).ln()
}

/// Fill `out[j] = Gumbel at position (b, start_i + j)` — the hot-row
/// generator.  Semantically identical to calling [`gumbel_at`] per element
/// (same counters, same stream), but processes a lane-group per iteration
/// so the compiler can keep four independent Philox pipelines in flight
/// (the 10 rounds of one counter are serial; across counters they are
/// embarrassingly parallel).  ~2.3x faster than the scalar loop on this
/// testbed (EXPERIMENTS.md §Perf L3).
pub fn gumbel_row(key: Key, b: u32, step: u32, start_i: u32, out: &mut [f32]) {
    const LANES: usize = 8;
    let kw = key.words();
    let mut j = 0;
    while j + LANES <= out.len() {
        let mut x0 = [0u32; LANES];
        for l in 0..LANES {
            let i = start_i + (j + l) as u32;
            x0[l] = philox4x32_10([i, b, STREAM_GUMBEL, step], kw)[0];
        }
        for l in 0..LANES {
            let u = uniform_open01(x0[l]);
            out[j + l] = -(-(u.ln())).ln();
        }
        j += LANES;
    }
    for (l, o) in out.iter_mut().enumerate().skip(j) {
        *o = gumbel_at(key, start_i + l as u32, b, step);
    }
}

/// Fast-math Gumbel (paper Appendix J "fast-math mode"): replaces the two
/// `ln` calls with a polynomial log2 approximation (|rel err| < 2e-5 over
/// the generated range).  Sampling stays algorithmically exact with respect
/// to the generated Gumbels; the approximation introduces a small numeric
/// distortion that `tests::fast_math_bias_negligible` bounds empirically —
/// the appendix's validation requirement.
#[inline]
pub fn gumbel_at_fast(key: Key, i: u32, b: u32, step: u32) -> f32 {
    let u = uniform_at(key, i, b, STREAM_GUMBEL, step);
    -(-fast_ln(u)).max(1e-38).ln_fast()
}

/// Fast ln approximation: exponent/mantissa decomposition + the atanh
/// series ln(m) = 2(s + s^3/3 + s^5/5 + s^7/7) with s = (m-1)/(m+1).
/// |s| <= 1/3 on [1, 2), so the truncation error is < 1.2e-5 absolute —
/// well inside the Appendix-J "negligible bias" budget.
#[inline(always)]
pub fn fast_ln(x: f32) -> f32 {
    let bits = x.to_bits();
    let e = ((bits >> 23) as i32 - 127) as f32;
    let m = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000); // [1, 2)
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    let ln_m = 2.0 * s * (1.0 + s2 * (1.0 / 3.0 + s2 * (0.2 + s2 / 7.0)));
    ln_m + e * core::f32::consts::LN_2
}

trait FastLn {
    fn ln_fast(self) -> f32;
}

impl FastLn for f32 {
    #[inline(always)]
    fn ln_fast(self) -> f32 {
        fast_ln(self)
    }
}

/// Gumbel draw on the outer group/rank-selection stream (Lemma D.1 reuse of
/// max-stability needs *fresh independent* Gumbels for the outer choice).
#[inline]
pub fn gumbel_group_select(key: Key, k: u32, b: u32, step: u32) -> f32 {
    let u = uniform_at(key, k, b, STREAM_GROUP_SELECT, step);
    -(-(u.ln())).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random123 kat_vectors: philox4x32x10.
    #[test]
    fn known_answer_vectors() {
        assert_eq!(
            philox4x32_10([0, 0, 0, 0], [0, 0]),
            [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
        );
        assert_eq!(
            philox4x32_10([u32::MAX; 4], [u32::MAX; 2]),
            [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
        );
        assert_eq!(
            philox4x32_10(
                [0x243F_6A88, 0x85A3_08D3, 0x1319_8A2E, 0x0370_7344],
                [0xA409_3822, 0x299F_31D0]
            ),
            [0xD16C_FE09, 0x94FD_CCEB, 0x5001_E420, 0x2412_6EA1]
        );
    }

    #[test]
    fn counter_and_key_sensitivity() {
        let base = philox4x32_10([1, 2, 3, 4], [5, 6]);
        for pos in 0..4 {
            let mut c = [1u32, 2, 3, 4];
            c[pos] ^= 1;
            assert_ne!(philox4x32_10(c, [5, 6]), base);
        }
        assert_ne!(philox4x32_10([1, 2, 3, 4], [5, 7]), base);
        assert_ne!(philox4x32_10([1, 2, 3, 4], [4, 6]), base);
    }

    #[test]
    fn uniform_is_open_interval() {
        assert!(uniform_open01(0) > 0.0);
        assert!(uniform_open01(u32::MAX) < 1.0);
        // Gumbel transform finite at both extremes.
        for r in [0u32, u32::MAX] {
            let u = uniform_open01(r);
            let g = -(-(u.ln())).ln();
            assert!(g.is_finite(), "g({r}) = {g}");
        }
    }

    #[test]
    fn uniform_moments() {
        let n = 200_000u32;
        let key = Key::new(1, 2);
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for i in 0..n {
            let u = uniform_at(key, i, 0, STREAM_GUMBEL, 0) as f64;
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let m2 = sumsq / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((m2 - 1.0 / 3.0).abs() < 0.005, "m2={m2}");
    }

    #[test]
    fn gumbel_moments() {
        let n = 200_000u32;
        let key = Key::new(123, 456);
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for i in 0..n {
            let g = gumbel_at(key, i, 0, 0) as f64;
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5772).abs() < 0.01, "mean={mean}");
        assert!((var - std::f64::consts::PI.powi(2) / 6.0).abs() < 0.03, "var={var}");
    }

    /// Cross-language pinning: values computed by python/compile/philox.py
    /// (jnp implementation) must match bit-for-bit — this is what makes the
    /// Rust samplers pathwise comparable to the Pallas kernel.
    #[test]
    fn cross_language_vectors() {
        let cases: [((u32, u32, u32, u32, u32), f32, f32); 3] = [
            ((0, 0, 0, 0, 0), 0.084_820_26, 0.516_679_1),
            ((5, 3, 7, 123, 456), 2.052_738, 0.814_669_07),
            (
                (151_935, 255, 999, 0xDEAD_BEEF, 0x1234_5678),
                3.063_818_2,
                0.964_546_14,
            ),
        ];
        for ((i, b, step, klo, khi), g_expect, u_expect) in cases {
            let key = Key::new(klo, khi);
            let g = gumbel_at(key, i, b, step);
            let u = uniform_at(key, i, b, STREAM_ROW_UNIFORM, step);
            assert!((g - g_expect).abs() < 1e-6, "gumbel {g} vs {g_expect}");
            assert!((u - u_expect).abs() < 1e-7, "uniform {u} vs {u_expect}");
        }
    }

    #[test]
    fn fast_ln_accuracy() {
        // Relative error of the approximation over the span the Gumbel
        // transform exercises.
        for k in 1..10_000u32 {
            let x = k as f32 / 10_000.0;
            let err = (fast_ln(x) - x.ln()).abs();
            let tol = 5e-5 * x.ln().abs().max(1.0);
            assert!(err < tol, "x={x}: {} vs {}", fast_ln(x), x.ln());
        }
    }

    /// Appendix J: fast-math mode must introduce only negligible sampling
    /// bias.  Compare argmax decisions of exact vs fast Gumbels on random
    /// rows: disagreement should be rare (driven only by ~1e-5 score
    /// perturbations near ties).
    #[test]
    fn fast_math_bias_negligible() {
        let key = Key::new(0xF, 0xA5);
        let mut disagree = 0u32;
        let n_rows = 2_000u32;
        let v = 256u32;
        for step in 0..n_rows {
            let (mut be, mut bi_e) = (f32::NEG_INFINITY, 0u32);
            let (mut bf, mut bi_f) = (f32::NEG_INFINITY, 0u32);
            for i in 0..v {
                // logits from a side stream
                let l = 3.0 * (uniform_at(key, i, 1, 3, step) - 0.5);
                let ge = l + gumbel_at(key, i, 0, step);
                let gf = l + gumbel_at_fast(key, i, 0, step);
                if ge > be {
                    be = ge;
                    bi_e = i;
                }
                if gf > bf {
                    bf = gf;
                    bi_f = i;
                }
            }
            if bi_e != bi_f {
                disagree += 1;
            }
        }
        let rate = disagree as f64 / n_rows as f64;
        assert!(rate < 0.002, "fast-math changed {disagree}/{n_rows} samples");
    }

    #[test]
    fn gumbel_row_matches_scalar() {
        let key = Key::new(3, 14);
        let mut buf = vec![0.0f32; 1003];
        gumbel_row(key, 7, 9, 100, &mut buf);
        for (j, &g) in buf.iter().enumerate() {
            assert_eq!(g, gumbel_at(key, 100 + j as u32, 7, 9), "j={j}");
        }
    }

    #[test]
    fn streams_are_distinct() {
        let key = Key::new(9, 9);
        let a = uniform_at(key, 42, 7, STREAM_GUMBEL, 0);
        let b = uniform_at(key, 42, 7, STREAM_ROW_UNIFORM, 0);
        let c = uniform_at(key, 42, 7, STREAM_GROUP_SELECT, 0);
        assert_ne!(a, b);
        assert_ne!(b, c);
        // The spec-decode streams are disjoint from the sampler streams
        // and from each other across draft positions.
        let d = uniform_at(key, 42, 7, STREAM_SPEC_ACCEPT, 0);
        let e = uniform_at(key, 42, 7, STREAM_SPEC_DRAFT, 0);
        let f = uniform_at(key, 42, 7, STREAM_SPEC_DRAFT + 1, 0);
        assert_ne!(a, d);
        assert_ne!(d, e);
        assert_ne!(e, f);
    }
}
