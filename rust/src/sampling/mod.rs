//! Exact categorical samplers — native Rust mirrors of the paper's
//! algorithms, sharing Philox streams with the Pallas kernel.
//!
//! | Paper | Module | Registry name |
//! |---|---|---|
//! | Alg. I.1 streaming Gumbel-Max | [`gumbel`] | `gumbel` |
//! | Alg. A.1 materialized-logits baseline | [`multinomial`] | `multinomial` |
//! | Alg. I.2 parallel Group-Gumbel-Max | [`grouped`] | `grouped` |
//! | Alg. I.3 online merge (Lemma D.3) | [`online`] | `online` |
//! | Alg. I.4 distributed tensor-parallel merge | [`distributed`] | `distributed` |
//! | Gumbel-Top-k candidate reduction (App. D.6) | [`topk`] | `topk` |
//! | chi-squared GoF + paired bootstrap (§4.6) | [`stats`] | — |
//!
//! These run on the L3 request path (e.g. the TP orchestrator's rank merge)
//! and in tests/benches; the heavy fused path is the AOT Pallas kernel.
//!
//! # The `ExactSampler` trait and registry
//!
//! Every paper sampler is also exposed behind the common [`ExactSampler`]
//! trait, constructed from a **config string** via [`build_sampler`] — the
//! single seam through which the coordinator, the TP orchestrator, the
//! benches, and the repro tables select sampling algorithms (no hard-coded
//! call sites).  Spec grammar:
//!
//! ```text
//!   <name>                      e.g.  "gumbel"
//!   <name>:<k>=<v>[,<k>=<v>]*   e.g.  "grouped:group=64"
//!                                     "topk:k=8,p=0.95,tile=2048"
//! ```
//!
//! Recognised parameters: `tile` ([`gumbel`], [`topk`]), `group`
//! ([`grouped`], [`online`]), `ranks` ([`distributed`]), `k` and `p`
//! ([`topk`]).  Unknown names or parameters are errors, so config typos
//! fail fast.
//!
//! Exactness contract across the trait boundary: a sampler built from a
//! spec draws from exactly the same Philox streams as the underlying
//! module functions, so results are pathwise reproducible from
//! `(spec, seed, row, step)` — asserted by `tests/sampler_trait.rs`.
//!
//! ```
//! use flashsampling::sampling::{
//!     build_sampler, ExactSampler, Key, RowCtx, Transform,
//! };
//!
//! let sampler = build_sampler("grouped:group=4").unwrap();
//! let logits = [0.5f32, -1.0, 2.0, 0.0, 1.5, -0.5, 0.25, 1.0];
//! let t = Transform::default();
//! let ctx = RowCtx { transform: &t, key: Key::from_seed(7), row: 0, step: 0 };
//! let draw = sampler.sample_row(&logits, ctx).unwrap();
//! assert!((draw.index as usize) < logits.len());
//! // Group-structured samplers return log Z for free (Appendix L).
//! assert!(draw.log_z.is_some());
//! ```

pub mod distributed;
pub mod grouped;
pub mod gumbel;
pub mod multinomial;
pub mod online;
pub mod philox;
pub mod stats;
pub mod topk;

use anyhow::{bail, Context, Result};

pub use philox::Key;

/// Numerically stable log(sum(exp(xs))) over a slice.
///
/// Returns `-inf` for empty/all-`-inf` input (a zero-mass group, §D.1):
///
/// ```
/// use flashsampling::sampling::log_sum_exp;
///
/// // Empty slice and all-masked groups both carry zero mass.
/// assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
/// assert_eq!(log_sum_exp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
/// // No overflow at large magnitudes.
/// let z = log_sum_exp(&[1000.0, 1000.0]);
/// assert!((z - (1000.0 + 2f32.ln())).abs() < 1e-3);
/// ```
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return f32::NEG_INFINITY;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// log(e^a + e^b) without overflow; the online merge's running-mass update.
///
/// `-inf` operands act as the additive identity (zero mass), so streaming a
/// dead group leaves the running mass untouched:
///
/// ```
/// use flashsampling::sampling::log_add_exp;
///
/// assert_eq!(log_add_exp(f32::NEG_INFINITY, 2.0), 2.0);
/// assert_eq!(log_add_exp(2.0, f32::NEG_INFINITY), 2.0);
/// assert_eq!(
///     log_add_exp(f32::NEG_INFINITY, f32::NEG_INFINITY),
///     f32::NEG_INFINITY
/// );
/// assert!((log_add_exp(0.0, 0.0) - 2f32.ln()).abs() < 1e-6);
/// ```
pub fn log_add_exp(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Deterministic logit transforms (Alg. 1 line 3): temperature scaling,
/// optional additive bias, `-inf` masking handled via the bias path.
#[derive(Clone, Debug)]
pub struct Transform {
    /// Softmax temperature tau > 0.
    pub temperature: f32,
    /// Optional per-vocab additive bias; `-inf` entries ban tokens.
    pub bias: Option<Vec<f32>>,
}

impl Default for Transform {
    fn default() -> Self {
        Self { temperature: 1.0, bias: None }
    }
}

impl Transform {
    pub fn with_temperature(temperature: f32) -> Self {
        Self { temperature, bias: None }
    }

    /// Apply to one logit at vocab index `i`.
    #[inline(always)]
    pub fn apply(&self, logit: f32, i: usize) -> f32 {
        let mut y = logit / self.temperature;
        if let Some(b) = &self.bias {
            y += b[i];
        }
        y
    }
}

// --- the unified sampler trait -------------------------------------------

/// Per-row sampling context handed across the [`ExactSampler`] boundary.
///
/// Bundles the deterministic inputs of one draw: the logit transform and
/// the Philox coordinates `(key, row, step)`.  Two calls with equal context
/// and equal logits return the identical sample, whatever the algorithm.
#[derive(Clone, Copy, Debug)]
pub struct RowCtx<'a> {
    /// Logit transform (temperature, bias/masking).
    pub transform: &'a Transform,
    /// RNG key (the `seed` input of every artifact).
    pub key: Key,
    /// Batch row index b — selects the Philox stream.
    pub row: u32,
    /// Decode step — fresh noise per scheduler iteration.
    pub step: u32,
}

/// One exact draw plus optional free byproducts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Draw {
    /// Sampled vocabulary index.
    pub index: u32,
    /// log-normalizer log Z when the algorithm computes it as a byproduct
    /// of its group masses (Appendix L); `None` for single-pass samplers.
    pub log_z: Option<f32>,
}

/// A sampler that draws *exactly* from the transformed categorical
/// distribution (or a documented candidate-reduced variant, for
/// [`topk`]), deterministically in the Philox coordinates.
///
/// Implementations are thin adapters over the per-algorithm module
/// functions; construct them by config string through [`build_sampler`].
pub trait ExactSampler: Send + Sync {
    /// Registry name (`"gumbel"`, `"multinomial"`, ...).
    fn name(&self) -> &'static str;

    /// Draw one token from a row of logits.
    ///
    /// Returns `None` when every transformed logit is `-inf` (zero-mass
    /// target distribution — the caller must treat this as an error).
    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw>;

    /// Draw one token per row of a `[B, V]` row-major batch; row `b` uses
    /// Philox stream `b` (so batching never changes any row's sample).
    fn sample_batch(
        &self,
        logits: &[f32],
        vocab: usize,
        transform: &Transform,
        key: Key,
        step: u32,
    ) -> Vec<Option<Draw>> {
        assert!(vocab > 0, "vocab must be positive");
        assert_eq!(logits.len() % vocab, 0);
        logits
            .chunks_exact(vocab)
            .enumerate()
            .map(|(b, row)| {
                self.sample_row(
                    row,
                    RowCtx { transform, key, row: b as u32, step },
                )
            })
            .collect()
    }
}

// --- the name-keyed registry ---------------------------------------------

/// The six paper samplers, in paper order — every name accepted by
/// [`build_sampler`].
pub const SAMPLER_NAMES: [&str; 6] =
    ["gumbel", "multinomial", "grouped", "online", "distributed", "topk"];

/// Key/value parameters parsed from a sampler spec string.
struct SpecParams<'a> {
    spec: &'a str,
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> SpecParams<'a> {
    fn parse(spec: &'a str, params: Option<&'a str>) -> Result<Self> {
        let mut pairs: Vec<(&str, &str)> = Vec::new();
        if let Some(p) = params {
            for item in p.split(',') {
                let (k, v) = item.split_once('=').with_context(|| {
                    format!("sampler spec '{spec}': expected key=value, got '{item}'")
                })?;
                let (k, v) = (k.trim(), v.trim());
                if pairs.iter().any(|(seen, _)| *seen == k) {
                    bail!("sampler spec '{spec}': duplicate parameter '{k}'");
                }
                pairs.push((k, v));
            }
        }
        Ok(Self { spec, pairs })
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.pairs.iter().find(|(k, _)| *k == key) {
            None => Ok(default),
            Some((_, v)) => {
                let n: usize = v.parse().with_context(|| {
                    format!("sampler spec '{}': bad {key}='{v}'", self.spec)
                })?;
                if n == 0 {
                    bail!("sampler spec '{}': {key} must be >= 1", self.spec);
                }
                Ok(n)
            }
        }
    }

    fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.pairs.iter().find(|(k, _)| *k == key) {
            None => Ok(default),
            Some((_, v)) => v.parse().with_context(|| {
                format!("sampler spec '{}': bad {key}='{v}'", self.spec)
            }),
        }
    }

    /// Reject parameters no arm consumed (typo safety).
    fn check_known(&self, known: &[&str]) -> Result<()> {
        for (k, _) in &self.pairs {
            if !known.contains(k) {
                bail!(
                    "sampler spec '{}': unknown parameter '{k}' (known: {})",
                    self.spec,
                    known.join(", ")
                );
            }
        }
        Ok(())
    }
}

/// Build an [`ExactSampler`] from a config string (see the module docs for
/// the grammar).  This is the only constructor the serving stack uses —
/// sampler selection is always data, never code.
pub fn build_sampler(spec: &str) -> Result<Box<dyn ExactSampler>> {
    let spec = spec.trim();
    let (name, params) = match spec.split_once(':') {
        Some((n, p)) => (n.trim(), Some(p)),
        None => (spec, None),
    };
    let p = SpecParams::parse(spec, params)?;
    let sampler: Box<dyn ExactSampler> = match name {
        "gumbel" => {
            p.check_known(&["tile"])?;
            let tile = match p.pairs.iter().any(|(k, _)| *k == "tile") {
                true => Some(p.get_usize("tile", 0)?),
                false => None,
            };
            Box::new(gumbel::GumbelMaxSampler { tile_v: tile })
        }
        "multinomial" => {
            p.check_known(&[])?;
            Box::new(multinomial::MultinomialSampler)
        }
        "grouped" => {
            p.check_known(&["group"])?;
            Box::new(grouped::GroupedSampler {
                group_size: p.get_usize("group", grouped::DEFAULT_GROUP)?,
            })
        }
        "online" => {
            p.check_known(&["group"])?;
            Box::new(online::OnlineSampler {
                group_size: p.get_usize("group", grouped::DEFAULT_GROUP)?,
            })
        }
        "distributed" => {
            p.check_known(&["ranks"])?;
            Box::new(distributed::DistributedSampler {
                n_ranks: p.get_usize("ranks", distributed::DEFAULT_RANKS)?,
            })
        }
        "topk" => {
            p.check_known(&["k", "p", "tile"])?;
            let top_p = p.get_f32("p", 1.0)?;
            if !(top_p > 0.0 && top_p <= 1.0) {
                bail!("sampler spec '{spec}': p must be in (0, 1], got {top_p}");
            }
            Box::new(topk::GumbelTopKSampler {
                k: p.get_usize("k", topk::DEFAULT_K)?,
                top_p,
                tile_v: p.get_usize("tile", topk::DEFAULT_TILE_V)?,
            })
        }
        other => bail!(
            "unknown sampler '{other}' (known: {})",
            SAMPLER_NAMES.join(", ")
        ),
    };
    Ok(sampler)
}

/// One default-configured instance of every registered sampler, in
/// [`SAMPLER_NAMES`] order — the bench/report iteration set.
pub fn default_samplers() -> Vec<Box<dyn ExactSampler>> {
    SAMPLER_NAMES
        .iter()
        .map(|n| build_sampler(n).expect("default sampler specs are valid"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_matches_naive() {
        let xs = [0.1f32, -2.0, 3.5, 1.0];
        let naive: f32 = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn log_sum_exp_handles_extremes() {
        assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
        assert_eq!(log_sum_exp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
        // No overflow at large magnitudes.
        let big = log_sum_exp(&[1000.0, 1000.0]);
        assert!((big - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }

    #[test]
    fn log_add_exp_agrees_with_log_sum_exp() {
        for (a, b) in [(0.0f32, 1.0f32), (-5.0, 3.0), (100.0, 99.0)] {
            assert!((log_add_exp(a, b) - log_sum_exp(&[a, b])).abs() < 1e-5);
        }
        assert_eq!(log_add_exp(f32::NEG_INFINITY, 2.0), 2.0);
        assert_eq!(log_add_exp(2.0, f32::NEG_INFINITY), 2.0);
    }

    #[test]
    fn transform_applies_temperature_and_bias() {
        let t = Transform { temperature: 2.0, bias: Some(vec![0.0, -f32::INFINITY]) };
        assert_eq!(t.apply(4.0, 0), 2.0);
        assert_eq!(t.apply(4.0, 1), f32::NEG_INFINITY);
    }

    #[test]
    fn registry_builds_every_name() {
        for name in SAMPLER_NAMES {
            let s = build_sampler(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert_eq!(default_samplers().len(), SAMPLER_NAMES.len());
    }

    #[test]
    fn registry_parses_parameters() {
        assert!(build_sampler("grouped:group=64").is_ok());
        assert!(build_sampler("online:group=17").is_ok());
        assert!(build_sampler("distributed:ranks=4").is_ok());
        assert!(build_sampler("topk:k=4,p=0.9,tile=128").is_ok());
        assert!(build_sampler("gumbel:tile=2048").is_ok());
        assert!(build_sampler(" gumbel ").is_ok()); // whitespace-tolerant
    }

    #[test]
    fn registry_rejects_bad_specs() {
        assert!(build_sampler("nope").is_err());
        assert!(build_sampler("gumbel:wat=1").is_err()); // unknown param
        assert!(build_sampler("grouped:group=0").is_err()); // zero-sized
        assert!(build_sampler("grouped:group=abc").is_err()); // non-numeric
        assert!(build_sampler("topk:k").is_err()); // missing '='
        assert!(build_sampler("multinomial:x=1").is_err()); // takes none
        assert!(build_sampler("grouped:group=8,group=64").is_err()); // dup
        assert!(build_sampler("topk:p=nan").is_err()); // out-of-range mass
        assert!(build_sampler("topk:p=0").is_err());
        assert!(build_sampler("topk:p=1.5").is_err());
        assert!(build_sampler("topk:p=1.0").is_ok());
    }

    #[test]
    fn zero_mass_rows_return_none_for_all_samplers() {
        let logits = vec![1.0f32; 32];
        let t = Transform {
            temperature: 1.0,
            bias: Some(vec![f32::NEG_INFINITY; 32]),
        };
        for s in default_samplers() {
            let ctx = RowCtx { transform: &t, key: Key::new(3, 4), row: 0, step: 0 };
            assert_eq!(s.sample_row(&logits, ctx), None, "{}", s.name());
        }
    }

    #[test]
    fn sample_batch_rows_are_independent_of_batching() {
        let key = Key::new(9, 1);
        let t = Transform::default();
        let vocab = 64usize;
        let logits: Vec<f32> = (0..3 * vocab)
            .map(|i| philox::uniform_at(key, i as u32, 7, 3, 0) - 0.5)
            .collect();
        for s in default_samplers() {
            let batched = s.sample_batch(&logits, vocab, &t, key, 5);
            assert_eq!(batched.len(), 3, "{}", s.name());
            for (b, row) in logits.chunks_exact(vocab).enumerate() {
                let solo = s.sample_row(
                    row,
                    RowCtx { transform: &t, key, row: b as u32, step: 5 },
                );
                assert_eq!(batched[b], solo, "{} row {b}", s.name());
            }
        }
    }
}
