//! Exact categorical samplers — native Rust mirrors of the paper's
//! algorithms, sharing Philox streams with the Pallas kernel.
//!
//! | Paper | Module |
//! |---|---|
//! | Alg. I.1 streaming Gumbel-Max | [`gumbel`] |
//! | Alg. A.1 materialized-logits baseline | [`multinomial`] |
//! | Alg. I.2 parallel Group-Gumbel-Max | [`grouped`] |
//! | Alg. I.3 online merge (Lemma D.3) | [`online`] |
//! | Alg. I.4 distributed tensor-parallel merge | [`distributed`] |
//! | Gumbel-Top-k candidate reduction (App. D.6) | [`topk`] |
//! | chi-squared GoF + paired bootstrap (§4.6) | [`stats`] |
//!
//! These run on the L3 request path (e.g. the TP orchestrator's rank merge)
//! and in tests/benches; the heavy fused path is the AOT Pallas kernel.

pub mod distributed;
pub mod grouped;
pub mod gumbel;
pub mod multinomial;
pub mod online;
pub mod philox;
pub mod stats;
pub mod topk;

pub use philox::Key;

/// Numerically stable log(sum(exp(xs))) over a slice.
///
/// Returns `-inf` for empty/all-`-inf` input (a zero-mass group, §D.1).
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return f32::NEG_INFINITY;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// log(e^a + e^b) without overflow; the online merge's running-mass update.
pub fn log_add_exp(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Deterministic logit transforms (Alg. 1 line 3): temperature scaling,
/// optional additive bias, `-inf` masking handled via the bias path.
#[derive(Clone, Debug)]
pub struct Transform {
    /// Softmax temperature tau > 0.
    pub temperature: f32,
    /// Optional per-vocab additive bias; `-inf` entries ban tokens.
    pub bias: Option<Vec<f32>>,
}

impl Default for Transform {
    fn default() -> Self {
        Self { temperature: 1.0, bias: None }
    }
}

impl Transform {
    pub fn with_temperature(temperature: f32) -> Self {
        Self { temperature, bias: None }
    }

    /// Apply to one logit at vocab index `i`.
    #[inline(always)]
    pub fn apply(&self, logit: f32, i: usize) -> f32 {
        let mut y = logit / self.temperature;
        if let Some(b) = &self.bias {
            y += b[i];
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_matches_naive() {
        let xs = [0.1f32, -2.0, 3.5, 1.0];
        let naive: f32 = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn log_sum_exp_handles_extremes() {
        assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
        assert_eq!(log_sum_exp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
        // No overflow at large magnitudes.
        let big = log_sum_exp(&[1000.0, 1000.0]);
        assert!((big - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }

    #[test]
    fn log_add_exp_agrees_with_log_sum_exp() {
        for (a, b) in [(0.0f32, 1.0f32), (-5.0, 3.0), (100.0, 99.0)] {
            assert!((log_add_exp(a, b) - log_sum_exp(&[a, b])).abs() < 1e-5);
        }
        assert_eq!(log_add_exp(f32::NEG_INFINITY, 2.0), 2.0);
        assert_eq!(log_add_exp(2.0, f32::NEG_INFINITY), 2.0);
    }

    #[test]
    fn transform_applies_temperature_and_bias() {
        let t = Transform { temperature: 2.0, bias: Some(vec![0.0, -f32::INFINITY]) };
        assert_eq!(t.apply(4.0, 0), 2.0);
        assert_eq!(t.apply(4.0, 1), f32::NEG_INFINITY);
    }
}
