//! Exact categorical samplers — native Rust mirrors of the paper's
//! algorithms, sharing Philox streams with the Pallas kernel.
//!
//! | Paper | Module | Registry name |
//! |---|---|---|
//! | Alg. I.1 streaming Gumbel-Max | [`gumbel`] | `gumbel` |
//! | Alg. A.1 materialized-logits baseline | [`multinomial`] | `multinomial` |
//! | Alg. I.2 parallel Group-Gumbel-Max | [`grouped`] | `grouped` |
//! | Alg. I.3 online merge (Lemma D.3) | [`online`] | `online` |
//! | Alg. I.4 distributed tensor-parallel merge | [`distributed`] | `distributed` |
//! | Gumbel-Top-k candidate reduction (App. D.6) | [`topk`] | `topk` |
//! | chi-squared GoF + paired bootstrap (§4.6) | [`stats`] | — |
//!
//! These run on the L3 request path (e.g. the TP orchestrator's rank merge)
//! and in tests/benches; the heavy fused path is the AOT Pallas kernel.
//!
//! # The `ExactSampler` trait and the typed `SamplerSpec`
//!
//! Every paper sampler is also exposed behind the common [`ExactSampler`]
//! trait, selected by a typed [`SamplerSpec`] — the single seam through
//! which the coordinator, the TP orchestrator, the benches, and the repro
//! tables select sampling algorithms (no hard-coded call sites).  Config
//! strings are parsed **once** at the system boundary
//! (`SamplerSpec::from_str`) and rendered back canonically
//! (`SamplerSpec::to_string`); [`build_sampler`] survives as a thin
//! parse-then-build shim for string call sites.  Spec grammar:
//!
//! ```text
//!   <name>                      e.g.  "gumbel"
//!   <name>:<k>=<v>[,<k>=<v>]*   e.g.  "grouped:group=64"
//!                                     "topk:k=8,p=0.95,tile=2048"
//! ```
//!
//! Recognised parameters: `tile` ([`gumbel`], [`topk`]), `group`
//! ([`grouped`], [`online`]), `ranks` ([`distributed`]), `k` and `p`
//! ([`topk`]), `k` and `ngram` (`specdec` — the speculative-decode engine
//! path, [`SamplerSpec::SpecDecode`]; parses and validates like any spec
//! but is dispatched by the coordinator rather than built into an
//! [`ExactSampler`]).  Unknown names or parameters are errors, so config
//! typos fail fast.
//!
//! Exactness contract across the trait boundary: a sampler built from a
//! spec draws from exactly the same Philox streams as the underlying
//! module functions, so results are pathwise reproducible from
//! `(spec, seed, row, step)` — asserted by `tests/sampler_trait.rs`.
//!
//! ```
//! use flashsampling::sampling::{
//!     build_sampler, ExactSampler, Key, RowCtx, Transform,
//! };
//!
//! let sampler = build_sampler("grouped:group=4").unwrap();
//! let logits = [0.5f32, -1.0, 2.0, 0.0, 1.5, -0.5, 0.25, 1.0];
//! let t = Transform::default();
//! let ctx = RowCtx { transform: &t, key: Key::from_seed(7), row: 0, step: 0 };
//! let draw = sampler.sample_row(&logits, ctx).unwrap();
//! assert!((draw.index as usize) < logits.len());
//! // Group-structured samplers return log Z for free (Appendix L).
//! assert!(draw.log_z.is_some());
//! ```

pub mod distributed;
pub mod grouped;
pub mod gumbel;
pub mod multinomial;
pub mod online;
pub mod philox;
pub mod spec;
pub mod stats;
pub mod topk;

use anyhow::Result;

pub use philox::Key;
pub use spec::SamplerSpec;

/// Numerically stable log(sum(exp(xs))) over a slice.
///
/// Returns `-inf` for empty/all-`-inf` input (a zero-mass group, §D.1):
///
/// ```
/// use flashsampling::sampling::log_sum_exp;
///
/// // Empty slice and all-masked groups both carry zero mass.
/// assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
/// assert_eq!(log_sum_exp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
/// // No overflow at large magnitudes.
/// let z = log_sum_exp(&[1000.0, 1000.0]);
/// assert!((z - (1000.0 + 2f32.ln())).abs() < 1e-3);
/// ```
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return f32::NEG_INFINITY;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// log(e^a + e^b) without overflow; the online merge's running-mass update.
///
/// `-inf` operands act as the additive identity (zero mass), so streaming a
/// dead group leaves the running mass untouched:
///
/// ```
/// use flashsampling::sampling::log_add_exp;
///
/// assert_eq!(log_add_exp(f32::NEG_INFINITY, 2.0), 2.0);
/// assert_eq!(log_add_exp(2.0, f32::NEG_INFINITY), 2.0);
/// assert_eq!(
///     log_add_exp(f32::NEG_INFINITY, f32::NEG_INFINITY),
///     f32::NEG_INFINITY
/// );
/// assert!((log_add_exp(0.0, 0.0) - 2f32.ln()).abs() < 1e-6);
/// ```
pub fn log_add_exp(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Deterministic logit transforms (Alg. 1 line 3): temperature scaling,
/// optional additive bias, `-inf` masking handled via the bias path.
#[derive(Clone, Debug)]
pub struct Transform {
    /// Softmax temperature tau > 0.
    pub temperature: f32,
    /// Optional per-vocab additive bias; `-inf` entries ban tokens.
    pub bias: Option<Vec<f32>>,
}

impl Default for Transform {
    fn default() -> Self {
        Self { temperature: 1.0, bias: None }
    }
}

impl Transform {
    pub fn with_temperature(temperature: f32) -> Self {
        Self { temperature, bias: None }
    }

    /// Apply to one logit at vocab index `i`.
    #[inline(always)]
    pub fn apply(&self, logit: f32, i: usize) -> f32 {
        let mut y = logit / self.temperature;
        if let Some(b) = &self.bias {
            y += b[i];
        }
        y
    }

    /// Fold top-k / top-p truncation of `logits` into the bias, returning a
    /// new transform with the complement of the keep set masked to `-inf`.
    ///
    /// Masking-then-renormalizing **is** top-k / nucleus sampling (the
    /// truncated categorical is the renormalized restriction), so any exact
    /// sampler run under the returned transform draws exactly from the
    /// truncated distribution — this is how per-row `top_k`/`top_p` from
    /// `SamplingParams` reach the host-side samplers (App. D.6).  `top_p`
    /// applies after `top_k` (the vLLM/FlashInfer order); ties at the
    /// boundary break by lower vocab index.
    pub fn truncated(
        &self,
        logits: &[f32],
        top_k: Option<usize>,
        top_p: Option<f32>,
    ) -> Transform {
        if top_k.is_none() && top_p.is_none() {
            return self.clone();
        }
        // Transform once (O(V)), then rank live categories by the cached
        // value, descending — this runs per row per decode step on host
        // paths, so: top-k alone partitions in O(V) (the keep SET needs no
        // internal order), and only a nucleus pass sorts — the k survivors
        // if top-k ran first, the full live set otherwise.
        let y: Vec<f32> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| self.apply(l, i))
            .collect();
        let cmp = |a: &usize, b: &usize| {
            y[*b].partial_cmp(&y[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        let mut order: Vec<usize> =
            (0..y.len()).filter(|&i| y[i] > f32::NEG_INFINITY).collect();
        if let Some(k) = top_k {
            let k = k.max(1);
            if k < order.len() {
                // Total order (ties break by index) => deterministic set.
                order.select_nth_unstable_by(k - 1, cmp);
                order.truncate(k);
            }
        }
        if top_p.is_some() {
            order.sort_by(cmp);
        }
        let n_keep = match top_p {
            None => order.len(),
            Some(p) => {
                // Nucleus over the (possibly k-truncated) survivors: keep
                // the smallest prefix whose renormalized mass reaches p.
                let ys: Vec<f32> = order.iter().map(|&i| y[i]).collect();
                let z = log_sum_exp(&ys);
                let mut cum = 0.0f64;
                let mut keep = 0usize;
                for &yv in &ys {
                    keep += 1;
                    cum += ((yv - z) as f64).exp();
                    if cum >= p as f64 {
                        break;
                    }
                }
                keep.max(1)
            }
        };
        let mut bias = vec![f32::NEG_INFINITY; logits.len()];
        for &i in &order[..n_keep.min(order.len())] {
            bias[i] = self.bias.as_ref().map_or(0.0, |b| b[i]);
        }
        Transform { temperature: self.temperature, bias: Some(bias) }
    }
}

// --- the unified sampler trait -------------------------------------------

/// Per-row sampling context handed across the [`ExactSampler`] boundary.
///
/// Bundles the deterministic inputs of one draw: the logit transform and
/// the Philox coordinates `(key, row, step)`.  Two calls with equal context
/// and equal logits return the identical sample, whatever the algorithm.
#[derive(Clone, Copy, Debug)]
pub struct RowCtx<'a> {
    /// Logit transform (temperature, bias/masking).
    pub transform: &'a Transform,
    /// RNG key (the `seed` input of every artifact).
    pub key: Key,
    /// Batch row index b — selects the Philox stream.
    pub row: u32,
    /// Decode step — fresh noise per scheduler iteration.
    pub step: u32,
}

/// One exact draw plus optional free byproducts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Draw {
    /// Sampled vocabulary index.
    pub index: u32,
    /// log-normalizer log Z when the algorithm computes it as a byproduct
    /// of its group masses (Appendix L); `None` for single-pass samplers.
    pub log_z: Option<f32>,
}

/// A sampler that draws *exactly* from the transformed categorical
/// distribution (or a documented candidate-reduced variant, for
/// [`topk`]), deterministically in the Philox coordinates.
///
/// Implementations are thin adapters over the per-algorithm module
/// functions; construct them by config string through [`build_sampler`].
pub trait ExactSampler: Send + Sync {
    /// Registry name (`"gumbel"`, `"multinomial"`, ...).
    fn name(&self) -> &'static str;

    /// Draw one token from a row of logits.
    ///
    /// Returns `None` when every transformed logit is `-inf` (zero-mass
    /// target distribution — the caller must treat this as an error).
    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw>;

    /// Draw one token per row of a `[B, V]` row-major batch; row `b` uses
    /// Philox stream `b` (so batching never changes any row's sample).
    fn sample_batch(
        &self,
        logits: &[f32],
        vocab: usize,
        transform: &Transform,
        key: Key,
        step: u32,
    ) -> Vec<Option<Draw>> {
        assert!(vocab > 0, "vocab must be positive");
        assert_eq!(logits.len() % vocab, 0);
        logits
            .chunks_exact(vocab)
            .enumerate()
            .map(|(b, row)| {
                self.sample_row(
                    row,
                    RowCtx { transform, key, row: b as u32, step },
                )
            })
            .collect()
    }

    /// Per-row-parameterized batch entry point: row `b` of the `[B, V]`
    /// matrix samples under `ctxs[b]` — its own transform (temperature /
    /// bias / truncation mask) and its own key.
    ///
    /// This is how heterogeneous batches sample **exactly**: each row keeps
    /// the Philox coordinates it would have alone (`ctxs[b].row`, `step`),
    /// so mixing rows with different `SamplingParams` in one batch never
    /// changes any row's draw — the property that lets the scheduler
    /// coalesce mixed-temperature requests into full buckets.
    fn sample_batch_rows(
        &self,
        logits: &[f32],
        vocab: usize,
        ctxs: &[RowCtx<'_>],
    ) -> Vec<Option<Draw>> {
        assert!(vocab > 0, "vocab must be positive");
        assert_eq!(
            logits.len(),
            vocab * ctxs.len(),
            "logits [B, V] must match the per-row context count"
        );
        logits
            .chunks_exact(vocab)
            .zip(ctxs)
            .map(|(row, ctx)| self.sample_row(row, *ctx))
            .collect()
    }
}

// --- the name-keyed registry ---------------------------------------------

/// The six paper samplers, in paper order — every name accepted by
/// [`SamplerSpec`] / [`build_sampler`].
pub const SAMPLER_NAMES: [&str; 6] =
    ["gumbel", "multinomial", "grouped", "online", "distributed", "topk"];

/// Build an [`ExactSampler`] from a config string — the back-compat shim
/// over the typed path: `spec.parse::<SamplerSpec>()?.build()`.  Legacy
/// strings (`"grouped:group=64"`, ...) construct identical samplers to the
/// pre-typed registry; typed call sites should hold a [`SamplerSpec`] and
/// call [`SamplerSpec::build`] directly.
pub fn build_sampler(spec: &str) -> Result<Box<dyn ExactSampler>> {
    spec.parse::<SamplerSpec>()?.build()
}

/// One default-configured instance of every registered sampler, in
/// [`SAMPLER_NAMES`] order — the bench/report iteration set.
pub fn default_samplers() -> Vec<Box<dyn ExactSampler>> {
    SAMPLER_NAMES
        .iter()
        .map(|n| build_sampler(n).expect("default sampler specs are valid"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_matches_naive() {
        let xs = [0.1f32, -2.0, 3.5, 1.0];
        let naive: f32 = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn log_sum_exp_handles_extremes() {
        assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
        assert_eq!(log_sum_exp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
        // No overflow at large magnitudes.
        let big = log_sum_exp(&[1000.0, 1000.0]);
        assert!((big - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }

    #[test]
    fn log_add_exp_agrees_with_log_sum_exp() {
        for (a, b) in [(0.0f32, 1.0f32), (-5.0, 3.0), (100.0, 99.0)] {
            assert!((log_add_exp(a, b) - log_sum_exp(&[a, b])).abs() < 1e-5);
        }
        assert_eq!(log_add_exp(f32::NEG_INFINITY, 2.0), 2.0);
        assert_eq!(log_add_exp(2.0, f32::NEG_INFINITY), 2.0);
    }

    #[test]
    fn transform_applies_temperature_and_bias() {
        let t = Transform { temperature: 2.0, bias: Some(vec![0.0, -f32::INFINITY]) };
        assert_eq!(t.apply(4.0, 0), 2.0);
        assert_eq!(t.apply(4.0, 1), f32::NEG_INFINITY);
    }

    #[test]
    fn registry_builds_every_name() {
        for name in SAMPLER_NAMES {
            let s = build_sampler(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert_eq!(default_samplers().len(), SAMPLER_NAMES.len());
    }

    #[test]
    fn registry_parses_parameters() {
        assert!(build_sampler("grouped:group=64").is_ok());
        assert!(build_sampler("online:group=17").is_ok());
        assert!(build_sampler("distributed:ranks=4").is_ok());
        assert!(build_sampler("topk:k=4,p=0.9,tile=128").is_ok());
        assert!(build_sampler("gumbel:tile=2048").is_ok());
        assert!(build_sampler(" gumbel ").is_ok()); // whitespace-tolerant
    }

    #[test]
    fn registry_rejects_bad_specs() {
        assert!(build_sampler("nope").is_err());
        assert!(build_sampler("gumbel:wat=1").is_err()); // unknown param
        assert!(build_sampler("grouped:group=0").is_err()); // zero-sized
        assert!(build_sampler("grouped:group=abc").is_err()); // non-numeric
        assert!(build_sampler("topk:k").is_err()); // missing '='
        assert!(build_sampler("multinomial:x=1").is_err()); // takes none
        assert!(build_sampler("grouped:group=8,group=64").is_err()); // dup
        assert!(build_sampler("topk:p=nan").is_err()); // out-of-range mass
        assert!(build_sampler("topk:p=0").is_err());
        assert!(build_sampler("topk:p=1.5").is_err());
        assert!(build_sampler("topk:p=1.0").is_ok());
    }

    #[test]
    fn zero_mass_rows_return_none_for_all_samplers() {
        let logits = vec![1.0f32; 32];
        let t = Transform {
            temperature: 1.0,
            bias: Some(vec![f32::NEG_INFINITY; 32]),
        };
        for s in default_samplers() {
            let ctx = RowCtx { transform: &t, key: Key::new(3, 4), row: 0, step: 0 };
            assert_eq!(s.sample_row(&logits, ctx), None, "{}", s.name());
        }
    }

    #[test]
    fn truncated_transform_masks_exactly_topk() {
        let logits = vec![3.0f32, 1.0, 2.0, 0.0, -1.0];
        let t = Transform::default().truncated(&logits, Some(2), None);
        let bias = t.bias.as_ref().unwrap();
        // Keep set = indices of the 2 largest logits {0, 2}.
        assert_eq!(bias[0], 0.0);
        assert_eq!(bias[2], 0.0);
        for i in [1usize, 3, 4] {
            assert_eq!(bias[i], f32::NEG_INFINITY, "index {i}");
        }
        // No truncation requested => transform unchanged (no bias).
        assert!(Transform::default().truncated(&logits, None, None).bias.is_none());
    }

    #[test]
    fn truncated_transform_nucleus_keeps_minimal_prefix() {
        // Probs ~ [0.64, 0.24, 0.09, 0.03]; p=0.8 keeps the top two.
        let logits = vec![3.0f32, 2.0, 1.0, 0.0];
        let t = Transform::default().truncated(&logits, None, Some(0.8));
        let bias = t.bias.as_ref().unwrap();
        assert_eq!(bias[0], 0.0);
        assert_eq!(bias[1], 0.0);
        assert_eq!(bias[2], f32::NEG_INFINITY);
        assert_eq!(bias[3], f32::NEG_INFINITY);
        // p=1.0 keeps everything live.
        let t = Transform::default().truncated(&logits, None, Some(1.0));
        assert!(t.bias.as_ref().unwrap().iter().all(|&b| b == 0.0));
        // The first survivor is always kept, even under a tiny p.
        let t = Transform::default().truncated(&logits, None, Some(1e-6));
        assert_eq!(t.bias.as_ref().unwrap()[0], 0.0);
    }

    #[test]
    fn truncated_transform_preserves_base_bias_and_temperature() {
        let logits = vec![0.0f32, 5.0, 1.0, 2.0];
        // Base masks index 1 (the would-be argmax); truncation ranks the
        // survivors only, under the base temperature.
        let mut bias = vec![0.5f32; 4];
        bias[1] = f32::NEG_INFINITY;
        let base = Transform { temperature: 2.0, bias: Some(bias) };
        let t = base.truncated(&logits, Some(1), None);
        assert_eq!(t.temperature, 2.0);
        let tb = t.bias.as_ref().unwrap();
        assert_eq!(tb[3], 0.5); // survivor keeps the base bias value
        for i in [0usize, 1, 2] {
            assert_eq!(tb[i], f32::NEG_INFINITY, "index {i}");
        }
    }

    #[test]
    fn sample_batch_rows_matches_homogeneous_path_per_row() {
        // A heterogeneous batch where every row happens to use the same
        // transform must reproduce sample_batch exactly; rows with their own
        // transforms must match their solo sample_row draws.
        let key = Key::new(21, 4);
        let vocab = 96usize;
        let logits: Vec<f32> = (0..3 * vocab)
            .map(|i| philox::uniform_at(key, i as u32, 8, 3, 0) - 0.5)
            .collect();
        let transforms = [
            Transform::with_temperature(0.5),
            Transform::with_temperature(1.0),
            Transform::with_temperature(2.0),
        ];
        for s in default_samplers() {
            let ctxs: Vec<RowCtx<'_>> = transforms
                .iter()
                .enumerate()
                .map(|(b, t)| RowCtx { transform: t, key, row: b as u32, step: 3 })
                .collect();
            let hetero = s.sample_batch_rows(&logits, vocab, &ctxs);
            assert_eq!(hetero.len(), 3, "{}", s.name());
            for (b, row) in logits.chunks_exact(vocab).enumerate() {
                let solo = s.sample_row(row, ctxs[b]);
                assert_eq!(hetero[b], solo, "{} row {b}", s.name());
            }
            // Homogeneous contexts reduce to sample_batch.
            let t = Transform::default();
            let ctxs: Vec<RowCtx<'_>> = (0..3)
                .map(|b| RowCtx { transform: &t, key, row: b as u32, step: 3 })
                .collect();
            assert_eq!(
                s.sample_batch_rows(&logits, vocab, &ctxs),
                s.sample_batch(&logits, vocab, &t, key, 3),
                "{}",
                s.name()
            );
        }
    }

    #[test]
    fn sample_batch_rows_are_independent_of_batching() {
        let key = Key::new(9, 1);
        let t = Transform::default();
        let vocab = 64usize;
        let logits: Vec<f32> = (0..3 * vocab)
            .map(|i| philox::uniform_at(key, i as u32, 7, 3, 0) - 0.5)
            .collect();
        for s in default_samplers() {
            let batched = s.sample_batch(&logits, vocab, &t, key, 5);
            assert_eq!(batched.len(), 3, "{}", s.name());
            for (b, row) in logits.chunks_exact(vocab).enumerate() {
                let solo = s.sample_row(
                    row,
                    RowCtx { transform: &t, key, row: b as u32, step: 5 },
                );
                assert_eq!(batched[b], solo, "{} row {b}", s.name());
            }
        }
    }
}
