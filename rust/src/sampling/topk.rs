//! Gumbel-Top-k candidate reduction (paper Appendix D.6, Kool et al. 2019).
//!
//! The paper proves the extension but leaves the fused implementation to
//! future work; we implement the two-stage candidate reduction natively:
//! each vocabulary tile reports its local top-k perturbed scores, a second
//! stage merges per-tile candidates into the global top-k, and the final
//! sample is drawn from the k survivors.  Top-p can then be applied on the
//! reduced candidate set (the "top-k-then-top-p" strategy vLLM/FlashInfer
//! use, §D.6).

use super::philox::{self, Key};
use super::{Draw, ExactSampler, RowCtx, Transform};

/// Default candidate-set size of the registry's `topk` spec.
pub const DEFAULT_K: usize = 8;
/// Default vocabulary tile of the registry's `topk` spec (matches the
/// fused kernel's tile).
pub const DEFAULT_TILE_V: usize = 2048;

/// A perturbed-score candidate (global index + score + raw logit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    pub index: u32,
    /// Perturbed score (logit + Gumbel) — ordering key for top-k w/o repl.
    pub score: f32,
    /// Transformed (unperturbed) logit — needed for the final re-sampling
    /// and for top-p mass computation on the candidate set.
    pub logit: f32,
}

/// Keep the k largest candidates (by perturbed score) seen so far.
///
/// Simple bounded insertion — k is small (<= 64 in practice), so an O(k)
/// insert beats heap overhead.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    items: Vec<Candidate>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        Self { k, items: Vec::with_capacity(k + 1) }
    }

    pub fn push(&mut self, c: Candidate) {
        if c.score == f32::NEG_INFINITY {
            return;
        }
        let pos = self
            .items
            .iter()
            .position(|x| c.score > x.score)
            .unwrap_or(self.items.len());
        if pos < self.k {
            self.items.insert(pos, c);
            self.items.truncate(self.k);
        }
    }

    pub fn merge(&mut self, other: &TopK) {
        for &c in &other.items {
            self.push(c);
        }
    }

    /// Candidates in descending score order.
    pub fn items(&self) -> &[Candidate] {
        &self.items
    }
}

/// Stage 1+2: top-k candidates of a row via tile-local reduction.
///
/// By the same partition argument as Lemma D.5 applied k times (Gumbel-Top-k
/// order statistics decompose over tiles as long as each tile keeps its own
/// top-k), the merged result equals the monolithic top-k — asserted in tests.
pub fn topk_tiled(
    logits: &[f32],
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
    k: usize,
    tile_v: usize,
) -> TopK {
    let mut global = TopK::new(k);
    for (t, tile) in logits.chunks(tile_v.max(1)).enumerate() {
        let mut local = TopK::new(k);
        let base = t * tile_v.max(1);
        for (j, &l) in tile.iter().enumerate() {
            let i = base + j;
            let y = transform.apply(l, i);
            if y == f32::NEG_INFINITY {
                continue;
            }
            let g = philox::gumbel_at(key, i as u32, row, step);
            local.push(Candidate { index: i as u32, score: y + g, logit: y });
        }
        global.merge(&local);
    }
    global
}

/// Monolithic Gumbel-Top-k (the oracle for `topk_tiled`).
pub fn topk_monolithic(
    logits: &[f32],
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
    k: usize,
) -> TopK {
    topk_tiled(logits, transform, key, row, step, k, logits.len().max(1))
}

/// Sample one token from the top-k survivors (softmax over their logits),
/// optionally truncated further by nucleus mass `top_p` (§D.6: top-p applied
/// after top-k on the tiny candidate set).
///
/// Consumes the ROW_UNIFORM stream at counter i = 1 (distinct from the
/// baseline sampler's i = 0).
pub fn sample_from_candidates(
    topk: &TopK,
    top_p: f32,
    key: Key,
    row: u32,
    step: u32,
) -> Option<u32> {
    let items = topk.items();
    if items.is_empty() {
        return None;
    }
    // Softmax over candidate logits (they are already transformed).
    let m = items.iter().map(|c| c.logit).fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f64> = items.iter().map(|c| ((c.logit - m) as f64).exp()).collect();
    let z: f64 = e.iter().sum();
    // Nucleus truncation on the candidate set, highest-prob first (the set
    // is score-ordered, so re-sort by prob = logit order).
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by(|&a, &b| e[b].partial_cmp(&e[a]).unwrap());
    let mut kept = Vec::with_capacity(items.len());
    let mut mass = 0.0f64;
    for &i in &order {
        kept.push(i);
        mass += e[i] / z;
        if mass >= top_p as f64 {
            break;
        }
    }
    let kept_z: f64 = kept.iter().map(|&i| e[i]).sum();
    let u = philox::uniform_at(key, 1, row, philox::STREAM_ROW_UNIFORM, step) as f64;
    let target = u * kept_z;
    let mut acc = 0.0f64;
    for &i in &kept {
        acc += e[i];
        if acc >= target {
            return Some(items[i].index);
        }
    }
    kept.last().map(|&i| items[i].index)
}

/// [`ExactSampler`] adapter over the Gumbel-Top-k candidate reduction
/// (Appendix D.6) — registry name `topk`.
///
/// Unlike the other five samplers this draws from the *k-candidate
/// truncated* distribution (optionally nucleus-truncated further by
/// `top_p`), which is the documented semantics of the top-k-then-top-p
/// strategy — exact over the reduced support, not over the full
/// categorical.  Spec example: `"topk:k=8,p=0.95,tile=2048"`.
#[derive(Clone, Copy, Debug)]
pub struct GumbelTopKSampler {
    /// Candidates kept per row (k >= 1).
    pub k: usize,
    /// Nucleus mass applied over the candidate set (1.0 = keep all).
    pub top_p: f32,
    /// Stage-1 vocabulary tile size.
    pub tile_v: usize,
}

impl Default for GumbelTopKSampler {
    fn default() -> Self {
        Self { k: DEFAULT_K, top_p: 1.0, tile_v: DEFAULT_TILE_V }
    }
}

impl ExactSampler for GumbelTopKSampler {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        let tk = topk_tiled(
            logits,
            ctx.transform,
            ctx.key,
            ctx.row,
            ctx.step,
            self.k,
            self.tile_v,
        );
        sample_from_candidates(&tk, self.top_p, ctx.key, ctx.row, ctx.step)
            .map(|index| Draw { index, log_z: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
        let key = Key::from_seed(seed ^ 0x70B0);
        (0..n)
            .map(|i| 4.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect()
    }

    #[test]
    fn topk_keeps_k_best() {
        let mut t = TopK::new(3);
        for (i, s) in [(0u32, 1.0f32), (1, 5.0), (2, 3.0), (3, 4.0), (4, 2.0)] {
            t.push(Candidate { index: i, score: s, logit: s });
        }
        let scores: Vec<f32> = t.items().iter().map(|c| c.score).collect();
        assert_eq!(scores, vec![5.0, 4.0, 3.0]);
    }

    #[test]
    fn topk_without_replacement_indices_distinct() {
        let l = toy_logits(100, 1);
        let t = topk_monolithic(&l, &Transform::default(), Key::new(1, 2), 0, 0, 10);
        let mut idx: Vec<u32> = t.items().iter().map(|c| c.index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn sample_from_candidates_respects_top_p_1() {
        let l = toy_logits(64, 2);
        let key = Key::new(3, 4);
        let tk = topk_monolithic(&l, &Transform::default(), key, 0, 0, 8);
        let s = sample_from_candidates(&tk, 1.0, key, 0, 0).unwrap();
        assert!(tk.items().iter().any(|c| c.index == s));
    }

    #[test]
    fn top_p_zero_is_greedy_over_candidates() {
        let l = toy_logits(64, 3);
        let key = Key::new(5, 6);
        let tk = topk_monolithic(&l, &Transform::default(), key, 0, 0, 8);
        // top_p -> 0 keeps only the highest-probability candidate
        let s = sample_from_candidates(&tk, 1e-9, key, 0, 0).unwrap();
        let best = tk
            .items()
            .iter()
            .max_by(|a, b| a.logit.partial_cmp(&b.logit).unwrap())
            .unwrap();
        assert_eq!(s, best.index);
    }

    /// Single-element vocabulary: the only candidate must always win, for
    /// any k, any top_p, and any tiling — and an all-masked single element
    /// yields no sample at all.
    #[test]
    fn single_element_vocab() {
        let l = [0.75f32];
        let t = Transform::default();
        let key = Key::new(40, 41);
        for k in [1usize, 2, 8] {
            for tile in [1usize, 7, 2048] {
                let tk = topk_tiled(&l, &t, key, 0, 0, k, tile);
                assert_eq!(tk.items().len(), 1, "k={k} tile={tile}");
                assert_eq!(tk.items()[0].index, 0);
                for p in [1e-9f32, 0.5, 1.0] {
                    let s = sample_from_candidates(&tk, p, key, 0, 0);
                    assert_eq!(s, Some(0), "k={k} tile={tile} p={p}");
                }
            }
        }
        let masked = Transform {
            temperature: 1.0,
            bias: Some(vec![f32::NEG_INFINITY]),
        };
        let tk = topk_monolithic(&l, &masked, key, 0, 0, 4);
        assert!(tk.items().is_empty());
        assert_eq!(sample_from_candidates(&tk, 1.0, key, 0, 0), None);
    }

    /// The trait adapter draws from the same Philox streams as the module
    /// functions (pathwise identity across the `ExactSampler` boundary).
    #[test]
    fn trait_adapter_matches_module_fns() {
        let l = toy_logits(300, 9);
        let t = Transform::default();
        let key = Key::new(50, 51);
        let s = GumbelTopKSampler { k: 8, top_p: 0.9, tile_v: 64 };
        for step in 0..20 {
            let ctx = RowCtx { transform: &t, key, row: 2, step };
            let via_trait = s.sample_row(&l, ctx).unwrap();
            let tk = topk_tiled(&l, &t, key, 2, step, 8, 64);
            let manual = sample_from_candidates(&tk, 0.9, key, 2, step).unwrap();
            assert_eq!(via_trait.index, manual);
            assert_eq!(via_trait.log_z, None);
        }
    }

    /// Tile decomposition of Gumbel-Top-k is exact for any tiling.
    #[test]
    fn prop_tiled_topk_equals_monolithic() {
        testutil::cases(96, 0x91, |g| {
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 16);
            let tile = g.usize_in(1, 64);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let key = Key::from_seed(seed);
            let a = topk_monolithic(&l, &t, key, 0, 0, k);
            let b = topk_tiled(&l, &t, key, 0, 0, k, tile);
            assert_eq!(a.items(), b.items());
        });
    }

    /// k = 1 degenerates to plain Gumbel-Max.
    #[test]
    fn prop_k1_is_gumbel_max() {
        testutil::cases(64, 0x92, |g| {
            let n = g.usize_in(1, 200);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let key = Key::from_seed(seed);
            let tk = topk_monolithic(&l, &t, key, 0, 7, 1);
            let gm = super::super::gumbel::sample_row(&l, &t, key, 0, 7).unwrap();
            assert_eq!(tk.items()[0].index, gm.index);
        });
    }
}
