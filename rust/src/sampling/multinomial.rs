//! Materialized-logits baseline sampler (paper Algorithm A.1).
//!
//! The kernel chain the paper's baselines pay for: max pass, exp-sum pass,
//! normalized probabilities, prefix sum, inverse-CDF search.  Exact, but it
//! touches the logits row multiple times — this cost asymmetry (vs. the
//! single fused pass) is exactly what `gpusim::kernel_chain` models and
//! Table 1 / Figure 4 report.

use super::philox::{self, Key};
use super::{Draw, ExactSampler, RowCtx, Transform};

/// Full baseline pipeline over one row (Alg. A.1 lines 1-9).
///
/// Draws the row uniform from the ROW_UNIFORM Philox stream at counter
/// (i=0, b=row) — the same stream the baseline AOT artifact uses, so the
/// Rust and XLA baselines are pathwise comparable.
///
/// Returns `None` when the row has no finite transformed logit.
pub fn sample_row(
    logits: &[f32],
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
) -> Option<u32> {
    // Pass 1: max over transformed logits.
    let mut m = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        m = m.max(transform.apply(l, i));
    }
    if m == f32::NEG_INFINITY {
        return None;
    }
    // Pass 2: normalizer.
    let mut z = 0.0f64;
    for (i, &l) in logits.iter().enumerate() {
        z += ((transform.apply(l, i) - m) as f64).exp();
    }
    // Prefix-sum + inverse-CDF search (merged loop; the paper's Alg. A.1
    // materializes p and c as separate kernels — the traffic model accounts
    // for those passes, the arithmetic here is equivalent).
    let u = philox::uniform_at(key, 0, row, philox::STREAM_ROW_UNIFORM, step) as f64;
    let target = u * z;
    let mut acc = 0.0f64;
    let mut last_alive = None;
    for (i, &l) in logits.iter().enumerate() {
        let y = transform.apply(l, i);
        if y == f32::NEG_INFINITY {
            continue;
        }
        acc += ((y - m) as f64).exp();
        last_alive = Some(i as u32);
        if acc >= target {
            return Some(i as u32);
        }
    }
    last_alive // fp slack: clamp to the last nonzero-mass category
}

/// Baseline over a `[B, V]` row-major batch.
pub fn sample_batch(
    logits: &[f32],
    vocab: usize,
    transform: &Transform,
    key: Key,
    step: u32,
) -> Vec<Option<u32>> {
    assert_eq!(logits.len() % vocab, 0);
    logits
        .chunks_exact(vocab)
        .enumerate()
        .map(|(b, row)| sample_row(row, transform, key, b as u32, step))
        .collect()
}

/// Exact categorical probabilities for a row (the chi-squared oracle).
pub fn probs(logits: &[f32], transform: &Transform) -> Vec<f64> {
    let mut m = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        m = m.max(transform.apply(l, i));
    }
    let e: Vec<f64> = logits
        .iter()
        .enumerate()
        .map(|(i, &l)| {
            let y = transform.apply(l, i);
            if y == f32::NEG_INFINITY { 0.0 } else { ((y - m) as f64).exp() }
        })
        .collect();
    let z: f64 = e.iter().sum();
    e.into_iter().map(|x| x / z).collect()
}

/// [`ExactSampler`] adapter over Algorithm A.1 — registry name
/// `multinomial` (the materialized-logits baseline; no parameters).
#[derive(Clone, Copy, Debug, Default)]
pub struct MultinomialSampler;

impl ExactSampler for MultinomialSampler {
    fn name(&self) -> &'static str {
        "multinomial"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        sample_row(logits, ctx.transform, ctx.key, ctx.row, ctx.step)
            .map(|index| Draw { index, log_z: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    #[test]
    fn samples_in_range_and_deterministic() {
        let l: Vec<f32> = (0..100).map(|i| ((i * 37) % 11) as f32 / 3.0).collect();
        let t = Transform::default();
        let a = sample_row(&l, &t, Key::new(3, 4), 0, 0).unwrap();
        let b = sample_row(&l, &t, Key::new(3, 4), 0, 0).unwrap();
        assert_eq!(a, b);
        assert!((a as usize) < 100);
    }

    #[test]
    fn respects_mask() {
        let l = vec![0.0f32; 32];
        let mut bias = vec![f32::NEG_INFINITY; 32];
        bias[5] = 0.0;
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        for step in 0..20 {
            assert_eq!(sample_row(&l, &t, Key::new(1, 1), 0, step), Some(5));
        }
    }

    #[test]
    fn all_masked_is_none() {
        let l = vec![0.0f32; 8];
        let t = Transform { temperature: 1.0, bias: Some(vec![f32::NEG_INFINITY; 8]) };
        assert_eq!(sample_row(&l, &t, Key::new(1, 1), 0, 0), None);
    }

    #[test]
    fn probs_sum_to_one() {
        let l: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let p = probs(&l, &Transform::default());
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn peaked_distribution_sampled_correctly() {
        let mut l = vec![-10.0f32; 50];
        l[17] = 10.0; // ~e^20 more likely than anything else
        for step in 0..50 {
            assert_eq!(
                sample_row(&l, &Transform::default(), Key::new(2, 2), 0, step),
                Some(17)
            );
        }
    }

    #[test]
    fn prop_always_returns_valid_index() {
        testutil::cases(128, 0xA1, |g| {
            let n = g.usize_in(1, 300);
            let seed = g.u64();
            let tau = g.f32_in(0.1, 4.0);
            let step = g.u32_in(0, 100);
            let key = Key::from_seed(seed);
            let l: Vec<f32> = (0..n)
                .map(|i| 4.0 * (philox::uniform_at(key, i as u32, 1, 3, 0) - 0.5))
                .collect();
            let t = Transform::with_temperature(tau);
            let s = sample_row(&l, &t, key, 0, step).unwrap();
            assert!((s as usize) < n);
        });
    }
}
