//! Statistical verification machinery for §4.6: chi-squared goodness-of-fit
//! (kernel level) and paired bootstrap (end-to-end level).
//!
//! Self-contained implementations (no external stats crate): the chi-squared
//! survival function goes through the regularized upper incomplete gamma
//! function Q(df/2, x/2), computed by series/continued-fraction (Numerical
//! Recipes style), accurate to ~1e-10 over the ranges we use.

use super::philox::{self, Key};

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9 coefficients).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x), by series expansion
/// (converges fast for x < a + 1).
fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut sum = 1.0 / a;
    let mut term = sum;
    let mut n = a;
    for _ in 0..500 {
        n += 1.0;
        term *= x / n;
        sum += term;
        if term.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// Regularized upper incomplete gamma Q(a, x), by continued fraction
/// (converges fast for x > a + 1).
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Chi-squared survival function: P(X >= chi2) for X ~ ChiSq(df).
pub fn chi2_sf(chi2: f64, df: f64) -> f64 {
    if chi2 <= 0.0 {
        return 1.0;
    }
    let a = df / 2.0;
    let x = chi2 / 2.0;
    if x < a + 1.0 {
        1.0 - gamma_p_series(a, x)
    } else {
        gamma_q_cf(a, x)
    }
    .clamp(0.0, 1.0)
}

/// Chi-squared goodness-of-fit p-value of observed `counts` against
/// `probs`, merging small-expectation bins (E >= 5 validity rule), same
/// protocol as python/tests/test_distribution.py and the paper's §4.6.
pub fn chi_squared_pvalue(counts: &[u64], probs: &[f64], n: u64) -> f64 {
    assert_eq!(counts.len(), probs.len());
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| probs[a].partial_cmp(&probs[b]).unwrap());
    let mut bins: Vec<(f64, f64)> = Vec::new();
    let (mut acc_e, mut acc_c) = (0.0f64, 0.0f64);
    for &i in &order {
        acc_e += probs[i] * n as f64;
        acc_c += counts[i] as f64;
        if acc_e >= 5.0 {
            bins.push((acc_e, acc_c));
            acc_e = 0.0;
            acc_c = 0.0;
        }
    }
    if acc_e > 0.0 {
        if let Some(last) = bins.last_mut() {
            last.0 += acc_e;
            last.1 += acc_c;
        } else {
            bins.push((acc_e, acc_c));
        }
    }
    if bins.len() < 2 {
        return 1.0;
    }
    let chi2: f64 = bins.iter().map(|&(e, c)| (c - e) * (c - e) / e).sum();
    chi2_sf(chi2, (bins.len() - 1) as f64)
}

/// Paired bootstrap test for a difference in paired binary outcomes
/// (the paper's §4.6 end-to-end check: per-question accuracy of
/// FlashSampling vs baseline decode, p = 0.776 ⇒ no significant delta).
///
/// Returns the two-sided p-value for H0: mean(a - b) = 0.
pub fn paired_bootstrap_pvalue(
    a: &[f64],
    b: &[f64],
    resamples: u32,
    seed: u64,
) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let n = a.len();
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let observed: f64 = diffs.iter().sum::<f64>() / n as f64;
    // Bootstrap under the null: center the differences, resample with
    // replacement, count |mean*| >= |observed|.
    let centered: Vec<f64> = diffs.iter().map(|d| d - observed).collect();
    let key = Key::from_seed(seed);
    let mut extreme = 0u32;
    for r in 0..resamples {
        let mut s = 0.0f64;
        for j in 0..n {
            // index from the Philox stream: counter (j, r)
            let u = philox::uniform_at(key, j as u32, r, 3, 0) as f64;
            let idx = ((u * n as f64) as usize).min(n - 1);
            s += centered[idx];
        }
        if (s / n as f64).abs() >= observed.abs() {
            extreme += 1;
        }
    }
    // add-one smoothing keeps p > 0 (standard bootstrap practice)
    (extreme as f64 + 1.0) / (resamples as f64 + 1.0)
}

/// Welford online mean/variance — used by benchmark harnesses.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningStats {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1) = 1, Γ(2) = 1, Γ(5) = 24, Γ(0.5) = sqrt(pi)
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn chi2_sf_known_values() {
        // From standard tables: P(X >= 3.841 | df=1) = 0.05
        assert!((chi2_sf(3.841, 1.0) - 0.05).abs() < 1e-3);
        // P(X >= 18.307 | df=10) = 0.05
        assert!((chi2_sf(18.307, 10.0) - 0.05).abs() < 1e-3);
        // P(X >= df | large df) ~ 0.5-ish; check monotonicity instead
        assert!(chi2_sf(5.0, 10.0) > chi2_sf(15.0, 10.0));
        assert!((chi2_sf(0.0, 5.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chi_squared_accepts_exact_counts() {
        let probs = vec![0.25f64; 4];
        let counts = vec![250u64, 251, 249, 250];
        let p = chi_squared_pvalue(&counts, &probs, 1000);
        assert!(p > 0.9, "p={p}");
    }

    #[test]
    fn chi_squared_rejects_biased_counts() {
        let probs = vec![0.25f64; 4];
        let counts = vec![400u64, 200, 200, 200];
        let p = chi_squared_pvalue(&counts, &probs, 1000);
        assert!(p < 1e-6, "p={p}");
    }

    #[test]
    fn chi_squared_merges_tiny_bins() {
        // Many near-zero-probability bins must not blow up the statistic.
        let mut probs = vec![1e-6f64; 1000];
        probs[0] = 1.0 - 999e-6;
        let mut counts = vec![0u64; 1000];
        counts[0] = 10_000;
        let p = chi_squared_pvalue(&counts, &probs, 10_000);
        assert!(p > 0.01, "p={p}");
    }

    #[test]
    fn paired_bootstrap_null_not_rejected() {
        // identical accuracy vectors -> observed diff 0 -> p ~ 1
        let a: Vec<f64> = (0..500).map(|i| ((i * 7) % 10 < 9) as u8 as f64).collect();
        let p = paired_bootstrap_pvalue(&a, &a.clone(), 2000, 42);
        assert!(p > 0.9, "p={p}");
    }

    #[test]
    fn paired_bootstrap_detects_large_difference() {
        let a = vec![1.0f64; 300];
        let b = vec![0.0f64; 300];
        let p = paired_bootstrap_pvalue(&a, &b, 2000, 42);
        assert!(p < 0.01, "p={p}");
    }

    #[test]
    fn paired_bootstrap_small_noise_not_significant() {
        // a and b agree on 97% of items, disagreements balanced
        let mut a = vec![1.0f64; 400];
        let mut b = vec![1.0f64; 400];
        for i in 0..6 {
            a[i] = 0.0;
        }
        for i in 6..12 {
            b[i] = 0.0;
        }
        let p = paired_bootstrap_pvalue(&a, &b, 2000, 7);
        assert!(p > 0.5, "p={p}");
    }

    #[test]
    fn running_stats() {
        let mut s = RunningStats::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.count(), 8);
    }
}
