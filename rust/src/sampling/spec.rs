//! Typed sampler specifications — the parse-once form of the registry's
//! config-string grammar.
//!
//! A [`SamplerSpec`] is the single currency for sampler selection across
//! the serving stack: `EngineConfig`, the launcher `Config`, the TP
//! orchestrator strategies, the repro tables, and the benches all carry
//! this enum instead of raw strings.  Strings appear only at the system
//! boundary (config files, CLI `--set`), where they are parsed exactly
//! once via [`FromStr`]; [`fmt::Display`] renders the canonical string
//! back, and the two round-trip: `spec.to_string().parse() == spec` for
//! every valid spec.
//!
//! The legacy entry point [`crate::sampling::build_sampler`] remains as a
//! thin shim (`parse` + [`SamplerSpec::build`]) so existing config strings
//! like `"grouped:group=64"` keep constructing identical samplers.

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Context, Error, Result};

use super::{distributed, grouped, gumbel, multinomial, online, topk, ExactSampler};

/// Typed selection of one of the six paper samplers plus its parameters.
///
/// Parameter fields mirror the config-string grammar documented in the
/// [`crate::sampling`] module docs; defaults match what the bare registry
/// names construct.
#[derive(Clone, Debug, PartialEq)]
pub enum SamplerSpec {
    /// Alg. I.1 streaming Gumbel-Max; `tile = Some(t)` selects the
    /// two-stage tile decomposition (Lemma D.5).
    Gumbel { tile: Option<usize> },
    /// Alg. A.1 materialized-logits baseline.
    Multinomial,
    /// Alg. I.2 parallel Group-Gumbel-Max.
    Grouped { group: usize },
    /// Alg. I.3 online merge (Lemma D.3).
    Online { group: usize },
    /// Alg. I.4 distributed tensor-parallel merge.
    Distributed { ranks: usize },
    /// Gumbel-Top-k candidate reduction (App. D.6), with nucleus mass
    /// `top_p` applied on the reduced candidate set.
    TopK { k: usize, top_p: f32, tile: usize },
    /// Speculative decoding (DESIGN.md §9): draft `k` tokens with the
    /// order-`ngram` deterministic suffix drafter, verify them against the
    /// fused decode artifact with the Gumbel-coupled exact rule.  An
    /// **engine decode path**, not a per-row sampler — [`SamplerSpec::build`]
    /// rejects it; the coordinator dispatches on it instead
    /// (`coordinator::engine`).  Spec string: `specdec:k=4,ngram=3`.
    SpecDecode { k: usize, ngram: usize },
}

impl Default for SamplerSpec {
    /// The fused FlashSampling path (`"gumbel"`).
    fn default() -> Self {
        SamplerSpec::Gumbel { tile: None }
    }
}

impl SamplerSpec {
    /// Registry name (the string grammar's `<name>` head).
    pub fn name(&self) -> &'static str {
        match self {
            SamplerSpec::Gumbel { .. } => "gumbel",
            SamplerSpec::Multinomial => "multinomial",
            SamplerSpec::Grouped { .. } => "grouped",
            SamplerSpec::Online { .. } => "online",
            SamplerSpec::Distributed { .. } => "distributed",
            SamplerSpec::TopK { .. } => "topk",
            SamplerSpec::SpecDecode { .. } => "specdec",
        }
    }

    /// Check parameter ranges (the constructors of this enum are public,
    /// so a hand-built spec may carry values the parser would reject).
    pub fn validate(&self) -> Result<()> {
        match *self {
            SamplerSpec::Gumbel { tile: Some(0) } => {
                bail!("sampler spec 'gumbel': tile must be >= 1")
            }
            SamplerSpec::Grouped { group: 0 } | SamplerSpec::Online { group: 0 } => {
                bail!("sampler spec '{}': group must be >= 1", self.name())
            }
            SamplerSpec::Distributed { ranks: 0 } => {
                bail!("sampler spec 'distributed': ranks must be >= 1")
            }
            SamplerSpec::TopK { k, top_p, tile } => {
                if k == 0 || tile == 0 {
                    bail!("sampler spec 'topk': k and tile must be >= 1");
                }
                if !(top_p > 0.0 && top_p <= 1.0) {
                    bail!("sampler spec 'topk': p must be in (0, 1], got {top_p}");
                }
                Ok(())
            }
            SamplerSpec::SpecDecode { k, ngram } => {
                if k == 0 || ngram == 0 {
                    bail!("sampler spec 'specdec': k and ngram must be >= 1");
                }
                if k > 64 {
                    bail!("sampler spec 'specdec': k must be <= 64, got {k}");
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Construct the boxed [`ExactSampler`] this spec describes.
    pub fn build(&self) -> Result<Box<dyn ExactSampler>> {
        self.validate()?;
        Ok(match *self {
            SamplerSpec::Gumbel { tile } => {
                Box::new(gumbel::GumbelMaxSampler { tile_v: tile })
            }
            SamplerSpec::Multinomial => Box::new(multinomial::MultinomialSampler),
            SamplerSpec::Grouped { group } => {
                Box::new(grouped::GroupedSampler { group_size: group })
            }
            SamplerSpec::Online { group } => {
                Box::new(online::OnlineSampler { group_size: group })
            }
            SamplerSpec::Distributed { ranks } => {
                Box::new(distributed::DistributedSampler { n_ranks: ranks })
            }
            SamplerSpec::TopK { k, top_p, tile } => {
                Box::new(topk::GumbelTopKSampler { k, top_p, tile_v: tile })
            }
            SamplerSpec::SpecDecode { .. } => bail!(
                "sampler spec 'specdec' selects the engine's speculative \
                 decode path (coordinator), not a per-row ExactSampler"
            ),
        })
    }

    /// Is this spec served by an AOT decode artifact?  The fused
    /// FlashSampling path (`gumbel`) and the materialized-logits baseline
    /// (`multinomial`) have `decode_*` executables, and `specdec` runs the
    /// fused `decode_sample` artifact inside its coupled verify loop; the
    /// other four are host-side algorithms (TP leader, benches, repro).
    pub fn is_artifact_backed(&self) -> bool {
        matches!(
            self,
            SamplerSpec::Gumbel { .. }
                | SamplerSpec::Multinomial
                | SamplerSpec::SpecDecode { .. }
        )
    }

    /// Does this spec select the baseline (materialized-logits) decode
    /// artifact — the paper's §4.5 A/B switch?
    pub fn uses_baseline_artifact(&self) -> bool {
        matches!(self, SamplerSpec::Multinomial)
    }
}

impl fmt::Display for SamplerSpec {
    /// Canonical config-string form; [`FromStr`] inverts it exactly.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            SamplerSpec::Gumbel { tile: None } => write!(f, "gumbel"),
            SamplerSpec::Gumbel { tile: Some(t) } => write!(f, "gumbel:tile={t}"),
            SamplerSpec::Multinomial => write!(f, "multinomial"),
            SamplerSpec::Grouped { group } => write!(f, "grouped:group={group}"),
            SamplerSpec::Online { group } => write!(f, "online:group={group}"),
            SamplerSpec::Distributed { ranks } => {
                write!(f, "distributed:ranks={ranks}")
            }
            SamplerSpec::TopK { k, top_p, tile } => {
                write!(f, "topk:k={k},p={top_p},tile={tile}")
            }
            SamplerSpec::SpecDecode { k, ngram } => {
                write!(f, "specdec:k={k},ngram={ngram}")
            }
        }
    }
}

/// Key/value parameters split out of a sampler spec string.
struct SpecParams<'a> {
    spec: &'a str,
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> SpecParams<'a> {
    fn parse(spec: &'a str, params: Option<&'a str>) -> Result<Self> {
        let mut pairs: Vec<(&str, &str)> = Vec::new();
        if let Some(p) = params {
            for item in p.split(',') {
                let (k, v) = item.split_once('=').with_context(|| {
                    format!("sampler spec '{spec}': expected key=value, got '{item}'")
                })?;
                let (k, v) = (k.trim(), v.trim());
                if pairs.iter().any(|(seen, _)| *seen == k) {
                    bail!("sampler spec '{spec}': duplicate parameter '{k}'");
                }
                pairs.push((k, v));
            }
        }
        Ok(Self { spec, pairs })
    }

    fn has(&self, key: &str) -> bool {
        self.pairs.iter().any(|(k, _)| *k == key)
    }

    /// Range checks (e.g. zero rejection) live in [`SamplerSpec::validate`],
    /// the single enforcement point shared with hand-built specs.
    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.pairs.iter().find(|(k, _)| *k == key) {
            None => Ok(default),
            Some((_, v)) => v.parse().with_context(|| {
                format!("sampler spec '{}': bad {key}='{v}'", self.spec)
            }),
        }
    }

    fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.pairs.iter().find(|(k, _)| *k == key) {
            None => Ok(default),
            Some((_, v)) => v.parse().with_context(|| {
                format!("sampler spec '{}': bad {key}='{v}'", self.spec)
            }),
        }
    }

    /// Reject parameters no arm consumed (typo safety).
    fn check_known(&self, known: &[&str]) -> Result<()> {
        for (k, _) in &self.pairs {
            if !known.contains(k) {
                bail!(
                    "sampler spec '{}': unknown parameter '{k}' (known: {})",
                    self.spec,
                    known.join(", ")
                );
            }
        }
        Ok(())
    }
}

impl FromStr for SamplerSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let spec = s.trim();
        let (name, params) = match spec.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (spec, None),
        };
        let p = SpecParams::parse(spec, params)?;
        let parsed = match name {
            "gumbel" => {
                p.check_known(&["tile"])?;
                let tile = if p.has("tile") {
                    Some(p.get_usize("tile", 0)?)
                } else {
                    None
                };
                SamplerSpec::Gumbel { tile }
            }
            "multinomial" => {
                p.check_known(&[])?;
                SamplerSpec::Multinomial
            }
            "grouped" => {
                p.check_known(&["group"])?;
                SamplerSpec::Grouped {
                    group: p.get_usize("group", grouped::DEFAULT_GROUP)?,
                }
            }
            "online" => {
                p.check_known(&["group"])?;
                SamplerSpec::Online {
                    group: p.get_usize("group", grouped::DEFAULT_GROUP)?,
                }
            }
            "distributed" => {
                p.check_known(&["ranks"])?;
                SamplerSpec::Distributed {
                    ranks: p.get_usize("ranks", distributed::DEFAULT_RANKS)?,
                }
            }
            "topk" => {
                p.check_known(&["k", "p", "tile"])?;
                SamplerSpec::TopK {
                    k: p.get_usize("k", topk::DEFAULT_K)?,
                    top_p: p.get_f32("p", 1.0)?,
                    tile: p.get_usize("tile", topk::DEFAULT_TILE_V)?,
                }
            }
            "specdec" => {
                p.check_known(&["k", "ngram"])?;
                SamplerSpec::SpecDecode {
                    k: p.get_usize("k", crate::specdec::DEFAULT_K)?,
                    ngram: p.get_usize("ngram", crate::specdec::DEFAULT_NGRAM)?,
                }
            }
            // `specdec` is appended by hand: it is a valid spec name but
            // deliberately NOT in SAMPLER_NAMES (that list enumerates the
            // buildable per-row ExactSamplers; specdec never build()s —
            // the coordinator dispatches on it instead).
            other => bail!(
                "unknown sampler '{other}' (known: {}, specdec)",
                super::SAMPLER_NAMES.join(", ")
            ),
        };
        parsed.validate()?;
        Ok(parsed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip_is_identity() {
        // Every shape of spec, bare names included: parse -> Display ->
        // parse lands on the same typed value (the acceptance criterion).
        for s in [
            "gumbel",
            "gumbel:tile=2048",
            "multinomial",
            "grouped:group=64",
            "grouped",
            "online:group=17",
            "distributed:ranks=4",
            "topk",
            "topk:k=4,p=0.9",
            "topk:k=8,p=0.95,tile=128",
            "specdec",
            "specdec:k=8",
            "specdec:k=2,ngram=5",
        ] {
            let a: SamplerSpec = s.parse().unwrap();
            let b: SamplerSpec = a.to_string().parse().unwrap();
            assert_eq!(a, b, "round-trip broke for '{s}' -> '{a}'");
        }
    }

    #[test]
    fn display_is_canonical() {
        let a: SamplerSpec = " grouped : group = 64 ".parse().unwrap();
        assert_eq!(a.to_string(), "grouped:group=64");
        assert_eq!(SamplerSpec::default().to_string(), "gumbel");
        // Bare names render their defaults explicitly once parameters exist.
        let t: SamplerSpec = "topk".parse().unwrap();
        assert_eq!(t.to_string(), "topk:k=8,p=1,tile=2048");
        let s: SamplerSpec = "specdec".parse().unwrap();
        assert_eq!(s, SamplerSpec::SpecDecode { k: 4, ngram: 3 });
        assert_eq!(s.to_string(), "specdec:k=4,ngram=3");
    }

    /// Satellite: property-style round-trip over a generated grid of specs
    /// — every variant × parameter corners, `parse(display(s)) == s`.
    #[test]
    fn prop_roundtrip_over_generated_spec_grid() {
        let corners: [usize; 6] = [1, 2, 7, 63, 64, 2048];
        let masses: [f32; 5] = [0.1, 0.5, 0.9, 0.999, 1.0];
        crate::testutil::cases(256, 0x5EC5, |g| {
            let spec = match g.u32_in(0, 7) {
                0 => SamplerSpec::Gumbel { tile: None },
                1 => SamplerSpec::Gumbel { tile: Some(*g.choose(&corners)) },
                2 => SamplerSpec::Multinomial,
                3 => SamplerSpec::Grouped { group: *g.choose(&corners) },
                4 => SamplerSpec::Online { group: *g.choose(&corners) },
                5 => SamplerSpec::Distributed { ranks: *g.choose(&corners) },
                6 => SamplerSpec::TopK {
                    k: *g.choose(&corners),
                    top_p: *g.choose(&masses),
                    tile: *g.choose(&corners),
                },
                _ => SamplerSpec::SpecDecode {
                    k: *g.choose(&[1usize, 2, 7, 63, 64]),
                    ngram: *g.choose(&corners),
                },
            };
            spec.validate().expect("grid specs are in range");
            let rendered = spec.to_string();
            let reparsed: SamplerSpec =
                rendered.parse().unwrap_or_else(|e| {
                    panic!("'{rendered}' failed to re-parse: {e}")
                });
            assert_eq!(spec, reparsed, "round-trip broke for '{rendered}'");
        });
    }

    #[test]
    fn artifact_backed_classification() {
        assert!(SamplerSpec::default().is_artifact_backed());
        assert!(SamplerSpec::Multinomial.is_artifact_backed());
        assert!(SamplerSpec::Multinomial.uses_baseline_artifact());
        assert!(!SamplerSpec::default().uses_baseline_artifact());
        assert!(!SamplerSpec::Grouped { group: 64 }.is_artifact_backed());
        assert!(!SamplerSpec::TopK { k: 8, top_p: 1.0, tile: 2048 }
            .is_artifact_backed());
        // specdec runs the fused decode artifact inside its verify loop.
        let sd = SamplerSpec::SpecDecode { k: 4, ngram: 3 };
        assert!(sd.is_artifact_backed());
        assert!(!sd.uses_baseline_artifact());
    }

    #[test]
    fn hand_built_specs_are_validated_at_build() {
        assert!(SamplerSpec::Grouped { group: 0 }.build().is_err());
        assert!(SamplerSpec::Distributed { ranks: 0 }.build().is_err());
        assert!(SamplerSpec::TopK { k: 0, top_p: 1.0, tile: 1 }.build().is_err());
        assert!(SamplerSpec::TopK { k: 1, top_p: 0.0, tile: 1 }.build().is_err());
        assert!(SamplerSpec::Gumbel { tile: Some(0) }.build().is_err());
        assert!(SamplerSpec::Gumbel { tile: None }.build().is_ok());
    }

    #[test]
    fn specdec_spec_parses_validates_and_never_builds() {
        assert!("specdec:k=0".parse::<SamplerSpec>().is_err());
        assert!("specdec:ngram=0".parse::<SamplerSpec>().is_err());
        assert!("specdec:k=65".parse::<SamplerSpec>().is_err());
        assert!("specdec:wat=1".parse::<SamplerSpec>().is_err());
        let sd: SamplerSpec = "specdec:k=6,ngram=2".parse().unwrap();
        assert_eq!(sd, SamplerSpec::SpecDecode { k: 6, ngram: 2 });
        assert_eq!(sd.name(), "specdec");
        assert!(sd.validate().is_ok());
        // An engine decode path, not a per-row sampler.
        let err = sd.build().unwrap_err();
        assert!(err.to_string().contains("speculative"), "{err}");
    }
}
