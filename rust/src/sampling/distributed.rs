//! Distributed FlashSampling merge for tensor-parallel vocabularies
//! (paper Algorithm I.4, §D.2).
//!
//! Each rank holds a vocabulary shard and reports an O(1)-per-row summary;
//! the coordinator merges them.  Two exact merge modes:
//!
//! * **Pathwise** (`merge_pathwise`) — ranks report `(max perturbed score,
//!   global argmax)`; because Philox positions are global, a max-merge is
//!   bit-identical to a single-device FlashSampling pass (Lemma D.5 over
//!   the shard partition).  This is the per-tile P2P fan-out payload of
//!   Algorithm 1's multi-GPU path.
//! * **Distributional** (`merge_by_mass`) — ranks report `(local exact
//!   sample, shard log-mass)`; the coordinator runs an outer Gumbel-Max over
//!   shard masses with fresh Gumbels (Algorithm I.4 line 3).  Exact by
//!   Theorem D.4; requires only the shard masses, not shard maxima.

use super::philox::{self, Key};
use super::{Draw, ExactSampler, RowCtx};

/// Default tensor-parallel degree of the registry's `distributed` spec.
pub const DEFAULT_RANKS: usize = 8;

/// One rank's per-row summary (the wire format of the simulated NVLink
/// fan-out in `crate::tp`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSummary {
    /// Rank id (= group index k in the hierarchical factorization).
    pub rank: u32,
    /// Max perturbed score within the shard (pathwise payload).
    pub max_score: f32,
    /// Global vocab index attaining `max_score` — also the rank's exact
    /// local sample (Gumbel-Max within the shard).
    pub local_sample: u32,
    /// Shard log-mass L_k = logsumexp(shard logits).
    pub log_mass: f32,
}

/// Pathwise merge: argmax over shard maxima (identical to single-rank).
///
/// Returns `None` on empty input.  Tie-break: lowest rank first, matching
/// the monolithic scan's first-index preference.
pub fn merge_pathwise(summaries: &[ShardSummary]) -> Option<ShardSummary> {
    summaries
        .iter()
        .copied()
        .reduce(|a, b| if b.max_score > a.max_score { b } else { a })
}

/// Distribution-level merge: outer Gumbel-Max over shard log-masses with
/// fresh Gumbels on the GROUP_SELECT stream (counter = rank id).
///
/// Zero-mass shards (log_mass = -inf) never win (§D.1).
pub fn merge_by_mass(
    summaries: &[ShardSummary],
    key: Key,
    row: u32,
    step: u32,
) -> Option<ShardSummary> {
    summaries
        .iter()
        .filter(|s| s.log_mass > f32::NEG_INFINITY)
        .map(|&s| {
            let g = philox::gumbel_group_select(key, s.rank, row, step);
            (s.log_mass + g, s)
        })
        .reduce(|a, b| if b.0 > a.0 { b } else { a })
        .map(|(_, s)| s)
}

/// log_Z over all shards (Appendix L, from the same O(n) summaries).
pub fn log_z(summaries: &[ShardSummary]) -> f32 {
    let masses: Vec<f32> = summaries.iter().map(|s| s.log_mass).collect();
    super::log_sum_exp(&masses)
}

/// Compute one rank's summary from its shard logits, Rust-native (the AOT
/// shard kernel computes the same thing on the XLA side).
///
/// `shard_offset` is the shard's starting global vocab index.
pub fn shard_summary(
    rank: u32,
    shard_logits: &[f32],
    shard_offset: usize,
    transform: &super::Transform,
    key: Key,
    row: u32,
    step: u32,
) -> ShardSummary {
    let mut best = f32::NEG_INFINITY;
    let mut best_i = shard_offset as u32;
    let mut transformed = Vec::with_capacity(shard_logits.len());
    for (j, &l) in shard_logits.iter().enumerate() {
        let i = shard_offset + j;
        let y = transform.apply(l, i);
        transformed.push(y);
        if y == f32::NEG_INFINITY {
            continue;
        }
        let s = y + philox::gumbel_at(key, i as u32, row, step);
        if s > best {
            best = s;
            best_i = i as u32;
        }
    }
    ShardSummary {
        rank,
        max_score: best,
        local_sample: best_i,
        log_mass: super::log_sum_exp(&transformed),
    }
}

/// [`ExactSampler`] adapter over Algorithm I.4 — registry name
/// `distributed`.  Shards the row into `n_ranks` contiguous vocabulary
/// shards, computes each rank's O(1) summary, and runs the distributional
/// (mass) merge on the leader.  Spec example: `"distributed:ranks=4"`.
#[derive(Clone, Copy, Debug)]
pub struct DistributedSampler {
    /// Simulated tensor-parallel degree (number of vocabulary shards).
    pub n_ranks: usize,
}

impl Default for DistributedSampler {
    fn default() -> Self {
        Self { n_ranks: DEFAULT_RANKS }
    }
}

impl ExactSampler for DistributedSampler {
    fn name(&self) -> &'static str {
        "distributed"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        // Contiguous shards of ceil(V / n) positions (the last may be
        // short) — global Philox positions keep shard samples reproducible
        // across regroupings, exactly like the rank kernels.
        let vs = logits.len().div_ceil(self.n_ranks).max(1);
        let summaries: Vec<ShardSummary> = logits
            .chunks(vs)
            .enumerate()
            .map(|(r, shard)| {
                shard_summary(
                    r as u32,
                    shard,
                    r * vs,
                    ctx.transform,
                    ctx.key,
                    ctx.row,
                    ctx.step,
                )
            })
            .collect();
        let lz = log_z(&summaries);
        merge_by_mass(&summaries, ctx.key, ctx.row, ctx.step)
            .map(|w| Draw { index: w.local_sample, log_z: Some(lz) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{gumbel, log_sum_exp, Transform};
    use crate::testutil;

    fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
        let key = Key::from_seed(seed ^ 0xD157);
        (0..n)
            .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect()
    }

    fn shards(l: &[f32], n_ranks: usize, key: Key, row: u32, step: u32) -> Vec<ShardSummary> {
        let t = Transform::default();
        let vs = l.len() / n_ranks;
        (0..n_ranks)
            .map(|r| {
                shard_summary(
                    r as u32,
                    &l[r * vs..(r + 1) * vs],
                    r * vs,
                    &t,
                    key,
                    row,
                    step,
                )
            })
            .collect()
    }

    #[test]
    fn pathwise_merge_equals_single_rank() {
        let l = toy_logits(512, 3);
        let key = Key::new(8, 9);
        for n in [2usize, 4, 8] {
            for step in 0..10 {
                let mono = gumbel::sample_row(&l, &Transform::default(), key, 0, step)
                    .unwrap();
                let merged = merge_pathwise(&shards(&l, n, key, 0, step)).unwrap();
                assert_eq!(merged.local_sample, mono.index, "n={n} step={step}");
                assert_eq!(merged.max_score, mono.score);
            }
        }
    }

    #[test]
    fn log_z_from_shards_is_exact() {
        let l = toy_logits(256, 4);
        let s = shards(&l, 4, Key::new(1, 1), 0, 0);
        assert!((log_z(&s) - log_sum_exp(&l)).abs() < 1e-4);
    }

    #[test]
    fn zero_mass_shard_never_wins_mass_merge() {
        let s = vec![
            ShardSummary { rank: 0, max_score: 1.0, local_sample: 3, log_mass: 0.0 },
            ShardSummary {
                rank: 1,
                max_score: f32::NEG_INFINITY,
                local_sample: 99,
                log_mass: f32::NEG_INFINITY,
            },
        ];
        for step in 0..50 {
            let w = merge_by_mass(&s, Key::new(5, 5), 0, step).unwrap();
            assert_eq!(w.rank, 0);
        }
    }

    /// The trait adapter's shard/merge pipeline is pathwise identical to
    /// assembling the shard summaries by hand.
    #[test]
    fn trait_adapter_matches_manual_merge() {
        let l = toy_logits(512, 13);
        let key = Key::new(31, 32);
        let t = Transform::default();
        let sampler = DistributedSampler { n_ranks: 4 };
        for step in 0..20 {
            let ctx = RowCtx { transform: &t, key, row: 0, step };
            let via_trait = sampler.sample_row(&l, ctx).unwrap();
            let s = shards(&l, 4, key, 0, step);
            let manual = merge_by_mass(&s, key, 0, step).unwrap();
            assert_eq!(via_trait.index, manual.local_sample);
            assert_eq!(via_trait.log_z, Some(log_z(&s)));
        }
    }

    /// Chi-squared: the distributional merge produces the exact categorical.
    #[test]
    fn mass_merge_distribution_exact() {
        let v = 64;
        let l = toy_logits(v, 11);
        let t = Transform::default();
        let p = super::super::multinomial::probs(&l, &t);
        let n = 40_000u32;
        let key = Key::new(0xC0, 0xDE);
        let mut counts = vec![0u64; v];
        for step in 0..n {
            let s = shards(&l, 4, key, 0, step);
            let w = merge_by_mass(&s, key, 0, step).unwrap();
            counts[w.local_sample as usize] += 1;
        }
        let pval = super::super::stats::chi_squared_pvalue(&counts, &p, n as u64);
        assert!(pval > 1e-3, "Alg I.4 GoF rejected: p={pval}");
    }

    /// Pathwise merge is shard-count invariant (Lemma D.5).
    #[test]
    fn prop_pathwise_shard_invariance() {
        testutil::cases(64, 0x81, |g| {
            let n_ranks = 1usize << g.u32_in(1, 3); // 2, 4, 8
            let seed = g.u64();
            let step = g.u32_in(0, 500);
            let l = toy_logits(512, seed);
            let key = Key::from_seed(seed);
            let mono = gumbel::sample_row(&l, &Transform::default(), key, 0, step)
                .unwrap();
            let merged = merge_pathwise(&shards(&l, n_ranks, key, 0, step)).unwrap();
            assert_eq!(merged.local_sample, mono.index);
        });
    }

    /// The payload is O(1) per rank: merging loses no exactness however
    /// the vocab splits (log_Z bookkeeping check).
    #[test]
    fn prop_mass_bookkeeping() {
        testutil::cases(64, 0x82, |g| {
            let n_ranks = g.usize_in(1, 8);
            let seed = g.u64();
            let l = toy_logits(504, seed);
            let vs = l.len() / n_ranks;
            let l = &l[..vs * n_ranks];
            let s = shards(l, n_ranks, Key::from_seed(seed), 0, 0);
            assert!((log_z(&s) - log_sum_exp(l)).abs() < 1e-3);
        });
    }
}
