//! Streaming Gumbel-Max sampler (paper Algorithm I.1).
//!
//! One pass over the logits, keeping only `(best score, best index)` — the
//! online-normalizer-style state that makes epilogue fusion practical
//! (paper §3.1).  With the shared Philox streams this is *pathwise*
//! identical to the Pallas kernel's output for the same `(seed, step)`.

use super::philox::{self, Key};
use super::{Draw, ExactSampler, RowCtx, Transform};

/// Result of a Gumbel-Max pass over one row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GumbelMax {
    /// The exact sample: argmax_i (logit_i + g_i).
    pub index: u32,
    /// The winning perturbed score max_i (logit_i + g_i).
    pub score: f32,
}

/// Streaming Gumbel-Max over one row of logits (Alg. I.1).
///
/// `row` is the batch index b (selects the Philox stream); `step` the decode
/// step.  Returns `None` if every transformed logit is `-inf` (undefined
/// target distribution — the caller must treat this as an error).
pub fn sample_row(
    logits: &[f32],
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
) -> Option<GumbelMax> {
    // Chunked: generate Gumbels for a tile of positions at once (lets the
    // Philox pipelines overlap — §Perf L3), then reduce the tile.
    const CHUNK: usize = 512;
    let mut noise = [0.0f32; CHUNK];
    let mut best = f32::NEG_INFINITY;
    let mut best_i: i64 = -1;
    let mut base = 0usize;
    for chunk in logits.chunks(CHUNK) {
        philox::gumbel_row(key, row, step, base as u32, &mut noise[..chunk.len()]);
        for (j, &l) in chunk.iter().enumerate() {
            let i = base + j;
            let y = transform.apply(l, i);
            if y == f32::NEG_INFINITY {
                continue; // zero-mass category: can never win
            }
            let s = y + noise[j];
            if s > best {
                best = s;
                best_i = i as i64;
            }
        }
        base += chunk.len();
    }
    (best_i >= 0).then(|| GumbelMax { index: best_i as u32, score: best })
}

/// Gumbel-Max over a batch of rows `[B, V]` (row-major).
pub fn sample_batch(
    logits: &[f32],
    vocab: usize,
    transform: &Transform,
    key: Key,
    step: u32,
) -> Vec<Option<GumbelMax>> {
    assert_eq!(logits.len() % vocab, 0);
    logits
        .chunks_exact(vocab)
        .enumerate()
        .map(|(b, row)| sample_row(row, transform, key, b as u32, step))
        .collect()
}

/// Tile-decomposed Gumbel-Max: Stage 1 + Stage 2 of Algorithm 1, on the CPU.
///
/// Splits the row into `tile_v`-sized vocabulary tiles, reduces each tile to
/// a local `(max, argmax)` candidate, then argmaxes over candidates.  By
/// Lemma D.5 this returns the identical sample to [`sample_row`] — asserted
/// by proptest in this module's tests.  (This is the reference model of the
/// fused kernel's two-stage structure, used by the TP orchestrator to merge
/// per-rank candidates.)
pub fn sample_row_tiled(
    logits: &[f32],
    transform: &Transform,
    key: Key,
    row: u32,
    step: u32,
    tile_v: usize,
) -> Option<GumbelMax> {
    assert!(tile_v > 0);
    let mut candidates: Vec<GumbelMax> = Vec::with_capacity(logits.len().div_ceil(tile_v));
    for (t, tile) in logits.chunks(tile_v).enumerate() {
        let base = t * tile_v;
        let mut best = f32::NEG_INFINITY;
        let mut best_i: i64 = -1;
        for (j, &l) in tile.iter().enumerate() {
            let i = base + j;
            let y = transform.apply(l, i);
            if y == f32::NEG_INFINITY {
                continue;
            }
            let s = y + philox::gumbel_at(key, i as u32, row, step);
            if s > best {
                best = s;
                best_i = i as i64;
            }
        }
        if best_i >= 0 {
            candidates.push(GumbelMax { index: best_i as u32, score: best });
        }
    }
    // Stage 2: argmax over the candidate buffer (first max wins, matching
    // the monolithic scan's first-index tie-break).
    candidates
        .into_iter()
        .reduce(|a, b| if b.score > a.score { b } else { a })
}

/// [`ExactSampler`] adapter over Algorithm I.1 — registry name `gumbel`.
///
/// `tile_v = None` runs the monolithic streaming scan ([`sample_row`]);
/// `tile_v = Some(t)` runs the two-stage tile decomposition
/// ([`sample_row_tiled`]), which by Lemma D.5 returns the identical sample.
/// Spec examples: `"gumbel"`, `"gumbel:tile=2048"`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GumbelMaxSampler {
    /// Vocabulary tile size; `None` = monolithic streaming scan.
    pub tile_v: Option<usize>,
}

impl ExactSampler for GumbelMaxSampler {
    fn name(&self) -> &'static str {
        "gumbel"
    }

    fn sample_row(&self, logits: &[f32], ctx: RowCtx<'_>) -> Option<Draw> {
        let result = match self.tile_v {
            Some(t) => sample_row_tiled(
                logits,
                ctx.transform,
                ctx.key,
                ctx.row,
                ctx.step,
                t,
            ),
            None => sample_row(logits, ctx.transform, ctx.key, ctx.row, ctx.step),
        };
        result.map(|g| Draw { index: g.index, log_z: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
        // Deterministic pseudo-logits via Philox itself (any values work).
        let key = Key::from_seed(seed ^ 0xABCD);
        (0..n)
            .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
            .collect()
    }

    #[test]
    fn deterministic_given_seed() {
        let l = toy_logits(100, 1);
        let t = Transform::default();
        let a = sample_row(&l, &t, Key::new(1, 2), 0, 0).unwrap();
        let b = sample_row(&l, &t, Key::new(1, 2), 0, 0).unwrap();
        assert_eq!(a, b);
        let c = sample_row(&l, &t, Key::new(1, 3), 0, 0).unwrap();
        // different key virtually surely differs somewhere over repeats
        let _ = c;
    }

    #[test]
    fn all_masked_returns_none() {
        let l = vec![1.0f32; 8];
        let t = Transform { temperature: 1.0, bias: Some(vec![f32::NEG_INFINITY; 8]) };
        assert!(sample_row(&l, &t, Key::new(0, 0), 0, 0).is_none());
    }

    #[test]
    fn mask_restricts_support() {
        let l = toy_logits(64, 2);
        let mut bias = vec![f32::NEG_INFINITY; 64];
        for i in 10..20 {
            bias[i] = 0.0;
        }
        let t = Transform { temperature: 1.0, bias: Some(bias) };
        for step in 0..50 {
            let s = sample_row(&l, &t, Key::new(7, 8), 0, step).unwrap();
            assert!((10..20).contains(&(s.index as usize)));
        }
    }

    #[test]
    fn rows_draw_distinct_streams() {
        let l = toy_logits(512, 3);
        let t = Transform::default();
        let k = Key::new(5, 5);
        let a = sample_row(&l, &t, k, 0, 0).unwrap();
        let b = sample_row(&l, &t, k, 1, 0).unwrap();
        // scores essentially never equal across independent streams
        assert_ne!(a.score, b.score);
    }

    /// Lemma D.5: tiled two-stage == monolithic, for any tiling (property).
    #[test]
    fn prop_tile_decomposition_is_exact() {
        testutil::cases(128, 0xD5, |g| {
            let n = g.usize_in(1, 400);
            let tile_v = g.usize_in(1, 96);
            let seed = g.u64();
            let step = g.u32_in(0, 1000);
            let l = toy_logits(n, seed);
            let t = Transform::default();
            let key = Key::from_seed(seed);
            let mono = sample_row(&l, &t, key, 0, step);
            let tiled = sample_row_tiled(&l, &t, key, 0, step, tile_v);
            assert_eq!(mono, tiled);
        });
    }

    /// Temperature never changes the support, only the distribution.
    #[test]
    fn prop_temperature_keeps_index_in_range() {
        testutil::cases(64, 0x7A0, |g| {
            let n = g.usize_in(2, 200);
            let tau = g.f32_in(0.05, 5.0);
            let seed = g.u64();
            let l = toy_logits(n, seed);
            let t = Transform::with_temperature(tau);
            let s = sample_row(&l, &t, Key::from_seed(seed), 0, 0).unwrap();
            assert!((s.index as usize) < n);
        });
    }
}
