//! Workload generation: Poisson open-loop arrivals + prompt/output length
//! distributions, mirroring the paper's §4.5 protocol (`vllm bench sweep
//! serve` with `--request-rate=B` Poisson arrivals and AIME-style
//! long-generation prompts).

use crate::sampling::philox::{self, Key};

/// One synthetic request: arrival offset + prompt + output budget.
#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub id: u64,
    /// Arrival time offset from run start, seconds.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
}

/// Length distribution of prompts/outputs.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// Fixed length.
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// AIME-like: short prompt, long reasoning output (the paper's §4.5
    /// dataset shape): prompt Uniform(lo,hi), used for outputs too.
    Aime,
}

impl LengthDist {
    fn draw(self, u: f32) -> usize {
        match self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform(lo, hi) => {
                lo + ((hi - lo + 1) as f32 * u) as usize
            }
            // AIME problems: prompts ~40-120 tokens.
            LengthDist::Aime => 40 + (81.0 * u) as usize,
        }
    }
}

/// Open-loop Poisson workload generator (deterministic via Philox).
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    pub key: Key,
    /// Mean request rate (req/s).  The paper sets rate = concurrency B.
    pub rate: f64,
    pub prompt_len: LengthDist,
    pub output_len: LengthDist,
    pub vocab: usize,
    pub temperature: f32,
    /// Non-empty: each request draws its temperature uniformly from this
    /// set instead of using `temperature` — models a mixed client
    /// population (the workload the per-row tau ABI exists for).
    pub temperature_choices: Vec<f32>,
}

impl WorkloadGen {
    pub fn new(seed: u64, rate: f64, vocab: usize) -> Self {
        Self {
            key: Key::from_seed(seed),
            rate,
            prompt_len: LengthDist::Aime,
            output_len: LengthDist::Uniform(32, 96),
            vocab,
            temperature: 1.0,
            temperature_choices: Vec::new(),
        }
    }

    fn u(&self, stream: u32, i: u32, b: u32) -> f32 {
        philox::uniform_at(self.key, i, b, stream, 0)
    }

    /// Generate `n` requests with exponential inter-arrival gaps
    /// (a Poisson process at `self.rate`).
    pub fn generate(&self, n: usize) -> Vec<RequestSpec> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for i in 0..n as u32 {
            // Exponential gap: -ln(u)/rate.
            let gap = -(self.u(10, i, 0) as f64).ln() / self.rate;
            t += gap;
            let plen = self.prompt_len.draw(self.u(11, i, 0)).max(1);
            let olen = self.output_len.draw(self.u(12, i, 0)).max(1);
            let prompt: Vec<i32> = (0..plen as u32)
                .map(|j| {
                    (self.u(13, i, j) * self.vocab as f32) as i32
                        % self.vocab as i32
                })
                .collect();
            let temperature = if self.temperature_choices.is_empty() {
                self.temperature
            } else {
                let n = self.temperature_choices.len();
                let j = ((self.u(14, i, 0) * n as f32) as usize).min(n - 1);
                self.temperature_choices[j]
            };
            out.push(RequestSpec {
                id: i as u64,
                arrival_s: t,
                prompt,
                max_new_tokens: olen,
                temperature,
            });
        }
        out
    }
}

/// A recorded trace (for replay in benches): (arrival_s, prompt_len,
/// output_len) triples, serialized as CSV.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub entries: Vec<(f64, usize, usize)>,
}

impl Trace {
    pub fn from_requests(reqs: &[RequestSpec]) -> Self {
        Self {
            entries: reqs
                .iter()
                .map(|r| (r.arrival_s, r.prompt.len(), r.max_new_tokens))
                .collect(),
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("arrival_s,prompt_len,output_len\n");
        for (a, p, o) in &self.entries {
            s.push_str(&format!("{a:.6},{p},{o}\n"));
        }
        s
    }

    pub fn from_csv(text: &str) -> anyhow::Result<Self> {
        let mut entries = Vec::new();
        for line in text.lines().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split(',');
            let a: f64 = it.next().unwrap_or("").trim().parse()?;
            let p: usize = it.next().unwrap_or("").trim().parse()?;
            let o: usize = it.next().unwrap_or("").trim().parse()?;
            entries.push((a, p, o));
        }
        Ok(Self { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let g = WorkloadGen::new(7, 20.0, 2048);
        let reqs = g.generate(4000);
        let span = reqs.last().unwrap().arrival_s;
        let observed_rate = reqs.len() as f64 / span;
        assert!(
            (observed_rate - 20.0).abs() / 20.0 < 0.08,
            "rate {observed_rate}"
        );
        // arrivals strictly increasing
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = WorkloadGen::new(1, 5.0, 128).generate(50);
        let b = WorkloadGen::new(1, 5.0, 128).generate(50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
        let c = WorkloadGen::new(2, 5.0, 128).generate(50);
        assert_ne!(a[0].prompt, c[0].prompt);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let g = WorkloadGen::new(3, 1.0, 100);
        for r in g.generate(200) {
            assert!(!r.prompt.is_empty());
            assert!(r.prompt.iter().all(|&t| (0..100).contains(&t)));
            assert!(r.max_new_tokens >= 1);
        }
    }

    #[test]
    fn length_dists() {
        assert_eq!(LengthDist::Fixed(7).draw(0.9), 7);
        for u in [0.0f32, 0.5, 0.999] {
            let v = LengthDist::Uniform(10, 20).draw(u);
            assert!((10..=20).contains(&v));
            let a = LengthDist::Aime.draw(u);
            assert!((40..=121).contains(&a));
        }
    }

    #[test]
    fn temperature_choices_mix_the_population() {
        let mut g = WorkloadGen::new(11, 5.0, 128);
        g.temperature_choices = vec![0.5, 1.0, 2.0];
        let reqs = g.generate(120);
        for r in &reqs {
            assert!(g.temperature_choices.contains(&r.temperature));
        }
        // All three temperatures appear (deterministically, given the seed).
        for want in &g.temperature_choices {
            assert!(
                reqs.iter().any(|r| r.temperature == *want),
                "temperature {want} never drawn"
            );
        }
    }

    #[test]
    fn trace_csv_roundtrip() {
        let g = WorkloadGen::new(5, 2.0, 64);
        let t = Trace::from_requests(&g.generate(20));
        let back = Trace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(t.entries.len(), back.entries.len());
        for (a, b) in t.entries.iter().zip(&back.entries) {
            assert!((a.0 - b.0).abs() < 1e-5);
            assert_eq!(a.1, b.1);
            assert_eq!(a.2, b.2);
        }
    }
}
