//! Workload generation: Poisson open-loop arrivals + prompt/output length
//! distributions, mirroring the paper's §4.5 protocol (`vllm bench sweep
//! serve` with `--request-rate=B` Poisson arrivals and AIME-style
//! long-generation prompts).

use crate::coordinator::request::Priority;
use crate::sampling::philox::{self, Key};

/// One synthetic request: arrival offset + prompt + output budget.
#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub id: u64,
    /// Arrival time offset from run start, seconds.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Scheduling priority (uniform `Normal` unless
    /// [`WorkloadGen::priority_choices`] is set).
    pub priority: Priority,
    /// Stable conversation identity: every turn of one multi-turn stream
    /// carries the same id (the session-affinity tag the router's
    /// prefix-affinity dispatch and bench key on).  `Some(user)` in
    /// shared-prefix mode, `None` for i.i.d. traffic.
    pub session_id: Option<u64>,
}

/// Length distribution of prompts/outputs.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// Fixed length.
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// AIME-like: short prompt, long reasoning output (the paper's §4.5
    /// dataset shape): prompt Uniform(lo,hi), used for outputs too.
    Aime,
}

impl LengthDist {
    fn draw(self, u: f32) -> usize {
        match self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform(lo, hi) => {
                lo + ((hi - lo + 1) as f32 * u) as usize
            }
            // AIME problems: prompts ~40-120 tokens.
            LengthDist::Aime => 40 + (81.0 * u) as usize,
        }
    }
}

/// Token-content distribution for generated prompt tokens.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TokenMode {
    /// Uniform over the vocabulary (the historic default — bit-identical
    /// draws to the pre-`TokenMode` generator).
    Uniform,
    /// Skewed-unigram (Zipf-ranked) emissions with exponent `s > 0`:
    /// token id doubles as rank, so id `r` is drawn with probability
    /// `∝ 1/(r+1)^s` — low ids are hot, concentrating traffic in the low
    /// vocab tiles.  This is the workload shape the certified sub-vocab
    /// decode head exists for (DESIGN.md §16): real LM unigram
    /// distributions are Zipfian, uniform ones are its adversary.
    Zipf { s: f64 },
}

/// Continuous bounded-Zipf inverse CDF over ranks `1..=vocab`, mapped to
/// token ids `0..vocab`.  An O(1) approximation of the discrete Zipf draw
/// (no per-call harmonic sums), monotone in `u` and exact at both ends.
fn zipf_token(u: f64, vocab: usize, s: f64) -> i32 {
    let v = vocab as f64;
    let x = if (s - 1.0).abs() < 1e-9 {
        // s = 1: CDF ~ ln(x)/ln(V).
        (u * v.ln()).exp()
    } else {
        (u * (v.powf(1.0 - s) - 1.0) + 1.0).powf(1.0 / (1.0 - s))
    };
    ((x.floor() as i64) - 1).clamp(0, vocab as i64 - 1) as i32
}

/// Shared-prefix / multi-turn traffic shape (the workload automatic
/// prefix caching exists for, DESIGN.md §10): `num_prefixes` distinct
/// system prompts served to `users` concurrent users, each user pinned to
/// one system prompt and holding a growing conversation history.
///
/// Request `i` belongs to user `i % users` at turn `i / users`; its prompt
/// is `system prompt ++ turns 0..=turn of that user's history`, so
/// consecutive turns of one user share the *entire* previous prompt as a
/// prefix, and users of the same system prompt share at least
/// `prefix_len` tokens — both reusable block-for-block by the prefix
/// cache.  Every spec is tagged `session_id = Some(user)` — the stable
/// per-conversation identity the router's prefix-affinity dispatch (and
/// `benches/router.rs`) group turns by.
#[derive(Clone, Debug)]
pub struct SharedPrefix {
    /// Distinct system prompts (deterministic token content per index).
    pub num_prefixes: usize,
    /// Tokens per system prompt.
    pub prefix_len: usize,
    /// Concurrent users; user `u` is pinned to system prompt
    /// `u % num_prefixes`.
    pub users: usize,
    /// Tokens each conversation turn appends to the user's history.
    pub turn_len: LengthDist,
}

/// Open-loop Poisson workload generator (deterministic via Philox).
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    pub key: Key,
    /// Mean request rate (req/s).  The paper sets rate = concurrency B.
    pub rate: f64,
    pub prompt_len: LengthDist,
    pub output_len: LengthDist,
    pub vocab: usize,
    pub temperature: f32,
    /// Non-empty: each request draws its temperature uniformly from this
    /// set instead of using `temperature` — models a mixed client
    /// population (the workload the per-row tau ABI exists for).
    pub temperature_choices: Vec<f32>,
    /// Non-empty: each request draws its scheduling priority uniformly
    /// from this set (stream 15) — models mixed-SLO traffic for the
    /// priority scheduler.  Empty: uniform `Normal` (identity-neutral).
    pub priority_choices: Vec<Priority>,
    /// `Some`: prompts follow the shared-prefix / multi-turn shape
    /// instead of drawing `prompt_len` of i.i.d. tokens (arrivals, output
    /// budgets, and temperatures keep their usual streams, so flipping
    /// this on changes prompt *content* only).
    pub prefix_mode: Option<SharedPrefix>,
    /// `Some((every, len))`: every `every`-th request (those with
    /// `i % every == every - 1`) gets a fixed `len`-token "monopolist"
    /// prompt — the adversarial long-prompt traffic chunked prefill
    /// exists for (DESIGN.md §12).  All other requests draw exactly what
    /// they would with the knob off (counter-based Philox streams, so the
    /// skipped length draw shifts nothing), and arrivals, output budgets,
    /// temperatures, and priorities are untouched for every request.
    /// Ignored in `prefix_mode`.
    pub long_prompt_every: Option<(usize, usize)>,
    /// Prompt token-content distribution.  [`TokenMode::Uniform`]
    /// (default) reproduces the historic generator bit-for-bit;
    /// [`TokenMode::Zipf`] skews emissions toward low token ids.  Each
    /// token still consumes exactly one draw from the same stream, so
    /// flipping the mode changes token *values* only — arrivals, lengths,
    /// budgets, temperatures, and priorities are untouched.
    pub token_mode: TokenMode,
}

impl WorkloadGen {
    pub fn new(seed: u64, rate: f64, vocab: usize) -> Self {
        Self {
            key: Key::from_seed(seed),
            rate,
            prompt_len: LengthDist::Aime,
            output_len: LengthDist::Uniform(32, 96),
            vocab,
            temperature: 1.0,
            temperature_choices: Vec::new(),
            priority_choices: Vec::new(),
            prefix_mode: None,
            long_prompt_every: None,
            token_mode: TokenMode::Uniform,
        }
    }

    fn u(&self, stream: u32, i: u32, b: u32) -> f32 {
        philox::uniform_at(self.key, i, b, stream, 0)
    }

    fn token(&self, stream: u32, i: u32, j: u32) -> i32 {
        let u = self.u(stream, i, j);
        match self.token_mode {
            TokenMode::Uniform => {
                (u * self.vocab as f32) as i32 % self.vocab as i32
            }
            TokenMode::Zipf { s } => zipf_token(u as f64, self.vocab, s),
        }
    }

    /// The shared-prefix prompt of request `i` (see [`SharedPrefix`]).
    /// Streams 20/21/22 keep these draws disjoint from the default mode's.
    fn shared_prefix_prompt(&self, sp: &SharedPrefix, i: u32) -> Vec<i32> {
        let user = i as usize % sp.users.max(1);
        let turn = i as usize / sp.users.max(1);
        let sys = (user % sp.num_prefixes.max(1)) as u32;
        let mut prompt: Vec<i32> = (0..sp.prefix_len as u32)
            .map(|j| self.token(20, sys, j))
            .collect();
        for t in 0..=turn {
            // Per-(user, turn) history chunk; the counter packs user and
            // turn so every chunk draws an independent stream.
            let idx = (user as u32) * 1024 + t as u32;
            let chunk = sp.turn_len.draw(self.u(22, idx, 0)).max(1);
            for j in 0..chunk as u32 {
                prompt.push(self.token(21, idx, j));
            }
        }
        prompt
    }

    /// Request `i` of the arrival process; `t` carries the running
    /// arrival clock (the exponential gaps accumulate across calls).
    fn spec_at(&self, i: u32, t: &mut f64) -> RequestSpec {
        // Exponential gap: -ln(u)/rate.
        let gap = -(self.u(10, i, 0) as f64).ln() / self.rate;
        *t += gap;
        let olen = self.output_len.draw(self.u(12, i, 0)).max(1);
        // Session-affinity tagging is free of Philox draws: the session id
        // IS the shared-prefix user index, so turning it on (or reading it)
        // cannot perturb any other stream.
        let session_id = self
            .prefix_mode
            .as_ref()
            .map(|sp| (i as usize % sp.users.max(1)) as u64);
        let prompt: Vec<i32> = match &self.prefix_mode {
            Some(sp) => self.shared_prefix_prompt(sp, i),
            None => {
                let plen = match self.long_prompt_every {
                    Some((every, len))
                        if every > 0 && i as usize % every == every - 1 =>
                    {
                        len.max(1)
                    }
                    _ => self.prompt_len.draw(self.u(11, i, 0)).max(1),
                };
                (0..plen as u32).map(|j| self.token(13, i, j)).collect()
            }
        };
        let temperature = if self.temperature_choices.is_empty() {
            self.temperature
        } else {
            let n = self.temperature_choices.len();
            let j = ((self.u(14, i, 0) * n as f32) as usize).min(n - 1);
            self.temperature_choices[j]
        };
        let priority = if self.priority_choices.is_empty() {
            Priority::default()
        } else {
            let n = self.priority_choices.len();
            let j = ((self.u(15, i, 0) * n as f32) as usize).min(n - 1);
            self.priority_choices[j]
        };
        RequestSpec {
            id: i as u64,
            arrival_s: *t,
            prompt,
            max_new_tokens: olen,
            temperature,
            priority,
            session_id,
        }
    }

    /// Generate `n` requests with exponential inter-arrival gaps
    /// (a Poisson process at `self.rate`).
    pub fn generate(&self, n: usize) -> Vec<RequestSpec> {
        let mut t = 0.0f64;
        (0..n as u32).map(|i| self.spec_at(i, &mut t)).collect()
    }

    /// Endless open-loop arrival stream — the driver for a continuously
    /// streaming `serve` loop.  Deterministic given the seed, and
    /// prefix-stable: `arrivals().take(n)` equals `generate(n)` exactly,
    /// so a streaming run replays the same traffic as a batch run.
    pub fn arrivals(&self) -> Arrivals<'_> {
        Arrivals { workload: self, i: 0, t: 0.0 }
    }
}

/// Iterator over the open-loop Poisson arrival process (see
/// [`WorkloadGen::arrivals`]); never terminates — cap with `take` or by
/// arrival time.
#[derive(Clone, Debug)]
pub struct Arrivals<'a> {
    workload: &'a WorkloadGen,
    i: u32,
    t: f64,
}

impl Iterator for Arrivals<'_> {
    type Item = RequestSpec;

    fn next(&mut self) -> Option<RequestSpec> {
        let s = self.workload.spec_at(self.i, &mut self.t);
        self.i = self.i.wrapping_add(1);
        Some(s)
    }
}

/// A recorded trace (for replay in benches): (arrival_s, prompt_len,
/// output_len) triples, serialized as CSV.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub entries: Vec<(f64, usize, usize)>,
}

impl Trace {
    pub fn from_requests(reqs: &[RequestSpec]) -> Self {
        Self {
            entries: reqs
                .iter()
                .map(|r| (r.arrival_s, r.prompt.len(), r.max_new_tokens))
                .collect(),
        }
    }

    /// Serialize as CSV.  Arrival times use Rust's shortest-round-trip
    /// f64 `Display` (NOT a fixed precision), so `from_csv(to_csv(t))`
    /// reproduces every entry **exactly** — replayed traces are
    /// bit-identical to recorded ones.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("arrival_s,prompt_len,output_len\n");
        for (a, p, o) in &self.entries {
            s.push_str(&format!("{a},{p},{o}\n"));
        }
        s
    }

    pub fn from_csv(text: &str) -> anyhow::Result<Self> {
        let mut entries = Vec::new();
        for line in text.lines().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split(',');
            let a: f64 = it.next().unwrap_or("").trim().parse()?;
            let p: usize = it.next().unwrap_or("").trim().parse()?;
            let o: usize = it.next().unwrap_or("").trim().parse()?;
            entries.push((a, p, o));
        }
        Ok(Self { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let g = WorkloadGen::new(7, 20.0, 2048);
        let reqs = g.generate(4000);
        let span = reqs.last().unwrap().arrival_s;
        let observed_rate = reqs.len() as f64 / span;
        assert!(
            (observed_rate - 20.0).abs() / 20.0 < 0.08,
            "rate {observed_rate}"
        );
        // arrivals strictly increasing
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = WorkloadGen::new(1, 5.0, 128).generate(50);
        let b = WorkloadGen::new(1, 5.0, 128).generate(50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
        let c = WorkloadGen::new(2, 5.0, 128).generate(50);
        assert_ne!(a[0].prompt, c[0].prompt);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let g = WorkloadGen::new(3, 1.0, 100);
        for r in g.generate(200) {
            assert!(!r.prompt.is_empty());
            assert!(r.prompt.iter().all(|&t| (0..100).contains(&t)));
            assert!(r.max_new_tokens >= 1);
        }
    }

    #[test]
    fn length_dists() {
        assert_eq!(LengthDist::Fixed(7).draw(0.9), 7);
        for u in [0.0f32, 0.5, 0.999] {
            let v = LengthDist::Uniform(10, 20).draw(u);
            assert!((10..=20).contains(&v));
            let a = LengthDist::Aime.draw(u);
            assert!((40..=121).contains(&a));
        }
    }

    #[test]
    fn temperature_choices_mix_the_population() {
        let mut g = WorkloadGen::new(11, 5.0, 128);
        g.temperature_choices = vec![0.5, 1.0, 2.0];
        let reqs = g.generate(120);
        for r in &reqs {
            assert!(g.temperature_choices.contains(&r.temperature));
        }
        // All three temperatures appear (deterministically, given the seed).
        for want in &g.temperature_choices {
            assert!(
                reqs.iter().any(|r| r.temperature == *want),
                "temperature {want} never drawn"
            );
        }
    }

    #[test]
    fn arrivals_iterator_is_prefix_stable_with_generate() {
        let mut g = WorkloadGen::new(21, 6.0, 512);
        g.temperature_choices = vec![0.5, 1.0];
        g.priority_choices =
            vec![Priority::Low, Priority::Normal, Priority::High];
        let batch = g.generate(40);
        let streamed: Vec<RequestSpec> = g.arrivals().take(40).collect();
        assert_eq!(batch.len(), streamed.len());
        for (a, b) in batch.iter().zip(&streamed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.temperature, b.temperature);
            assert_eq!(a.priority, b.priority);
        }
        // The stream keeps going past any batch horizon.
        assert!(g.arrivals().nth(100).is_some());
    }

    #[test]
    fn priority_choices_mix_the_population_independently() {
        let mut g = WorkloadGen::new(17, 5.0, 128);
        // Default: uniform Normal.
        assert!(g.generate(20).iter().all(|r| r.priority == Priority::Normal));
        g.priority_choices = vec![Priority::Low, Priority::High];
        let reqs = g.generate(80);
        for want in &g.priority_choices {
            assert!(
                reqs.iter().any(|r| r.priority == *want),
                "priority {want} never drawn"
            );
        }
        // Stream 15 is its own draw: flipping priorities on must not
        // perturb arrivals, prompts, budgets, or temperatures.
        let base = WorkloadGen::new(17, 5.0, 128).generate(80);
        for (a, b) in base.iter().zip(&reqs) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.temperature, b.temperature);
        }
    }

    #[test]
    fn long_prompt_every_injects_monopolists_without_perturbing_the_rest() {
        let base = WorkloadGen::new(19, 4.0, 256).generate(24);
        let mut g = WorkloadGen::new(19, 4.0, 256);
        g.long_prompt_every = Some((8, 300));
        let spiked = g.generate(24);
        for (i, (a, b)) in base.iter().zip(&spiked).enumerate() {
            // Arrivals / budgets / temperatures come from their own
            // streams: identical for EVERY request.
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.temperature, b.temperature);
            if i % 8 == 7 {
                assert_eq!(b.prompt.len(), 300, "request {i} is the long one");
                assert!(b.prompt.iter().all(|&t| (0..256).contains(&t)));
            } else {
                // Non-designated prompts are bit-identical.
                assert_eq!(a.prompt, b.prompt, "request {i} perturbed");
            }
        }
    }

    #[test]
    fn zipf_token_mode_skews_without_perturbing_other_streams() {
        let base = WorkloadGen::new(23, 4.0, 2048).generate(60);
        let mut g = WorkloadGen::new(23, 4.0, 2048);
        g.token_mode = TokenMode::Zipf { s: 1.1 };
        let skewed = g.generate(60);
        let mut low = 0usize;
        let mut total = 0usize;
        for (a, b) in base.iter().zip(&skewed) {
            // Token *values* are the only thing the mode may change.
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.temperature, b.temperature);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.prompt.len(), b.prompt.len());
            for &t in &b.prompt {
                assert!((0..2048).contains(&t));
                total += 1;
                if t < 128 {
                    low += 1;
                }
            }
        }
        // Under uniform draws the lowest 128-id tile holds 128/2048 =
        // 6.25% of tokens; Zipf(s=1.1) over 2048 ranks puts the majority
        // of mass there.  Demand a wide margin so the assertion is about
        // skew, not noise.
        let frac = low as f64 / total as f64;
        assert!(frac > 0.4, "low-tile fraction {frac} not skewed");
        // And the uniform baseline really is flat.
        let base_low = base
            .iter()
            .flat_map(|r| &r.prompt)
            .filter(|&&t| t < 128)
            .count();
        let base_total: usize = base.iter().map(|r| r.prompt.len()).sum();
        assert!((base_low as f64 / base_total as f64) < 0.12);
    }

    #[test]
    fn zipf_token_mode_is_deterministic_given_seed() {
        let mk = || {
            let mut g = WorkloadGen::new(29, 5.0, 512);
            g.token_mode = TokenMode::Zipf { s: 1.3 };
            g.generate(40)
        };
        let (a, b) = (mk(), mk());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
        // Inverse CDF is exact at both ends and monotone in u.
        assert_eq!(zipf_token(0.0, 2048, 1.1), 0);
        assert_eq!(zipf_token(1.0, 2048, 1.1), 2047);
        assert_eq!(zipf_token(0.0, 2048, 1.0), 0); // s = 1 branch
        let mut prev = -1;
        for k in 0..=100 {
            let t = zipf_token(k as f64 / 100.0, 2048, 1.2);
            assert!(t >= prev, "zipf inverse CDF not monotone");
            prev = t;
        }
    }

    #[test]
    fn trace_csv_roundtrip() {
        let g = WorkloadGen::new(5, 2.0, 64);
        let t = Trace::from_requests(&g.generate(20));
        let back = Trace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(t.entries, back.entries); // exact, including arrivals
    }

    fn shared_mode() -> SharedPrefix {
        SharedPrefix {
            num_prefixes: 3,
            prefix_len: 32,
            users: 4,
            turn_len: LengthDist::Uniform(4, 12),
        }
    }

    #[test]
    fn shared_prefix_mode_shares_system_prompts_and_histories() {
        let mut g = WorkloadGen::new(9, 5.0, 512);
        g.prefix_mode = Some(shared_mode());
        let reqs = g.generate(24); // 4 users x 6 turns
        // Tokens stay in vocab; arrivals strictly increase.
        for r in &reqs {
            assert!(r.prompt.iter().all(|&t| (0..512).contains(&t)));
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        // Users with the same system prompt share the 32-token prefix:
        // user 0 and user 3 both map to system prompt 0.
        assert_eq!(reqs[0].prompt[..32], reqs[3].prompt[..32]);
        // Distinct system prompts differ.
        assert_ne!(reqs[0].prompt[..32], reqs[1].prompt[..32]);
        // Multi-turn: a user's next turn extends their previous prompt —
        // the ENTIRE previous prompt is a prefix of the next one.
        for u in 0..4usize {
            for turn in 0..5usize {
                let prev = &reqs[u + 4 * turn].prompt;
                let next = &reqs[u + 4 * (turn + 1)].prompt;
                assert!(next.len() > prev.len());
                assert_eq!(&next[..prev.len()], &prev[..], "user {u} turn {turn}");
            }
        }
        // Deterministic given the seed.
        let mut g2 = WorkloadGen::new(9, 5.0, 512);
        g2.prefix_mode = Some(shared_mode());
        let reqs2 = g2.generate(24);
        for (a, b) in reqs.iter().zip(&reqs2) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival_s, b.arrival_s);
        }
    }

    #[test]
    fn session_ids_are_stable_across_turns_and_absent_by_default() {
        // Default traffic carries no session identity.
        assert!(WorkloadGen::new(9, 5.0, 512)
            .generate(12)
            .iter()
            .all(|r| r.session_id.is_none()));
        let mut g = WorkloadGen::new(9, 5.0, 512);
        g.prefix_mode = Some(shared_mode()); // 4 users
        let reqs = g.generate(24);
        for (i, r) in reqs.iter().enumerate() {
            // The session id IS the user index: stable across every turn
            // of one conversation.
            assert_eq!(r.session_id, Some((i % 4) as u64), "request {i}");
        }
        // Same session => every later turn extends the earlier prompt;
        // same system prompt across sessions 0 and 3 (both map to system
        // prompt 0) but distinct session ids.
        assert_eq!(reqs[0].prompt[..32], reqs[3].prompt[..32]);
        assert_ne!(reqs[0].session_id, reqs[3].session_id);
        // Tagging draws nothing from Philox: prompts and arrivals are
        // bit-identical to the pre-tagging shared-prefix shape (the
        // determinism test above already pins them run-to-run).
        let mut g2 = WorkloadGen::new(9, 5.0, 512);
        g2.prefix_mode = Some(shared_mode());
        for (a, b) in reqs.iter().zip(&g2.generate(24)) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.session_id, b.session_id);
        }
    }

    #[test]
    fn shared_prefix_mode_leaves_arrivals_and_budgets_unchanged() {
        // Flipping the mode on changes prompt CONTENT only: arrivals and
        // output budgets come from the same streams either way.
        let base = WorkloadGen::new(13, 3.0, 256).generate(16);
        let mut g = WorkloadGen::new(13, 3.0, 256);
        g.prefix_mode = Some(shared_mode());
        let shared = g.generate(16);
        for (a, b) in base.iter().zip(&shared) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.temperature, b.temperature);
        }
    }

    #[test]
    fn prop_trace_csv_roundtrip_is_exact() {
        // Exact f64/usize round-trip over randomized traces — including
        // arrivals with long decimal expansions and the shared-prefix
        // workload shape.
        crate::testutil::cases(32, 0x7ACE, |g| {
            let mut entries = Vec::new();
            let mut t = 0.0f64;
            for _ in 0..g.usize_in(0, 40) {
                // Sums of f32-derived gaps give f64s with messy digits.
                t += g.f32_in(1e-6, 10.0) as f64 / 3.0;
                entries.push((t, g.usize_in(1, 4096), g.usize_in(1, 4096)));
            }
            let trace = Trace { entries };
            let back = Trace::from_csv(&trace.to_csv()).unwrap();
            assert_eq!(trace.entries, back.entries);
        });
        // And over a generated shared-prefix trace.
        let mut g = WorkloadGen::new(3, 7.0, 128);
        g.prefix_mode = Some(shared_mode());
        let trace = Trace::from_requests(&g.generate(40));
        let back = Trace::from_csv(&trace.to_csv()).unwrap();
        assert_eq!(trace.entries, back.entries);
    }
}
