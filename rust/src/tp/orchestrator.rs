//! TP orchestrator: leader + n rank threads decoding one vocab shard each.
//!
//! Mirrors the deployment the paper targets: rank r holds LM-head rows
//! `[r·V/n, (r+1)·V/n)`; at each decode step the leader broadcasts the
//! hidden states, every rank runs its fused shard kernel, and summaries (or
//! full shard logits, for the baseline) come back over the interconnect.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::interconnect::{Interconnect, Message};
use crate::runtime::{Runtime, Tensor};
#[allow(unused_imports)]
use crate::sampling::ExactSampler;
use crate::sampling::{distributed, Key, RowCtx, SamplerSpec, Transform};

/// Communication strategy (the paper's comparison axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// FlashSampling P2P fan-out of O(1)-per-row summaries.
    P2pFanout,
    /// Baseline: all-gather full shard logits, then sample on the leader
    /// with the materialized-logits pipeline (Alg. A.1).
    AllGatherMultinomial,
    /// Baseline: all-gather, then Gumbel-Max on materialized logits (FI2).
    AllGatherGumbel,
}

impl Strategy {
    /// Typed [`SamplerSpec`] of the leader-side sampling pass this
    /// strategy runs over materialized logits; `None` for the fan-out
    /// path, which merges per-rank summaries instead of re-sampling.
    pub fn leader_sampler_spec(self) -> Option<SamplerSpec> {
        match self {
            Strategy::P2pFanout => None,
            Strategy::AllGatherMultinomial => Some(SamplerSpec::Multinomial),
            Strategy::AllGatherGumbel => Some(SamplerSpec::default()),
        }
    }
}

/// Orchestrator configuration.
#[derive(Clone, Debug)]
pub struct TpConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Tensor-parallel degree; must match a `shard_sample_*_tp{n}` artifact.
    pub n_ranks: usize,
    pub batch: usize,
    pub d_model: usize,
    pub vocab: usize,
    pub seed: u64,
}

/// One decode step's outcome.
#[derive(Clone, Debug)]
pub struct TpStepResult {
    pub samples: Vec<i32>,
    /// Log-normalizers (fan-out path only; free from shard masses).
    pub log_z: Option<Vec<f32>>,
    /// Bytes that crossed the interconnect this step.
    pub wire_bytes: u64,
}

enum Work {
    Step { h: Vec<f32>, seed: Key, step: u32, tau: Vec<f32>, strategy: Strategy },
    Shutdown,
}

/// Leader handle over the rank threads.
pub struct TpOrchestrator {
    cfg: TpConfig,
    ranks: Vec<(Sender<Work>, JoinHandle<Result<()>>)>,
    fabric: Interconnect,
    bytes_before: u64,
    key: Key,
}

impl TpOrchestrator {
    /// Spawn rank threads.  `w` is the full LM-head weight `[V, D]`
    /// row-major; each rank receives its contiguous shard.
    pub fn new(cfg: TpConfig, w: &[f32]) -> Result<Self> {
        anyhow::ensure!(
            cfg.vocab % cfg.n_ranks == 0,
            "vocab {} not divisible by {} ranks",
            cfg.vocab,
            cfg.n_ranks
        );
        anyhow::ensure!(w.len() == cfg.vocab * cfg.d_model, "bad weight size");
        // (Each rank's Runtime::new refuses scalar-tau v1 artifact sets.)
        let vs = cfg.vocab / cfg.n_ranks;
        let fabric = Interconnect::new(cfg.n_ranks);
        let sample_artifact = format!(
            "shard_sample_b{}_d{}_v{}_tp{}",
            cfg.batch, cfg.d_model, cfg.vocab, cfg.n_ranks
        );
        let logits_artifact = format!(
            "shard_logits_b{}_d{}_v{}_tp{}",
            cfg.batch, cfg.d_model, cfg.vocab, cfg.n_ranks
        );

        let mut ranks = Vec::with_capacity(cfg.n_ranks);
        for r in 0..cfg.n_ranks {
            let (tx, rx) = channel::<Work>();
            let link = fabric.link(r as u32);
            let shard = w[r * vs * cfg.d_model..(r + 1) * vs * cfg.d_model].to_vec();
            let dir = cfg.artifacts_dir.clone();
            let (sa, la) = (sample_artifact.clone(), logits_artifact.clone());
            let (b, d) = (cfg.batch, cfg.d_model);
            let offset = (r * vs) as i32;
            let handle = std::thread::spawn(move || -> Result<()> {
                // One PJRT runtime per rank thread (one-process-per-GPU).
                let rt = Runtime::new(&dir)?;
                let sample_exe = rt.load(&sa)?;
                let logits_exe = rt.load(&la)?;
                // The shard weight is uploaded once and reused every step.
                let w_lit = Tensor::F32(shard, vec![vs, d]).to_literal()?;
                let off_lit = Tensor::I32(vec![offset], vec![1]).to_literal()?;
                while let Ok(work) = rx.recv() {
                    match work {
                        Work::Shutdown => break,
                        Work::Step { h, seed, step, tau, strategy } => {
                            let h_lit = Tensor::F32(h, vec![b, d]).to_literal()?;
                            match strategy {
                                Strategy::P2pFanout => {
                                    let seed_lit = Tensor::seed(seed).to_literal()?;
                                    let step_lit =
                                        Tensor::scalar_u32(step).to_literal()?;
                                    // tau: [B] — per-row temperatures
                                    // shared by every rank (ABI v2).
                                    let tau_lit = Tensor::F32(tau, vec![b])
                                        .to_literal()?;
                                    let out = sample_exe.run_literals(&[
                                        &h_lit, &w_lit, &off_lit, &seed_lit,
                                        &step_lit, &tau_lit,
                                    ])?;
                                    let m = out[0].as_f32()?;
                                    let idx = out[1].as_i32()?;
                                    let lm = out[2].as_f32()?;
                                    let rows = (0..b)
                                        .map(|i| (m[i], idx[i], lm[i]))
                                        .collect();
                                    link.send(Message::Summaries {
                                        rank: r as u32,
                                        rows,
                                    });
                                }
                                Strategy::AllGatherMultinomial
                                | Strategy::AllGatherGumbel => {
                                    let out =
                                        logits_exe.run_literals(&[&h_lit, &w_lit])?;
                                    link.send(Message::LogitsShard {
                                        rank: r as u32,
                                        batch: b,
                                        data: out[0].as_f32()?.to_vec(),
                                    });
                                }
                            }
                        }
                    }
                }
                Ok(())
            });
            ranks.push((tx, handle));
        }
        let key = Key::from_seed(cfg.seed);
        Ok(Self { cfg, ranks, fabric, bytes_before: 0, key })
    }

    pub fn n_ranks(&self) -> usize {
        self.cfg.n_ranks
    }

    /// Run one decode step over all ranks with the given strategy.
    ///
    /// `tau` carries one temperature per batch row (the `tau: [B]` ABI) —
    /// heterogeneous-temperature batches are first-class on the TP path.
    pub fn step(
        &mut self,
        h: &[f32],
        step: u32,
        tau: &[f32],
        strategy: Strategy,
    ) -> Result<TpStepResult> {
        anyhow::ensure!(h.len() == self.cfg.batch * self.cfg.d_model);
        anyhow::ensure!(
            tau.len() == self.cfg.batch,
            "tau has {} entries for batch {}",
            tau.len(),
            self.cfg.batch
        );
        self.bytes_before = self.fabric.total_bytes();
        for (tx, _) in &self.ranks {
            tx.send(Work::Step {
                h: h.to_vec(),
                seed: self.key,
                step,
                tau: tau.to_vec(),
                strategy,
            })
            .context("rank channel closed")?;
        }
        // Cross-rank barrier: collect all rank messages (Alg. 1 line 15).
        let msgs = self.fabric.gather(self.cfg.n_ranks);
        let wire_bytes = self.fabric.total_bytes() - self.bytes_before;
        let b = self.cfg.batch;
        let vs = self.cfg.vocab / self.cfg.n_ranks;

        match strategy {
            Strategy::P2pFanout => {
                // Per-row pathwise merge over rank summaries (Lemma D.5).
                let mut per_rank = vec![Vec::new(); self.cfg.n_ranks];
                for msg in msgs {
                    if let Message::Summaries { rank, rows } = msg {
                        per_rank[rank as usize] = rows;
                    }
                }
                let mut samples = Vec::with_capacity(b);
                let mut log_z = Vec::with_capacity(b);
                for row in 0..b {
                    let summaries: Vec<distributed::ShardSummary> = per_rank
                        .iter()
                        .enumerate()
                        .map(|(rk, rows)| distributed::ShardSummary {
                            rank: rk as u32,
                            max_score: rows[row].0,
                            local_sample: rows[row].1 as u32,
                            log_mass: rows[row].2,
                        })
                        .collect();
                    let win = distributed::merge_pathwise(&summaries)
                        .context("no shard summaries")?;
                    samples.push(win.local_sample as i32);
                    log_z.push(distributed::log_z(&summaries));
                }
                Ok(TpStepResult { samples, log_z: Some(log_z), wire_bytes })
            }
            Strategy::AllGatherMultinomial | Strategy::AllGatherGumbel => {
                // Materialize the full [B, V] logits on the leader...
                let mut logits = vec![0.0f32; b * self.cfg.vocab];
                for msg in msgs {
                    if let Message::LogitsShard { rank, data, .. } = msg {
                        let base = rank as usize * vs;
                        for row in 0..b {
                            logits[row * self.cfg.vocab + base
                                ..row * self.cfg.vocab + base + vs]
                                .copy_from_slice(&data[row * vs..(row + 1) * vs]);
                        }
                    }
                }
                // ...then run the separate sampling pass (the extra kernels
                // the baseline pays for), selected by typed spec — the
                // same seam the benches and repro tables use.  Per-row
                // transforms keep heterogeneous tau exact on this path too.
                let spec = strategy
                    .leader_sampler_spec()
                    .context("all-gather strategy without a leader sampler")?;
                let sampler = spec.build()?;
                let transforms: Vec<Transform> =
                    tau.iter().map(|&t| Transform::with_temperature(t)).collect();
                let ctxs: Vec<RowCtx<'_>> = transforms
                    .iter()
                    .enumerate()
                    .map(|(row, t)| RowCtx {
                        transform: t,
                        key: self.key,
                        row: row as u32,
                        step,
                    })
                    .collect();
                let samples = sampler
                    .sample_batch_rows(&logits, self.cfg.vocab, &ctxs)
                    .into_iter()
                    .map(|d| d.context("empty row").map(|d| d.index as i32))
                    .collect::<Result<Vec<i32>>>()?;
                Ok(TpStepResult { samples, log_z: None, wire_bytes })
            }
        }
    }

    /// Interconnect statistics since construction.
    pub fn link_stats(&self) -> Vec<super::LinkStats> {
        self.fabric.stats()
    }

    pub fn shutdown(mut self) -> Result<()> {
        for (tx, _) in &self.ranks {
            let _ = tx.send(Work::Shutdown);
        }
        for (_, handle) in self.ranks.drain(..) {
            handle.join().map_err(|_| anyhow::anyhow!("rank panicked"))??;
        }
        Ok(())
    }
}
