//! Simulated tensor-parallel runtime (paper §3.2 multi-GPU path, §D.2).
//!
//! The LM-head weight is sharded across `n` ranks along the vocabulary
//! dimension (Megatron column-parallel).  Each rank is a *thread* with its
//! own PJRT runtime (mirroring one-process-per-GPU), executing the
//! per-shard fused kernel; an interconnect layer carries messages between
//! ranks and counts every byte on the wire.
//!
//! Two communication strategies are implemented, exactly the paper's
//! comparison:
//!
//! * [`Strategy::AllGatherMultinomial`] / [`Strategy::AllGatherGumbel`] —
//!   the baselines: every rank ships its FULL
//!   local logits shard `[B, V/n]` to the leader, which materializes
//!   `[B, V]` and runs a separate sampling pass (Alg. A.1 / I.1).
//! * [`Strategy::P2pFanout`] — FlashSampling: every rank ships its O(1)
//!   per-row summary (max score, argmax, log-mass = 12 bytes/row), the
//!   leader max-merges (pathwise, Lemma D.5) or mass-merges (Alg. I.4).
//!
//! On this CPU testbed the *timing* benefit of overlap can't be observed
//! (there is no independent NVLink engine to overlap with), so the measured
//! quantities are the structural ones the paper's cost model uses — bytes
//! on wire, message counts, serialized-vs-overlappable phases — and
//! `gpusim::interconnect` converts them into predicted multi-GPU runtimes
//! (Figure 3 / Table 6).

pub mod interconnect;
pub mod orchestrator;

pub use interconnect::{Interconnect, LinkStats};
pub use orchestrator::{Strategy, TpConfig, TpOrchestrator, TpStepResult};
