//! Inter-rank message fabric with byte/message accounting.
//!
//! Models the NVLink mesh as mpsc channels plus per-link counters.  The
//! counters are the ground truth for the communication-volume claims
//! (FlashSampling: O(n·B) scalars; all-gather: O(n·B·V/n) = O(B·V)).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// One message on the fabric.
#[derive(Clone, Debug)]
pub enum Message {
    /// FlashSampling P2P fan-out payload: per-row (max, idx, lmass).
    Summaries { rank: u32, rows: Vec<(f32, i32, f32)> },
    /// All-gather payload: the rank's full logits shard, row-major [B, Vs].
    LogitsShard { rank: u32, batch: usize, data: Vec<f32> },
}

impl Message {
    /// Wire size in bytes (payload only, as the cost model counts it).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Summaries { rows, .. } => (rows.len() * 12) as u64,
            Message::LogitsShard { data, .. } => (data.len() * 4) as u64,
        }
    }
}

/// Per-link transfer statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
}

/// A leader-rooted fabric: every worker rank has a link to the leader.
/// (The paper's fan-out broadcasts to all peers; with a single logical
/// sampler the leader link is the accounted path — peer broadcast byte
/// counts are `n-1` times the leader count and derived in gpusim.)
pub struct Interconnect {
    tx: Sender<Message>,
    rx: Receiver<Message>,
    stats: Arc<Mutex<Vec<LinkStats>>>,
}

/// A rank's handle for sending to the leader.
#[derive(Clone)]
pub struct RankLink {
    rank: u32,
    tx: Sender<Message>,
    stats: Arc<Mutex<Vec<LinkStats>>>,
}

impl Interconnect {
    pub fn new(n_ranks: usize) -> Self {
        let (tx, rx) = channel();
        Self {
            tx,
            rx,
            stats: Arc::new(Mutex::new(vec![LinkStats::default(); n_ranks])),
        }
    }

    /// Create the sending endpoint for `rank`.
    pub fn link(&self, rank: u32) -> RankLink {
        RankLink { rank, tx: self.tx.clone(), stats: self.stats.clone() }
    }

    /// Leader: block until `n` messages arrive (the cross-rank barrier
    /// after the fan-out — Alg. 1 line 15).
    pub fn gather(&self, n: usize) -> Vec<Message> {
        (0..n).map(|_| self.rx.recv().expect("rank died")).collect()
    }

    pub fn stats(&self) -> Vec<LinkStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn total_bytes(&self) -> u64 {
        self.stats().iter().map(|s| s.bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.stats().iter().map(|s| s.messages).sum()
    }
}

impl RankLink {
    pub fn send(&self, msg: Message) {
        {
            let mut stats = self.stats.lock().unwrap();
            let s = &mut stats[self.rank as usize];
            s.messages += 1;
            s.bytes += msg.wire_bytes();
        }
        let _ = self.tx.send(msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_accounting() {
        let s = Message::Summaries { rank: 0, rows: vec![(1.0, 2, 3.0); 4] };
        assert_eq!(s.wire_bytes(), 48); // 4 rows x 12 bytes
        let l = Message::LogitsShard { rank: 0, batch: 4, data: vec![0.0; 1024] };
        assert_eq!(l.wire_bytes(), 4096);
    }

    #[test]
    fn gather_collects_all_ranks() {
        let ic = Interconnect::new(3);
        for r in 0..3u32 {
            let link = ic.link(r);
            std::thread::spawn(move || {
                link.send(Message::Summaries { rank: r, rows: vec![(0.0, 0, 0.0)] });
            });
        }
        let msgs = ic.gather(3);
        assert_eq!(msgs.len(), 3);
        assert_eq!(ic.total_messages(), 3);
        assert_eq!(ic.total_bytes(), 36);
    }

    #[test]
    fn fanout_vs_allgather_byte_ratio() {
        // The paper's communication claim, structurally: per-rank payload of
        // the summary path is independent of V.
        let b = 16usize;
        let vs = 64_128usize; // V/n for V=128k, n=2
        let fanout = Message::Summaries { rank: 0, rows: vec![(0.0, 0, 0.0); b] };
        let gather = Message::LogitsShard {
            rank: 0,
            batch: b,
            data: vec![0.0; b * vs],
        };
        let ratio = gather.wire_bytes() as f64 / fanout.wire_bytes() as f64;
        // B*Vs*4 / (B*12) = Vs/3
        assert!((ratio - vs as f64 / 3.0).abs() < 1.0);
    }
}
