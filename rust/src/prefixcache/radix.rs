//! Chain-hashed radix tree over full KV blocks — the prefix-cache index.
//!
//! Granularity is one **full** allocator block (`block_size` token
//! positions): a prompt's reusable prefix is its longest chain of full
//! blocks that some earlier sequence already computed.  Each tree node
//! stands for exactly one such block and is keyed by a *chain hash* that
//! commits to the node's entire token prefix — `hash(parent_hash, block
//! tokens)` — so two prefixes sharing a block's tokens but differing
//! earlier can never alias (DESIGN.md §10).  Because exactness is the
//! repo's contract, a hash is never trusted alone: every node stores its
//! block's tokens and a lookup only matches on token equality, so even a
//! 64-bit collision degrades to a cache miss, not a wrong reuse.
//!
//! Refcount discipline (kept in lockstep with the `BlockAllocator` by
//! [`crate::kvcache::KvCacheManager`]):
//!
//! * node exists            ⇒ the cache holds ONE allocator ref on `block`
//!   (taken at insert, released at eviction);
//! * `refs` counts live sequences attached through the node — each of
//!   those holds its OWN allocator ref per block (the `fork` machinery);
//! * eviction is LRU over **unpinned leaves only** (`refs == 0`, no
//!   children), so an interior node outlives every cached extension of it
//!   and an attached node can never be pulled out from under a sequence.

use std::collections::HashMap;

use crate::kvcache::BlockId;

/// Physical KV payload of one cached block: the `[L, H, block_size, Dh]`
/// f32 slices for K and V that the engine captured after prefill.  On a
/// real device these bytes would simply stay resident in the block's HBM
/// page; in this repro's dense-KV substitution (DESIGN.md §2) the cache
/// carries them so a hit can restore the prefix KV byte-identically.
/// Accounting-only users (benches, property tests) leave both empty.
#[derive(Clone, Debug, Default)]
pub struct BlockKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// One cached full block.
struct Node {
    /// Chain hash committing to the whole prefix up to and including this
    /// block (the key under which the parent indexes this child).
    hash: u64,
    /// This block's tokens — compared on every lookup so a hash collision
    /// is a miss, never a false hit.
    tokens: Vec<i32>,
    block: BlockId,
    kv: BlockKv,
    parent: Option<usize>,
    children: HashMap<u64, usize>,
    /// Live sequences currently attached through this node.
    refs: u32,
    /// LRU tick of the last attach/insert touching this node.
    last_used: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Root sentinel "parent hash" — the chain anchor for first blocks.
const ROOT_HASH: u64 = FNV_OFFSET;

fn fnv(mut h: u64, bytes: impl Iterator<Item = u8>) -> u64 {
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// `hash(parent_hash, tokens)` — FNV-1a over the parent hash then the
/// block's token bytes, so a node's key commits to its whole prefix.
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let h = fnv(FNV_OFFSET, parent.to_le_bytes().into_iter());
    fnv(h, tokens.iter().flat_map(|t| t.to_le_bytes()))
}

/// Routing key for multi-replica prefix-affinity dispatch
/// (`crate::router`): the chain hash of the prompt's FIRST full block —
/// exactly the root key under which any cached prefix of this prompt is
/// (or would be) indexed in a [`RadixTree`].  Pure — no tree needed — so
/// a router can compute it before anything is cached: two prompts that
/// share their first `block_size` tokens (multi-turn sessions over one
/// system prompt) map to the same value and therefore to the same home
/// replica even on a cold start.  `None` when the prompt is shorter than
/// one full block: nothing is cacheable, so there is no affinity signal.
pub fn prefix_home_hash(prompt: &[i32], block_size: usize) -> Option<u64> {
    assert!(block_size > 0, "block_size must be >= 1");
    (prompt.len() >= block_size).then(|| chain_hash(ROOT_HASH, &prompt[..block_size]))
}

/// The radix tree: a slab of nodes plus the first-block index.
pub struct RadixTree {
    block_size: usize,
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: HashMap<u64, usize>,
    tick: u64,
    live: usize,
}

impl RadixTree {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be >= 1");
        Self {
            block_size,
            nodes: Vec::new(),
            free: Vec::new(),
            roots: HashMap::new(),
            tick: 0,
            live: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Number of cached blocks (= live nodes).
    pub fn cached_blocks(&self) -> usize {
        self.live
    }

    /// Total sequence-attachment refs across all nodes — the
    /// abort/release consistency audit (DESIGN.md §11): whenever no
    /// sequence is attached through the tree this must be 0, i.e. every
    /// abort or release detached exactly the refs its attach took.
    pub fn attached_refs(&self) -> usize {
        self.nodes
            .iter()
            .filter_map(|n| n.as_ref())
            .map(|n| n.refs as usize)
            .sum()
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("stale node id")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("stale node id")
    }

    pub fn node_block(&self, id: usize) -> BlockId {
        self.node(id).block
    }

    pub fn node_kv(&self, id: usize) -> &BlockKv {
        &self.node(id).kv
    }

    /// Walk the longest cached full-block chain matching `prompt`, capped
    /// at `max_blocks` blocks.  Read-only; returns node ids in chain order.
    fn walk(&self, prompt: &[i32], max_blocks: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut map = &self.roots;
        let mut parent_hash = ROOT_HASH;
        for chunk in prompt.chunks_exact(self.block_size).take(max_blocks) {
            let h = chain_hash(parent_hash, chunk);
            match map.get(&h) {
                Some(&id) if self.node(id).tokens.as_slice() == chunk => {
                    out.push(id);
                    parent_hash = h;
                    map = &self.node(id).children;
                }
                _ => break,
            }
        }
        out
    }

    /// Longest cached prefix of `prompt` in tokens (full blocks only,
    /// capped at `max_blocks`).  Pure probe: no refcounts, no LRU bump —
    /// safe for admission-control queries.
    pub fn probe_tokens(&self, prompt: &[i32], max_blocks: usize) -> usize {
        self.walk(prompt, max_blocks).len() * self.block_size
    }

    /// Attach a sequence to the longest cached prefix: bumps each matched
    /// node's `refs` and LRU tick, returns the node ids in chain order.
    /// The caller must take one allocator ref per returned block and later
    /// [`Self::detach`] exactly these ids.
    pub fn attach(&mut self, prompt: &[i32], max_blocks: usize) -> Vec<usize> {
        let ids = self.walk(prompt, max_blocks);
        self.tick += 1;
        let tick = self.tick;
        for &id in &ids {
            let n = self.node_mut(id);
            n.refs += 1;
            n.last_used = tick;
        }
        ids
    }

    /// Drop a sequence's attachment refs (the inverse of [`Self::attach`]).
    pub fn detach(&mut self, ids: &[usize]) {
        for &id in ids {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "detach without attach");
            n.refs = n.refs.saturating_sub(1);
        }
    }

    /// Insert `prompt`'s full blocks, backed by the sequence's `blocks`
    /// (ordered block table); `payload(j)` supplies the physical KV of
    /// block `j` and is only called for blocks not already cached.
    /// Returns the block ids newly referenced by the cache — the caller
    /// must take one allocator ref on each.
    pub fn insert(
        &mut self,
        prompt: &[i32],
        blocks: &[BlockId],
        mut payload: impl FnMut(usize) -> BlockKv,
    ) -> Vec<BlockId> {
        let full = (prompt.len() / self.block_size).min(blocks.len());
        let mut new_blocks = Vec::new();
        self.tick += 1;
        let tick = self.tick;
        let mut parent: Option<usize> = None;
        let mut parent_hash = ROOT_HASH;
        for j in 0..full {
            let chunk = &prompt[j * self.block_size..(j + 1) * self.block_size];
            let h = chain_hash(parent_hash, chunk);
            let existing = match parent {
                None => self.roots.get(&h).copied(),
                Some(p) => self.node(p).children.get(&h).copied(),
            };
            let id = match existing {
                Some(id) if self.node(id).tokens.as_slice() == chunk => {
                    self.node_mut(id).last_used = tick;
                    id
                }
                // A 64-bit chain-hash collision between different token
                // blocks: leave the incumbent alone and stop extending —
                // correctness never depends on the hash (lookups compare
                // tokens), only this prefix stays uncached.
                Some(_) => break,
                None => {
                    let node = Node {
                        hash: h,
                        tokens: chunk.to_vec(),
                        block: blocks[j],
                        kv: payload(j),
                        parent,
                        children: HashMap::new(),
                        refs: 0,
                        last_used: tick,
                    };
                    let id = self.alloc_node(node);
                    match parent {
                        None => {
                            self.roots.insert(h, id);
                        }
                        Some(p) => {
                            self.node_mut(p).children.insert(h, id);
                        }
                    }
                    new_blocks.push(blocks[j]);
                    id
                }
            };
            parent = Some(id);
            parent_hash = h;
        }
        new_blocks
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        self.live += 1;
        match self.free.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    /// Evict the least-recently-used unpinned leaf (`refs == 0`, no
    /// children).  Returns the freed node's block id — the caller must
    /// release the cache's allocator ref on it.  `None` when nothing is
    /// evictable (every leaf is attached).
    pub fn evict_lru(&mut self) -> Option<BlockId> {
        let mut best: Option<(u64, usize)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.refs == 0
                    && n.children.is_empty()
                    && best.is_none_or(|(t, _)| n.last_used < t)
                {
                    best = Some((n.last_used, id));
                }
            }
        }
        let (_, id) = best?;
        let node = self.nodes[id].take().expect("picked a live node");
        match node.parent {
            None => {
                self.roots.remove(&node.hash);
            }
            Some(p) => {
                self.node_mut(p).children.remove(&node.hash);
            }
        }
        self.free.push(id);
        self.live -= 1;
        Some(node.block)
    }

    /// Blocks that eviction could actually return to the free list right
    /// now: nodes whose subtree contains no attached (`refs > 0`) node —
    /// those can all be peeled off leaf-first — AND whose block the cache
    /// is the sole holder of (`reclaims(block)`; a block a live sequence
    /// still references survives its node's eviction, freeing nothing).
    /// The admission plan counts these as reclaimable headroom next to the
    /// allocator's free list, so the count must never overstate what
    /// [`Self::evict_lru`] can deliver.
    pub fn evictable(&self, reclaims: impl Fn(BlockId) -> bool) -> usize {
        fn visit(
            tree: &RadixTree,
            id: usize,
            count: &mut usize,
            reclaims: &impl Fn(BlockId) -> bool,
        ) -> bool {
            let n = tree.node(id);
            let mut pinned = n.refs > 0;
            for &c in n.children.values() {
                // Note: every child is visited even under a pinned parent
                // (children order is irrelevant to the count).
                pinned |= visit(tree, c, count, reclaims);
            }
            if !pinned && reclaims(n.block) {
                *count += 1;
            }
            pinned
        }
        let mut count = 0;
        for &id in self.roots.values() {
            visit(self, id, &mut count, &reclaims);
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(xs: &[i32]) -> Vec<i32> {
        xs.to_vec()
    }

    #[test]
    fn insert_then_probe_matches_full_blocks_only() {
        let mut t = RadixTree::new(4);
        // 10 tokens = 2 full blocks + a 2-token tail (never cached).
        let p = toks(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        let added = t.insert(&p, &[7, 8, 9], |_| BlockKv::default());
        assert_eq!(added, vec![7, 8]); // tail block 9 is not full
        assert_eq!(t.cached_blocks(), 2);
        assert_eq!(t.probe_tokens(&p, usize::MAX), 8);
        // A shorter prompt sharing the first block matches one block.
        assert_eq!(t.probe_tokens(&[1, 2, 3, 4, 99], usize::MAX), 4);
        // Cap limits the match.
        assert_eq!(t.probe_tokens(&p, 1), 4);
        // A different first token misses entirely.
        assert_eq!(t.probe_tokens(&[9, 2, 3, 4, 5, 6, 7, 8], usize::MAX), 0);
    }

    #[test]
    fn chain_hash_commits_to_the_whole_prefix() {
        let mut t = RadixTree::new(2);
        // Two prompts whose SECOND block has identical tokens but whose
        // first blocks differ: the second blocks must be distinct nodes.
        t.insert(&[1, 1, 5, 5], &[0, 1], |_| BlockKv::default());
        t.insert(&[2, 2, 5, 5], &[2, 3], |_| BlockKv::default());
        assert_eq!(t.cached_blocks(), 4);
        assert_eq!(t.probe_tokens(&[1, 1, 5, 5], usize::MAX), 4);
        assert_eq!(t.probe_tokens(&[2, 2, 5, 5], usize::MAX), 4);
        // The [5, 5] block under prefix [1, 1] maps to block 1, under
        // [2, 2] to block 3 — prefix-committed, never shared.
        let a = t.attach(&[1, 1, 5, 5], usize::MAX);
        let b = t.attach(&[2, 2, 5, 5], usize::MAX);
        assert_eq!(t.node_block(a[1]), 1);
        assert_eq!(t.node_block(b[1]), 3);
    }

    #[test]
    fn shared_prefix_deduplicates_nodes() {
        let mut t = RadixTree::new(4);
        t.insert(&[1, 2, 3, 4, 5, 6, 7, 8], &[0, 1], |_| BlockKv::default());
        // Same first block, different second block: only one new node.
        let added =
            t.insert(&[1, 2, 3, 4, 9, 9, 9, 9], &[0, 2], |_| BlockKv::default());
        assert_eq!(added, vec![2]);
        assert_eq!(t.cached_blocks(), 3);
    }

    #[test]
    fn payload_roundtrips_through_attach() {
        let mut t = RadixTree::new(2);
        t.insert(&[4, 5, 6, 7], &[10, 11], |j| BlockKv {
            k: vec![j as f32; 2],
            v: vec![-(j as f32); 2],
        });
        let ids = t.attach(&[4, 5, 6, 7], usize::MAX);
        assert_eq!(ids.len(), 2);
        assert_eq!(t.node_kv(ids[1]).k, vec![1.0; 2]);
        assert_eq!(t.node_kv(ids[1]).v, vec![-1.0; 2]);
        t.detach(&ids);
    }

    #[test]
    fn eviction_is_lru_over_unpinned_leaves() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 1], &[0], |_| BlockKv::default()); // oldest
        t.insert(&[2, 2], &[1], |_| BlockKv::default());
        t.insert(&[3, 3], &[2], |_| BlockKv::default()); // newest
        // Touch [1, 1] so [2, 2] becomes the LRU leaf.
        t.attach(&[1, 1], usize::MAX);
        // [1,1] is pinned (attached); LRU among {2,2},{3,3} is {2,2}.
        assert_eq!(t.evict_lru(), Some(1));
        assert_eq!(t.evict_lru(), Some(2));
        // Only the pinned node remains: nothing evictable.
        assert_eq!(t.evict_lru(), None);
        assert_eq!(t.cached_blocks(), 1);
    }

    #[test]
    fn interior_nodes_evict_only_after_their_children() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 1, 2, 2, 3, 3], &[0, 1, 2], |_| BlockKv::default());
        assert_eq!(t.evictable(|_| true), 3);
        // A block still held elsewhere frees nothing when its node goes.
        assert_eq!(t.evictable(|b| b != 1), 2);
        // Leaf-first: deepest block (2) goes first, then 1, then 0.
        assert_eq!(t.evict_lru(), Some(2));
        assert_eq!(t.evict_lru(), Some(1));
        assert_eq!(t.evict_lru(), Some(0));
        assert_eq!(t.evict_lru(), None);
    }

    #[test]
    fn attached_descendants_pin_the_whole_chain() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 1, 2, 2], &[0, 1], |_| BlockKv::default());
        t.insert(&[1, 1, 9, 9], &[0, 2], |_| BlockKv::default());
        let ids = t.attach(&[1, 1, 2, 2], usize::MAX);
        // The [9, 9] branch is evictable; the attached chain is not.
        assert_eq!(t.evictable(|_| true), 1);
        assert_eq!(t.evict_lru(), Some(2));
        assert_eq!(t.evict_lru(), None);
        t.detach(&ids);
        assert_eq!(t.evictable(|_| true), 2);
        assert_eq!(t.evict_lru(), Some(1));
        assert_eq!(t.evict_lru(), Some(0));
    }

    #[test]
    fn reinsert_after_eviction_reuses_slab_slots() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 1], &[0], |_| BlockKv::default());
        assert_eq!(t.evict_lru(), Some(0));
        let added = t.insert(&[2, 2], &[5], |_| BlockKv::default());
        assert_eq!(added, vec![5]);
        assert_eq!(t.cached_blocks(), 1);
        assert_eq!(t.probe_tokens(&[2, 2], usize::MAX), 2);
        assert_eq!(t.probe_tokens(&[1, 1], usize::MAX), 0);
    }

    #[test]
    fn prop_insert_probe_agree_with_a_naive_map() {
        // Model: a set of inserted full-block prefixes; probe must return
        // the longest chain of inserted prefixes of the query.
        use std::collections::HashSet;
        crate::testutil::cases(48, 0x9AD1, |g| {
            let bs = g.usize_in(1, 4);
            let mut t = RadixTree::new(bs);
            let mut model: HashSet<Vec<i32>> = HashSet::new();
            let mut next_block: BlockId = 0;
            for _ in 0..g.usize_in(1, 24) {
                let len = g.usize_in(1, 12);
                let p: Vec<i32> =
                    (0..len).map(|_| g.u32_in(0, 3) as i32).collect();
                let nblocks = len.div_ceil(bs);
                let blocks: Vec<BlockId> =
                    (0..nblocks).map(|i| next_block + i as u32).collect();
                next_block += nblocks as u32;
                t.insert(&p, &blocks, |_| BlockKv::default());
                for j in 1..=len / bs {
                    model.insert(p[..j * bs].to_vec());
                }
                // Probe a random other prompt against the model.
                let qlen = g.usize_in(1, 12);
                let q: Vec<i32> =
                    (0..qlen).map(|_| g.u32_in(0, 3) as i32).collect();
                let expect = (1..=qlen / bs)
                    .take_while(|&j| model.contains(&q[..j * bs]))
                    .count()
                    * bs;
                assert_eq!(t.probe_tokens(&q, usize::MAX), expect, "query {q:?}");
            }
            assert_eq!(t.cached_blocks(), model.len());
        });
    }
}
