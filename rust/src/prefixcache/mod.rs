//! Automatic prefix caching: radix-tree KV reuse across requests
//! (DESIGN.md §10).
//!
//! FlashSampling makes sampling a free epilogue of the LM head; for
//! multi-user serving the next dominant cost is **redundant prefill** —
//! system prompts, few-shot templates, and multi-turn histories are
//! re-embedded for every request even when their KV state is
//! byte-identical.  This subsystem removes that cost without touching the
//! exactness story: reusing KV blocks for an identical token prefix feeds
//! bit-identical hidden states into the fused sample kernel, and the
//! first-token Philox coordinates are unchanged, so every statistical
//! certificate (`repro chisq` et al.) holds with caching on or off —
//! checked end-to-end by `repro prefix-identity`.
//!
//! Pieces:
//!
//! * [`RadixTree`] — the index: full-block granularity, chain-hashed keys
//!   (a node commits to its whole prefix), token-verified lookups, LRU
//!   eviction of unpinned leaves only.
//! * [`BlockKv`] — the physical payload: the `[L, H, block_size, Dh]` K/V
//!   slices of one cached block (the stand-in for the block's HBM page in
//!   the dense-KV substitution, DESIGN.md §2).
//! * [`crate::kvcache::KvCacheManager`] owns the tree and keeps its
//!   refcounts in lockstep with the `BlockAllocator`:
//!   `register_with_prefix` attaches matched blocks copy-on-write (the
//!   `fork` machinery), `insert_prefix` publishes a freshly prefilled
//!   prompt, `release` detaches, and allocation pressure evicts.
//! * `coordinator` — the scheduler charges only uncached prefill tokens
//!   against the admission budget and buckets by suffix length; the
//!   engine restores cached prefix KV and runs the `prefill_cached`
//!   artifact on the suffix only.
//! * `gpusim::tpot` models the TTFT win as a function of the cached
//!   fraction; `workload` generates shared-prefix / multi-turn traffic so
//!   the win is measurable end-to-end (`cargo bench --bench prefixcache`).

pub mod radix;

pub use radix::{prefix_home_hash, BlockKv, RadixTree};
