//! Streaming request lifecycle: per-token events over an internal event
//! queue, consumed through a [`RequestHandle`] (DESIGN.md §11).
//!
//! `Engine::submit` returns a handle at admission time; every scheduler
//! iteration that produces tokens for the request pushes one
//! [`RequestOutput`] per token into the handle's queue, and completion
//! (stop token, budget, rejection, or [`Engine::abort`]) pushes a final
//! terminal event carrying the [`FinishReason`] plus the assembled
//! [`Completion`].  The engine is single-threaded — events appear between
//! [`Engine::step`] calls, never concurrently with them — but the queue
//! is `Arc<Mutex<..>>` so handles are `Send` and can be polled from a
//! different thread than the one driving the engine loop.
//!
//! Timing is reported on the engine's **logical step clock** (one tick
//! per `Engine::step`), which makes TTFT/TPOT measurements deterministic
//! and replayable — the wall-clock counterparts stay on
//! [`Completion::timing`] as before.
//!
//! [`Engine::submit`]: super::Engine::submit
//! [`Engine::step`]: super::Engine::step
//! [`Engine::abort`]: super::Engine::abort

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::request::{Completion, FinishReason};

/// One streaming event: a generated token, or the terminal
/// finish notification.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestOutput {
    pub request_id: u64,
    /// The sampled token; `None` on the terminal event.
    pub token: Option<i32>,
    /// 0-based index of this token within the generated text; on the
    /// terminal event, the final generated length.
    pub index: usize,
    /// Cumulative generated text length including this token.
    pub text_len: usize,
    /// Logical engine step (the step clock) at which this event fired.
    pub step: u64,
    /// Steps from submission to this token — set on the first token only
    /// (the logical-clock TTFT).
    pub ttft_steps: Option<u64>,
    /// Steps since this request's previous token — `None` on the first
    /// token (the logical-clock inter-token latency; its mean is the
    /// logical TPOT).
    pub inter_token_steps: Option<u64>,
    /// Set on the terminal event only.
    pub finish: Option<FinishReason>,
}

impl RequestOutput {
    /// The terminal event: no token, final length, finish reason.
    pub(crate) fn terminal(
        request_id: u64,
        text_len: usize,
        step: u64,
        finish: FinishReason,
    ) -> Self {
        Self {
            request_id,
            token: None,
            index: text_len,
            text_len,
            step,
            ttft_steps: None,
            inter_token_steps: None,
            finish: Some(finish),
        }
    }
}

/// Shared state between the engine and one request's handle.
#[derive(Debug, Default)]
pub(crate) struct StreamState {
    pub(crate) queue: VecDeque<RequestOutput>,
    pub(crate) finished: Option<FinishReason>,
    pub(crate) completion: Option<Completion>,
}

/// The engine's side of one stream (the handle holds the other clone).
pub(crate) type SharedStream = Arc<Mutex<StreamState>>;

/// Handle to one in-flight request: poll per-token events, observe
/// completion.  Cheap to clone (an `Arc` bump); dropping every clone
/// discards any undrained events but never blocks the engine.
#[derive(Clone, Debug)]
pub struct RequestHandle {
    id: u64,
    state: Arc<Mutex<StreamState>>,
}

impl RequestHandle {
    pub(crate) fn new(id: u64, state: Arc<Mutex<StreamState>>) -> Self {
        Self { id, state }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Pop the next pending event, if any (non-blocking — the engine is
    /// driven by the caller, so "no event" means "call `step` again").
    pub fn try_next(&self) -> Option<RequestOutput> {
        self.state.lock().expect("stream mutex").queue.pop_front()
    }

    /// Drain every pending event in order.
    pub fn drain(&self) -> Vec<RequestOutput> {
        self.state.lock().expect("stream mutex").queue.drain(..).collect()
    }

    /// Why the request finished — `None` while still in flight.
    pub fn finish_reason(&self) -> Option<FinishReason> {
        self.state.lock().expect("stream mutex").finished
    }

    /// Has the engine finished (completed, rejected, or aborted) the
    /// request?  Events may still be queued for draining.
    pub fn is_finished(&self) -> bool {
        self.finish_reason().is_some()
    }

    /// The final [`Completion`], once finished (a clone; also returned by
    /// the batch-style engine entry points).
    pub fn completion(&self) -> Option<Completion> {
        self.state.lock().expect("stream mutex").completion.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_drains_in_order_and_reports_finish() {
        let state = Arc::new(Mutex::new(StreamState::default()));
        let h = RequestHandle::new(3, state.clone());
        assert_eq!(h.id(), 3);
        assert!(h.try_next().is_none());
        assert!(!h.is_finished());
        {
            let mut g = state.lock().unwrap();
            for (i, tok) in [11, 12].into_iter().enumerate() {
                g.queue.push_back(RequestOutput {
                    request_id: 3,
                    token: Some(tok),
                    index: i,
                    text_len: i + 1,
                    step: (i + 1) as u64,
                    ttft_steps: (i == 0).then_some(1),
                    inter_token_steps: (i > 0).then_some(1),
                    finish: None,
                });
            }
            g.queue.push_back(RequestOutput::terminal(
                3,
                2,
                2,
                FinishReason::MaxTokens,
            ));
            g.finished = Some(FinishReason::MaxTokens);
        }
        assert!(h.is_finished());
        let evs = h.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].token, Some(11));
        assert_eq!(evs[0].ttft_steps, Some(1));
        assert_eq!(evs[1].inter_token_steps, Some(1));
        assert_eq!(evs[2].token, None);
        assert_eq!(evs[2].finish, Some(FinishReason::MaxTokens));
        assert_eq!(evs[2].text_len, 2);
        assert!(h.try_next().is_none()); // drained
        assert_eq!(h.finish_reason(), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn handles_are_send_and_clone() {
        fn assert_send<T: Send + Clone>() {}
        assert_send::<RequestHandle>();
    }
}
