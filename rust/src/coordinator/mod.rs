//! L3 coordinator — the paper's serving-stack integration (§4.5).
//!
//! A vLLM-shaped engine: request admission → continuous batcher →
//! prefill/decode scheduler → PJRT execution of fused decode+sample
//! artifacts → TPOT/TTFT metrics.  The FlashSampling contribution is wired
//! in as a first-class feature: the decode artifact's LM head *is* the
//! fused kernel, and `EngineConfig::sampler` (a typed `SamplerSpec`) flips
//! the A/B switch to the materialized-logits baseline the paper compares
//! against.  Per-request `SamplingParams` carry temperature per row into
//! the artifacts (`tau: [B]`, ABI v2), so sampling parameters never
//! fragment batches.

pub mod engine;
pub mod request;
pub mod scheduler;

pub use engine::{Engine, EngineConfig};
pub use request::{Completion, FinishReason, Request, SamplingParams, Sequence};
pub use scheduler::{Plan, SchedulerConfig};
