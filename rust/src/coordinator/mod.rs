//! L3 coordinator — the paper's serving-stack integration (§4.5).
//!
//! A vLLM-shaped engine: request admission → continuous batcher →
//! prefill/decode scheduler → PJRT execution of fused decode+sample
//! artifacts → TPOT/TTFT metrics.  The FlashSampling contribution is wired
//! in as a first-class feature: the decode artifact's LM head *is* the
//! fused kernel, and `EngineConfig::sampler` (a typed `SamplerSpec`) flips
//! the A/B switch to the materialized-logits baseline the paper compares
//! against.  Per-request `SamplingParams` carry temperature per row into
//! the artifacts (`tau: [B]`, ABI v2), so sampling parameters never
//! fragment batches.

//! The request lifecycle is a vLLM-style submission/streaming split
//! (DESIGN.md §11): [`Engine::submit`] returns a [`RequestHandle`] that
//! yields per-token [`RequestOutput`] events, [`Engine::abort`] cancels
//! mid-flight with zero-leak KV release, per-request [`Priority`] +
//! anti-starvation aging order the scheduler, and the public boundary
//! reports typed [`EngineError`]s.  The legacy batch entry points
//! (`run_to_completion`, `serve`) are thin shims over the same machinery.

pub mod engine;
pub mod error;
pub mod request;
pub mod scheduler;
pub mod stream;

pub use engine::{Engine, EngineConfig, TpDecode};
pub use error::EngineError;
pub use request::{
    Completion, FinishReason, Priority, Request, SamplingParams, Sequence,
};
pub use scheduler::{Plan, SchedulerConfig};
pub use stream::{RequestHandle, RequestOutput};
