//! Typed engine errors — the public API boundary of the serving
//! front-end (DESIGN.md §11).
//!
//! `Engine::submit`, `Engine::step`, and `Engine::abort` return
//! [`EngineError`] instead of stringly `anyhow` errors, so clients (the
//! serve CLI, the repro harness, a future RPC front-end) can branch on
//! the failure class: retry later on admission trouble, fix the request
//! on parameter trouble, surface operator alerts on artifact trouble.
//! Anything that is not a request-level failure (runtime I/O, accounting
//! invariants) is wrapped verbatim in [`EngineError::Internal`] — nothing
//! is lost, it is just no longer the *only* shape an error can take.
//!
//! Interop: `EngineError` implements `std::error::Error`, so `?` in an
//! `anyhow::Result` context converts it via the blanket `From`; the
//! reverse `From<anyhow::Error>` lands internal failures in
//! [`EngineError::Internal`], which is what lets the engine's private
//! helpers keep their `anyhow` plumbing.

use std::fmt;

/// A typed failure at the engine's public request-lifecycle boundary.
#[derive(Debug)]
pub enum EngineError {
    /// `submit` — the request id is already live in this engine
    /// (waiting, running, or holding an open stream).  Ids of *finished*
    /// requests may be reused.
    DuplicateRequestId { id: u64 },
    /// `submit` — the request can never be admitted by this engine:
    /// empty prompt, prompt longer than the largest prefill bucket,
    /// prompt + budget beyond `max_seq`, or out-of-vocab tokens.
    AdmissionRejected { id: u64, reason: String },
    /// `submit` — the sampling parameters are invalid, or carry fields
    /// the fused artifact ABI cannot honor (`detail` names them).
    UnsupportedParams { id: u64, detail: String },
    /// `abort` — no such request is live (never submitted, or already
    /// finished).
    UnknownRequest { id: u64 },
    /// `step` — the artifact set does not match what the planned batch
    /// needs (missing executable for a bucket, wrong output arity, ...).
    ArtifactMismatch { artifact: String, detail: String },
    /// Anything else: runtime execution or accounting failures, wrapped
    /// verbatim.
    Internal(anyhow::Error),
}

impl EngineError {
    /// Wrap an artifact load/shape failure with the artifact's name.
    pub(crate) fn artifact(name: &str, err: anyhow::Error) -> Self {
        Self::ArtifactMismatch { artifact: name.to_string(), detail: format!("{err:?}") }
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::DuplicateRequestId { id } => write!(
                f,
                "request id {id} is already live in this engine \
                 (waiting, running, or streaming)"
            ),
            Self::AdmissionRejected { id, reason } => {
                write!(f, "request {id} can never be admitted: {reason}")
            }
            Self::UnsupportedParams { id, detail } => {
                write!(f, "request {id}: unsupported sampling params: {detail}")
            }
            Self::UnknownRequest { id } => write!(
                f,
                "unknown request id {id} (never submitted, or already finished)"
            ),
            Self::ArtifactMismatch { artifact, detail } => {
                write!(f, "artifact '{artifact}' mismatch: {detail}")
            }
            // `{e:?}` keeps the vendored-anyhow "Caused by:" chain visible
            // (plain `{e}` would print the outermost message only).
            Self::Internal(e) => write!(f, "engine internal error: {e:?}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<anyhow::Error> for EngineError {
    fn from(e: anyhow::Error) -> Self {
        Self::Internal(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_class() {
        let e = EngineError::DuplicateRequestId { id: 7 };
        assert!(e.to_string().contains("already live"), "{e}");
        let e = EngineError::UnsupportedParams { id: 1, detail: "top_k".into() };
        assert!(e.to_string().contains("top_k"), "{e}");
        let e = EngineError::AdmissionRejected { id: 2, reason: "empty prompt".into() };
        assert!(e.to_string().contains("empty prompt"), "{e}");
        let e = EngineError::UnknownRequest { id: 3 };
        assert!(e.to_string().contains("unknown request id 3"), "{e}");
        let e = EngineError::artifact("decode_sample_b8", anyhow::anyhow!("4 outputs"));
        assert!(e.to_string().contains("decode_sample_b8"), "{e}");
    }

    #[test]
    fn converts_both_ways_with_anyhow() {
        // anyhow -> EngineError (the engine's internal `?` plumbing).
        fn inner() -> Result<(), EngineError> {
            let r: anyhow::Result<()> = Err(anyhow::anyhow!("kv accounting"));
            r?;
            Ok(())
        }
        match inner().unwrap_err() {
            EngineError::Internal(e) => assert_eq!(e.to_string(), "kv accounting"),
            other => panic!("expected Internal, got {other:?}"),
        }
        // EngineError -> anyhow (callers in anyhow contexts keep `?`).
        fn outer() -> anyhow::Result<()> {
            Err(EngineError::UnknownRequest { id: 9 })?;
            Ok(())
        }
        assert!(outer().unwrap_err().to_string().contains("unknown request id 9"));
    }
}
