//! The serving engine: continuous batching over AOT decode/prefill
//! executables with the FlashSampling LM head fused in.
//!
//! One `Engine` owns a PJRT runtime, the cached weight literals, the paged
//! KV accounting, and the waiting/running sequence sets.  `step()` executes
//! exactly one scheduler plan (a prefill batch or a decode batch) — the
//! granularity at which vLLM's engine loop operates.
//!
//! The request lifecycle is a vLLM-style submission/streaming split
//! (DESIGN.md §11): `submit()` validates and returns a [`RequestHandle`]
//! that yields per-token [`RequestOutput`] events over an internal event
//! queue as `step()` produces them; `abort()` cancels mid-flight with
//! zero-leak KV and prefix-cache release; and the public boundary
//! (`submit` / `step` / `abort`) reports typed [`EngineError`]s instead of
//! stringly failures.  `serve()` survives as a thin batch-compatibility
//! shim over the same machinery — handles are created, events flow, and
//! the returned completions are the streams' terminal artifacts — with
//! byte-identical token streams (same Philox coordinates) to the
//! pre-streaming engine.
//!
//! The decode hot path never touches Python and never materializes logits:
//! `decode_sample_b{B}` runs (transformer step → LM-head matmul → fused
//! Gumbel epilogue → tile reduction) inside a single XLA executable.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::error::EngineError;
use super::request::{Completion, FinishReason, Request, SeqKv, SeqState, Sequence};
use super::scheduler::{pick_bucket, plan, Plan, SchedulerConfig};
use super::stream::{RequestHandle, RequestOutput, SharedStream, StreamState};
use crate::gpusim::iomodel::{choose, PcieModel, PreemptAction, SwapPolicy};
use crate::kvcache::{KvCacheConfig, KvCacheManager, PrefixAttach};
use crate::metrics::ServingMetrics;
use crate::prefixcache::BlockKv;
use crate::runtime::{Runtime, Tensor};
use crate::sampling::{Key, SamplerSpec};
use crate::specdec::{coupled_emit_len, DraftModel, NGramDraft};
use crate::subvocab::{self, SubvocabConfig, SubvocabState, SUB_TILE_SLOTS};
use crate::tp::{Strategy, TpConfig, TpOrchestrator};
use crate::trace::{EventKind, Trace, TraceLevel};
use crate::workload::RequestSpec;

/// Tensor-parallel decode configuration (DESIGN.md §13).  With
/// `EngineConfig::tp = Some(..)` the replica's decode step runs the
/// `decode_hidden_b{B}` transformer artifact (no fused sampling epilogue),
/// then fans the hidden states out through [`crate::tp::TpOrchestrator`]:
/// each rank scores its vocab shard and the leader merges per-rank
/// summaries over the `gpusim` interconnect model.  Exact by the paper's
/// hierarchical factorization — the distributed merge consumes the same
/// Philox `(row, counter-step)` coordinates as the fused single-device
/// kernel, so shard count never shows in the token stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TpDecode {
    /// Tensor-parallel degree (>= 2; the model vocab must divide evenly).
    pub n_ranks: usize,
    /// Interconnect strategy: P2P summary fan-out (FlashSampling) or the
    /// all-gather materialized-logits baselines.
    pub strategy: Strategy,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Upper bound on concurrently decoding sequences.
    pub max_concurrency: usize,
    /// Paged-KV accounting pool (blocks of `kv_block_size` tokens).
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    /// RNG seed for the whole serving session.
    pub seed: u64,
    /// Automatic prefix caching (DESIGN.md §10): reuse KV blocks across
    /// requests whose prompts share a full-block token prefix, and run
    /// prefill on the uncached suffix only (`prefill_cached` artifacts).
    /// Exact by construction — cached KV bytes are byte-identical to
    /// recomputation and the first-token Philox coordinates are unchanged
    /// — so this defaults ON; flip off for A/B runs
    /// (`repro prefix-identity` asserts the on/off identity).
    pub prefix_caching: bool,
    /// Typed sampler selection — the one source of truth for which decode
    /// path runs.  [`SamplerSpec::Gumbel`] maps to the fused FlashSampling
    /// decode artifact, [`SamplerSpec::Multinomial`] to the baseline
    /// decode artifact (the paper's §4.5 A/B switch), and
    /// [`SamplerSpec::SpecDecode`] to the speculative decode loop over the
    /// fused artifact (DESIGN.md §9: n-gram drafts, Gumbel-coupled exact
    /// verification, 1..=K+1 tokens per step).  Any other spec (grouped /
    /// online / distributed / topk — host-side algorithms used by the TP
    /// leader, benches, and repro tables) is rejected at engine
    /// construction rather than silently substituted.
    pub sampler: SamplerSpec,
    /// Anti-starvation aging for priority scheduling (DESIGN.md §11):
    /// a waiting request gains one priority class of effective rank per
    /// this many logical engine steps (0 disables aging).  Neutral — and
    /// therefore stream-identical — when every request carries the
    /// default `Normal` priority.
    pub priority_aging_steps: u64,
    /// Chunked prefill window in prompt tokens (DESIGN.md §12); 0
    /// disables chunking (byte-identical to the pre-chunking engine).
    /// Requires the `prefill_cached` artifacts (chunk windows run prompt
    /// slices through them with per-row offsets); silently forced to 0 on
    /// artifact sets without them.  Also lifts the submit-time rejection
    /// of prompts beyond the largest prefill T bucket — windows cover any
    /// prompt that fits `max_seq`.
    pub prefill_chunk_tokens: usize,
    /// Alternate chunk windows with other work (decode, short prefills)
    /// on odd logical steps — bounds short-request TTFT under an
    /// adversarial long prompt at the cost of replay identity vs the
    /// unchunked baseline (the sampled distribution is unchanged).  Off:
    /// "sticky" windows, bit-identical completed-request streams.
    pub chunk_interleave: bool,
    /// Host-side swap ledger capacity in KV blocks (DESIGN.md §12); 0
    /// disables the swap tier and preemption falls back to finish-early.
    pub swap_blocks: usize,
    /// Swap-vs-recompute preemption policy, priced by
    /// [`crate::gpusim::iomodel::PcieModel`] (`Auto`), or forced
    /// (`Always` / `Never`).
    pub swap_policy: SwapPolicy,
    /// Tensor-parallel decode (DESIGN.md §13): `None` (default) keeps the
    /// single-shard fused decode artifacts; `Some` routes every decode
    /// step through [`TpDecode`]'s sharded LM-head fan-out.  Requires the
    /// fused Gumbel sampler, `n_ranks >= 2`, and the `decode_hidden` +
    /// shard artifacts — validated at construction, never at decode time.
    pub tp: Option<TpDecode>,
    /// Flight-recorder verbosity (DESIGN.md §14).  `Off` (default) costs
    /// one branch per event site; `Lifecycle` records request lifecycles;
    /// `Full` adds scheduler plans, aging promotions, and KV deltas.
    pub trace_level: TraceLevel,
    /// Flight-recorder ring capacity in events (`trace_ring_cap` config
    /// key; default 4096).  The trace digest and `DerivedCounters` are
    /// eviction-independent; the modeled-time profiler (DESIGN.md §15)
    /// refuses evicted rings, so size this to the workload before
    /// profiling.
    pub trace_ring_cap: usize,
    /// TTFT SLO threshold in microseconds for the
    /// `flashsampling_slo_violations_total` exposition (DESIGN.md §15);
    /// 0 (default) disables the classification and keeps the Prometheus
    /// render byte-identical to the pre-SLO stack.
    pub slo_ttft_us: u64,
    /// Inter-token-latency SLO threshold in microseconds; 0 (default)
    /// disables the classification.
    pub slo_itl_us: u64,
    /// Certified sub-vocabulary decode (DESIGN.md §16): run only the hot
    /// candidate vocab tiles through the `decode_sample_sub` artifacts and
    /// accept the result when the per-step Cauchy–Schwarz certificate
    /// proves the excluded tiles cannot win the Gumbel-argmax; fall back
    /// to the full `decode_sample` pass at the same Philox coordinates
    /// otherwise.  Token streams are bit-identical either way
    /// (`repro subvocab-identity`).  Requires the fused 'gumbel' sampler
    /// and no TP; silently degrades to full-vocab decode on artifact sets
    /// without the `decode_sample_sub_*` executables (ABI v3).
    pub subvocab: bool,
    /// Candidate tile budget per decode batch
    /// (1..=[`crate::subvocab::SUB_TILE_SLOTS`]; `subvocab_tiles` key).
    pub subvocab_tiles: usize,
    /// Additive certificate slack (>= 0, finite; `subvocab_slack` key):
    /// skip only when the candidate winner beats the excluded bound by
    /// more than this.  Larger slack means more fallbacks, never wrong
    /// tokens.
    pub subvocab_slack: f32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_concurrency: 8,
            kv_blocks: 512,
            kv_block_size: 16,
            seed: 0xF1A5_4_5A3,
            prefix_caching: true,
            sampler: SamplerSpec::default(),
            priority_aging_steps: 32,
            prefill_chunk_tokens: 0,
            chunk_interleave: false,
            swap_blocks: 0,
            swap_policy: SwapPolicy::Auto,
            tp: None,
            trace_level: TraceLevel::Off,
            trace_ring_cap: 4096,
            slo_ttft_us: 0,
            slo_itl_us: 0,
            subvocab: false,
            subvocab_tiles: crate::subvocab::SUB_TILE_SLOTS,
            subvocab_slack: 0.0,
        }
    }
}

impl EngineConfig {
    /// Does this configuration select the baseline (materialized-logits)
    /// decode artifact?
    pub fn uses_baseline_artifact(&self) -> bool {
        self.sampler.uses_baseline_artifact()
    }

    /// Validate the sampler spec: parameter ranges, plus the engine's own
    /// constraint that the decode path can actually honor it.
    pub fn validate_sampler(&self) -> Result<()> {
        self.sampler.validate().context("EngineConfig::sampler")?;
        anyhow::ensure!(
            self.sampler.is_artifact_backed(),
            "EngineConfig::sampler = '{}': the decode path runs inside AOT \
             artifacts, which exist only for 'gumbel' (fused FlashSampling), \
             'multinomial' (baseline), and 'specdec' (speculative decode \
             over the fused artifact); '{}' is a host-side sampler \
             (TP leader / benches / repro)",
            self.sampler,
            self.sampler.name()
        );
        Ok(())
    }
}

/// Steady-state decode fast path: when consecutive decode steps run the
/// SAME sequence set in the same bucket, the batch KV cache stays as the
/// previous step's output literals — no gather from per-sequence storage,
/// no host->literal conversion, no scatter back (≈19 ms/step saved on this
/// testbed, EXPERIMENTS.md §Perf L3).  The per-sequence `SeqKv` copies are
/// synchronized lazily whenever the batch composition changes.
struct DecodeCache {
    seq_ids: Vec<u64>,
    b_bucket: usize,
    kv_k: xla::Literal,
    kv_v: xla::Literal,
}

/// The serving engine (single-threaded; see `crate::tp` for the
/// multi-rank orchestrator).
pub struct Engine {
    rt: Runtime,
    cfg: EngineConfig,
    /// Artifact directory, kept for the lazy per-bucket TP orchestrator
    /// spawns (each rank thread opens its own PJRT runtime over it).
    artifacts_dir: std::path::PathBuf,
    sched: SchedulerConfig,
    /// Weight literals in canonical order (uploaded once).
    params_lit: Vec<xla::Literal>,
    /// Index of "lm_head" within the canonical order (first-token sampling).
    lm_head_idx: usize,
    kvmgr: KvCacheManager,
    /// Does the artifact set carry the `prefill_cached_*` executables?
    /// Older artifact dirs don't; the engine then still *accounts* prefix
    /// hits (admission, metrics) but computes every prefill in full —
    /// output-identical either way, just without the suffix-only speedup.
    cached_prefill_available: bool,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    /// Sequences parked in the host-side swap tier (DESIGN.md §12), FCFS.
    /// Their private KV blocks live in the [`KvCacheManager`] swap ledger;
    /// prefix-cache-attached blocks stay pinned on-device so radix
    /// identity survives the round trip.  Resumed by `swap_in_ready`
    /// ahead of the waiting queue as soon as pool + concurrency allow.
    swapped: Vec<Sequence>,
    /// Monotonic decode-step counter — the Philox `step` input, so every
    /// scheduler iteration draws fresh noise.
    step_counter: u32,
    /// Logical step clock: one tick per `step()` call.  This is the
    /// streaming API's timestamp domain (TTFT/TPOT in steps — exactly
    /// replayable, unlike the wall clock) and the aging rule's "now".
    /// Distinct from `step_counter`, which advances per artifact
    /// invocation (several per engine step under spec decode) and feeds
    /// Philox.
    clock: u64,
    /// Event queues of live streams, by request id.  Entries are removed
    /// at completion (the handle keeps its queue alive), so membership
    /// here doubles as the duplicate-id check for `submit`.
    streams: HashMap<u64, SharedStream>,
    key: Key,
    decode_cache: Option<DecodeCache>,
    /// TP orchestrators by decode bucket, spawned lazily on the first
    /// decode at that batch size (`cfg.tp` replicas only; empty otherwise).
    /// Rank threads and their PJRT runtimes are paid once per bucket.
    tp_orch: HashMap<usize, TpOrchestrator>,
    /// Certified sub-vocabulary decode state (DESIGN.md §16): the
    /// precomputed per-tile weight-norm bounds plus one candidate set per
    /// live request.  `None` when `cfg.subvocab` is off OR the artifact
    /// set lacks the `decode_sample_sub_*` executables (graceful
    /// degradation, like `cached_prefill_available`).
    subvocab: Option<SubvocabState>,
    pub metrics: ServingMetrics,
    /// Flight recorder (DESIGN.md §14).  Level comes from
    /// `EngineConfig::trace_level`; with `Off` every emission site costs
    /// one branch, mirroring the `Arc::strong_count` trick in `stream.rs`.
    pub trace: Trace,
    /// KV-counter baseline for `Full`-level per-step delta events
    /// (alloc / free / CoW / radix evictions), snapshotted at the end of
    /// each `step()`.
    trace_kv_base: [u64; 4],
}

/// Calibrated prefill throughput for the swap-vs-recompute policy
/// (`SwapPolicy::Auto`): what one prompt token of recompute costs on this
/// testbed, in µs.  Order-of-magnitude is what matters — the PCIe transfer
/// of a KV block is ~10 µs while recomputing a block's worth of context is
/// ~1 ms, so `Auto` swaps for all but trivially short contexts.
const PREFILL_US_PER_TOKEN: f64 = 50.0;

/// Push one per-token streaming event (free function: callers hold
/// disjoint field borrows of the engine).
fn emit_token(
    streams: &HashMap<u64, SharedStream>,
    s: &mut Sequence,
    token: i32,
    step: u64,
) {
    let index = s.generated.len() - 1; // called right after the push
    let ttft_steps = (index == 0).then(|| step.saturating_sub(s.submitted_step));
    let inter_token_steps = s.last_token_step.map(|p| step.saturating_sub(p));
    s.last_token_step = Some(step);
    // Skip event construction when every handle is gone (strong count 1 =
    // the engine's own clone): batch shims drop their handles, and the
    // decode hot path should not pay per-token allocation + mutex traffic
    // for queues nobody will ever drain.
    if let Some(st) = streams.get(&s.id).filter(|st| Arc::strong_count(st) > 1) {
        st.lock().expect("stream mutex").queue.push_back(RequestOutput {
            request_id: s.id,
            token: Some(token),
            index,
            text_len: index + 1,
            step,
            ttft_steps,
            inter_token_steps,
            finish: None,
        });
    }
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<Path>, cfg: EngineConfig) -> Result<Self> {
        // Fail fast on sampler specs the decode artifacts cannot honor.
        cfg.validate_sampler()?;
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        // Runtime::new refuses scalar-tau (v1) artifact sets, so the
        // per-row tau vectors below always match the executables.
        let rt = Runtime::new(&artifacts_dir)?;
        let model = rt.manifest().model.clone();
        if let Some(tp) = cfg.tp {
            // TP decode validation — everything fail-fast here so the
            // decode hot path never discovers a missing shard artifact.
            anyhow::ensure!(
                matches!(cfg.sampler, SamplerSpec::Gumbel { .. }),
                "EngineConfig::tp: the TP decode path fans out the fused \
                 FlashSampling epilogue across vocab shards; sampler must \
                 be 'gumbel' (got '{}')",
                cfg.sampler
            );
            anyhow::ensure!(tp.n_ranks >= 2, "EngineConfig::tp: n_ranks must be >= 2");
            anyhow::ensure!(
                model.vocab % tp.n_ranks == 0,
                "EngineConfig::tp: vocab {} not divisible by {} ranks",
                model.vocab,
                tp.n_ranks
            );
            for &b in &model.decode_buckets {
                for name in [
                    format!("decode_hidden_b{b}"),
                    format!(
                        "shard_sample_b{b}_d{}_v{}_tp{}",
                        model.d_model, model.vocab, tp.n_ranks
                    ),
                    format!(
                        "shard_logits_b{b}_d{}_v{}_tp{}",
                        model.d_model, model.vocab, tp.n_ranks
                    ),
                ] {
                    rt.manifest().find(&name).with_context(|| {
                        format!(
                            "EngineConfig::tp = {} ranks: artifact '{name}' \
                             missing (regenerate with `make artifacts`)",
                            tp.n_ranks
                        )
                    })?;
                }
            }
        }
        let params = rt.params_in_order()?;
        let params_lit: Vec<xla::Literal> = params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let lm_head_idx = model
            .param_order
            .iter()
            .position(|n| n == "lm_head")
            .context("lm_head missing from param order")?;
        let cached_prefill_available = model.prefill_t_buckets.iter().all(|t| {
            rt.manifest()
                .find(&format!("prefill_cached_b{}_t{t}", model.prefill_b))
                .is_ok()
        });
        let subvocab = if cfg.subvocab {
            // Fail fast on combinations the certified decode path cannot
            // honor, mirroring the TP validation above.
            anyhow::ensure!(
                matches!(cfg.sampler, SamplerSpec::Gumbel { .. }),
                "EngineConfig::subvocab: the certified tile-skip path runs \
                 the fused FlashSampling epilogue over candidate tiles; \
                 sampler must be 'gumbel' (got '{}')",
                cfg.sampler
            );
            anyhow::ensure!(
                cfg.tp.is_none(),
                "EngineConfig::subvocab: incompatible with tensor-parallel \
                 decode (the shard artifacts carry no tile-subset variant)"
            );
            anyhow::ensure!(
                (1..=SUB_TILE_SLOTS).contains(&cfg.subvocab_tiles),
                "EngineConfig::subvocab_tiles = {} out of range 1..={}",
                cfg.subvocab_tiles,
                SUB_TILE_SLOTS
            );
            anyhow::ensure!(
                cfg.subvocab_slack.is_finite() && cfg.subvocab_slack >= 0.0,
                "EngineConfig::subvocab_slack = {} must be finite and >= 0",
                cfg.subvocab_slack
            );
            // Graceful degradation on pre-v3 artifact layouts that still
            // pass the manifest version gate after regeneration: no
            // tile-subset executables, no skipping, identical tokens.
            let available = model.decode_buckets.iter().all(|b| {
                rt.manifest().find(&format!("decode_sample_sub_b{b}")).is_ok()
            });
            if available {
                let w = Tensor::from_literal(&params_lit[lm_head_idx])?
                    .as_f32()?
                    .to_vec();
                Some(SubvocabState::new(
                    &w,
                    model.vocab,
                    model.d_model,
                    SubvocabConfig {
                        tile_budget: cfg.subvocab_tiles,
                        slack: cfg.subvocab_slack,
                    },
                ))
            } else {
                None
            }
        } else {
            None
        };
        let sched = SchedulerConfig {
            decode_buckets: model.decode_buckets.clone(),
            prefill_t_buckets: model.prefill_t_buckets.clone(),
            prefill_b: model.prefill_b,
            max_concurrency: cfg.max_concurrency,
            // Spec decode emits up to K+1 tokens per sequence per step;
            // admission reserves that burst (see SchedulerConfig docs).
            max_tokens_per_step: match cfg.sampler {
                SamplerSpec::SpecDecode { k, .. } => k + 1,
                _ => 1,
            },
            aging_steps: cfg.priority_aging_steps,
            // Chunk windows run prompt slices through the prefill_cached
            // artifacts (per-row offsets); without them chunking silently
            // degrades to whole-prompt prefill.
            prefill_chunk_tokens: if cached_prefill_available {
                cfg.prefill_chunk_tokens
            } else {
                0
            },
            chunk_interleave: cfg.chunk_interleave,
        };
        let mut kvmgr = KvCacheManager::new(KvCacheConfig {
            block_size: cfg.kv_block_size,
            num_blocks: cfg.kv_blocks,
            prefix_caching: cfg.prefix_caching,
        });
        kvmgr.set_swap_capacity(cfg.swap_blocks);
        let key = Key::from_seed(cfg.seed);
        let trace = Trace::with_capacity(cfg.trace_level, cfg.trace_ring_cap);
        let metrics = ServingMetrics {
            slo_ttft_us: cfg.slo_ttft_us,
            slo_itl_us: cfg.slo_itl_us,
            ..ServingMetrics::default()
        };
        Ok(Self {
            rt,
            cfg,
            artifacts_dir,
            sched,
            params_lit,
            lm_head_idx,
            kvmgr,
            cached_prefill_available,
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: Vec::new(),
            step_counter: 0,
            clock: 0,
            streams: HashMap::new(),
            key,
            decode_cache: None,
            tp_orch: HashMap::new(),
            subvocab,
            metrics,
            trace,
            trace_kv_base: [0; 4],
        })
    }

    /// Lazily spawn (and cache) the TP orchestrator for one decode
    /// bucket.  The full LM-head weight is re-materialized from the
    /// uploaded literal and sharded row-contiguously across ranks —
    /// exactly the layout the shard artifacts were lowered for.
    fn tp_orchestrator(
        &mut self,
        b_bucket: usize,
    ) -> Result<&mut TpOrchestrator, EngineError> {
        if !self.tp_orch.contains_key(&b_bucket) {
            let tp = self.cfg.tp.expect("tp_orchestrator without EngineConfig::tp");
            let model = self.rt.manifest().model.clone();
            let w = Tensor::from_literal(&self.params_lit[self.lm_head_idx])?
                .as_f32()?
                .to_vec();
            let orch = TpOrchestrator::new(
                TpConfig {
                    artifacts_dir: self.artifacts_dir.clone(),
                    n_ranks: tp.n_ranks,
                    batch: b_bucket,
                    d_model: model.d_model,
                    vocab: model.vocab,
                    // Same seed => same Philox key as the fused path.
                    seed: self.cfg.seed,
                },
                &w,
            )?;
            self.tp_orch.insert(b_bucket, orch);
        }
        Ok(self.tp_orch.get_mut(&b_bucket).expect("just inserted"))
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Free KV blocks right now (leak diagnostics: after every request
    /// completes, `kv_blocks - free` must equal exactly the prefix cache's
    /// resident blocks).
    pub fn kv_free_blocks(&self) -> usize {
        self.kvmgr.free_blocks()
    }

    /// Blocks resident in the automatic prefix cache (0 with caching off).
    pub fn prefix_cached_blocks(&self) -> usize {
        self.kvmgr.prefix_cached_blocks()
    }

    /// Live prefix-cache attachment references across all registered
    /// sequences (the radix-identity balance the abort suite asserts).
    pub fn prefix_attached_refs(&self) -> usize {
        self.kvmgr.prefix_attached_refs()
    }

    /// Is the certified sub-vocabulary decode path live?  False when
    /// `EngineConfig::subvocab` is off or the artifact set lacks the
    /// `decode_sample_sub_*` executables (graceful degradation).
    pub fn subvocab_active(&self) -> bool {
        self.subvocab.is_some()
    }

    /// The effective chunk window after artifact gating (0 when chunking
    /// is off or the artifact set lacks `prefill_cached_*`).
    pub fn prefill_chunk_tokens(&self) -> usize {
        self.sched.prefill_chunk_tokens
    }

    /// KV blocks currently parked in the host-side swap ledger.
    pub fn swapped_blocks(&self) -> usize {
        self.kvmgr.swapped_blocks()
    }

    /// Sequences currently parked in the swap tier.
    pub fn swapped_sequences(&self) -> usize {
        self.swapped.len()
    }

    fn model(&self) -> &crate::runtime::ModelInfo {
        &self.rt.manifest().model
    }

    /// Per-sequence KV block length `[L, H, S, Dh]`.
    fn kv_len(&self) -> usize {
        let m = self.model();
        m.n_layers * m.n_heads * m.max_seq * m.head_dim()
    }

    /// Submit a request (validated against model limits and the decode
    /// artifacts' capabilities) and return the [`RequestHandle`] that
    /// streams its per-token [`RequestOutput`] events.
    ///
    /// Typed failures ([`EngineError`]): duplicate live request ids,
    /// invalid/artifact-unsupported sampling params, and prompts this
    /// engine can never admit.
    pub fn submit(&mut self, req: Request) -> Result<RequestHandle, EngineError> {
        let id = req.id;
        // Id collisions were previously silent until the scheduler-side
        // `register` tripped over them mid-step; they are a typed submit
        // error now.  Every waiting/running sequence holds a live stream
        // entry (inserted below, removed only in `complete_seq`), so the
        // map membership IS the liveness check.  Finished ids may be
        // reused.
        if self.streams.contains_key(&id) {
            return Err(EngineError::DuplicateRequestId { id });
        }
        let m = self.model();
        if let Err(e) = req.params.validate(m.vocab) {
            return Err(EngineError::UnsupportedParams { id, detail: e.to_string() });
        }
        // Reject params the fused ABI cannot honor rather than silently
        // ignoring them; host-side paths (`sample_batch_rows`) carry the
        // full set, the artifacts carry per-row tau + stop handling.
        let missing = req.params.artifact_unsupported();
        if !missing.is_empty() {
            return Err(EngineError::UnsupportedParams {
                id,
                detail: format!(
                    "the decode artifacts (ABI v{}) carry per-row temperature \
                     only; unsupported params: {}",
                    crate::runtime::TAU_ABI_VERSION,
                    missing.join(", ")
                ),
            });
        }
        // Hoist the model scalars: the reject closure below needs a
        // mutable borrow of the trace, which a live `&self`-tied `m`
        // would forbid.
        let (vocab, max_seq, max_t) =
            (m.vocab, m.max_seq, *m.prefill_t_buckets.last().unwrap());
        let clock = self.clock;
        let trace = &mut self.trace;
        let mut reject = |reason: String| {
            if trace.on() {
                trace.emit(clock, id, EventKind::Reject { reason: reason.clone() });
            }
            EngineError::AdmissionRejected { id, reason }
        };
        if req.prompt.is_empty() {
            return Err(reject("empty prompt".into()));
        }
        // Chunked prefill lifts the T-bucket ceiling: windows cover any
        // prompt that fits max_seq, one largest-bucket slice at a time.
        if self.sched.prefill_chunk_tokens == 0 && req.prompt.len() > max_t {
            return Err(reject(format!(
                "prompt of {} tokens exceeds the largest prefill bucket {max_t}",
                req.prompt.len()
            )));
        }
        if req.prompt.len() + req.params.max_new_tokens > max_seq {
            return Err(reject(format!(
                "prompt {} + budget {} exceeds max_seq {}",
                req.prompt.len(),
                req.params.max_new_tokens,
                max_seq
            )));
        }
        if req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
            return Err(reject("prompt token out of vocab range".into()));
        }
        if self.trace.on() {
            self.trace.emit(
                self.clock,
                id,
                EventKind::Submit {
                    prompt_len: req.prompt.len(),
                    max_new: req.params.max_new_tokens,
                },
            );
        }
        // Seed the certified sub-vocab candidate set from the prompt's
        // unigram statistics (DESIGN.md §16).
        if let Some(sv) = self.subvocab.as_mut() {
            sv.observe_prompt(id, &req.prompt);
        }
        let mut seq = Sequence::new(req);
        seq.submitted_step = self.clock;
        let state = Arc::new(Mutex::new(StreamState::default()));
        self.streams.insert(id, state.clone());
        self.waiting.push_back(seq);
        Ok(RequestHandle::new(id, state))
    }

    /// Cancel a request mid-flight: drop it from the waiting queue or the
    /// running set, release its KV blocks and prefix-cache attachments
    /// (zero leaks — the abort test suite asserts pool balance), push the
    /// terminal `Aborted` event on its stream, and return the partial
    /// [`Completion`].  [`EngineError::UnknownRequest`] if the id is not
    /// live.
    pub fn abort(&mut self, request_id: u64) -> Result<Completion, EngineError> {
        if let Some(idx) = self.waiting.iter().position(|s| s.id == request_id) {
            let s = self.waiting.remove(idx).expect("position is in range");
            // A partially-prefilled queue head IS registered (chunk start
            // allocated its full prompt); release or the blocks leak.
            if s.prefilled_tokens > 0 {
                self.kvmgr.release(s.id)?;
            }
            return Ok(self.complete_seq(s, FinishReason::Aborted));
        }
        if let Some(idx) = self.swapped.iter().position(|s| s.id == request_id) {
            let s = self.swapped.remove(idx);
            // `release` drops the on-device attached chain AND clears the
            // swap-ledger entry for this sequence's parked blocks.
            self.kvmgr.release(s.id)?;
            return Ok(self.complete_seq(s, FinishReason::Aborted));
        }
        if let Some(idx) = self.running.iter().position(|s| s.id == request_id) {
            // The steady-state decode cache may hold this sequence's KV as
            // device literals; fold the batch back into per-sequence
            // storage first so the survivors lose nothing.
            if self
                .decode_cache
                .as_ref()
                .is_some_and(|c| c.seq_ids.contains(&request_id))
            {
                self.sync_cache_to_seqs()?;
            }
            let s = self.running.remove(idx);
            self.kvmgr.release(s.id)?;
            return Ok(self.complete_seq(s, FinishReason::Aborted));
        }
        Err(EngineError::UnknownRequest { id: request_id })
    }

    /// Finish a sequence: build the [`Completion`], record streaming
    /// metrics, and deliver the terminal event to the request's stream
    /// (removing it from the live-stream map — the handle keeps the queue
    /// alive for draining).
    fn complete_seq(&mut self, s: Sequence, reason: FinishReason) -> Completion {
        if let Some(sv) = self.subvocab.as_mut() {
            sv.release(s.id);
        }
        let c = s.into_completion(reason);
        self.metrics.requests_completed += 1;
        if let Some(t) = c.timing.ttft {
            self.metrics.ttft.push(t);
        }
        if let Some(t) = c.timing.tpot() {
            self.metrics.tpot.push(t);
        }
        self.metrics
            .inter_token
            .extend(c.timing.token_latencies.iter().copied());
        if reason == FinishReason::Aborted {
            self.metrics.bump("aborted", 1);
        }
        if self.trace.on() {
            let name = match reason {
                FinishReason::MaxTokens => "max_tokens",
                FinishReason::StopToken => "stop_token",
                FinishReason::Rejected => "rejected",
                FinishReason::Aborted => "aborted",
            };
            self.trace.emit(
                self.clock,
                c.id,
                EventKind::Finish { reason: name, tokens: c.tokens.len() as u64 },
            );
        }
        if let Some(st) = self.streams.remove(&c.id) {
            // As in `emit_token`: with every handle dropped (the batch
            // shims), skip the terminal event and the Completion clone —
            // removal from the map is what matters (id becomes reusable).
            if Arc::strong_count(&st) > 1 {
                let mut g = st.lock().expect("stream mutex");
                g.queue.push_back(RequestOutput::terminal(
                    c.id,
                    c.tokens.len(),
                    self.clock,
                    reason,
                ));
                g.finished = Some(reason);
                g.completion = Some(c.clone());
            }
        }
        c
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len() + self.swapped.len()
    }

    /// The logical step clock: `step()` calls so far.  Streaming events
    /// timestamp against this domain.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Pool-balance diagnostic: KV blocks neither free nor prefix-cache-
    /// resident.  With no requests in flight this must equal 0 — the
    /// zero-leak invariant the abort suite asserts after every schedule.
    pub fn kv_unaccounted_blocks(&self) -> usize {
        self.kvmgr.unaccounted_blocks()
    }

    /// KV block size in token positions (the prefix cache's granularity
    /// and the router's affinity-key width).
    pub fn kv_block_size(&self) -> usize {
        self.cfg.kv_block_size
    }

    /// Router dispatch probe (DESIGN.md §13): tokens of `prompt` already
    /// resident in this engine's radix cache.  Pure — no refcounts move.
    pub fn cached_prefix_tokens(&self, prompt: &[i32]) -> usize {
        self.kvmgr.cached_prefix_tokens(prompt)
    }

    /// Router dispatch probe: free + reclaimable KV blocks available to
    /// admit `prompt` on this engine right now.
    pub fn prefill_headroom(&self, prompt: &[i32]) -> usize {
        self.kvmgr.prefill_headroom(prompt)
    }

    /// Router dispatch probe: new KV blocks `prompt` would need beyond
    /// its cached prefix (what admission charges against the budget).
    pub fn prefill_blocks_needed(&self, prompt: &[i32]) -> usize {
        self.kvmgr.prefill_blocks_needed(prompt, 0)
    }

    /// One scheduler iteration.  Returns completions finished this step
    /// (each also delivered as a terminal stream event); per-token events
    /// land on the corresponding [`RequestHandle`]s.
    pub fn step(&mut self) -> Result<Vec<Completion>, EngineError> {
        let t0 = Instant::now();
        // Tick the logical step clock first: events of this step carry
        // the new value, TTFT-in-steps >= 1.
        self.clock += 1;
        // Resume swapped-out sequences ahead of fresh admissions: their
        // tokens are sunk cost and their attached prefix blocks are
        // already pinned on-device.
        self.swap_in_ready()?;
        // The planner reads the waiting queue as one slice (no clone —
        // a backed-up queue would otherwise pay a deep per-step copy of
        // every pending prompt).
        self.waiting.make_contiguous();
        let (waiting, _) = self.waiting.as_slices();
        // Cache-aware admission: only uncached prefill blocks are charged
        // against the budget (plus the decode-burst headroom), with a
        // per-batch tally ([`crate::kvcache::BatchAdmission`], shared with
        // the `repro prefix-identity` sim) so the plan never
        // oversubscribes.
        let mut admission = self.kvmgr.batch_admission();
        let p = plan(
            &self.sched,
            waiting,
            &self.running,
            |s, burst| admission.admit(&self.kvmgr, &s.prompt, burst),
            |s| self.kvmgr.cached_prefix_tokens(&s.prompt),
            self.clock,
        );
        if self.trace.full() {
            let (outcome, batch) = match &p {
                Plan::ChunkPrefill { .. } => ("chunk_prefill", 1),
                Plan::Prefill { seq_ids, .. } => ("prefill", seq_ids.len()),
                Plan::Decode { seq_ids, .. } => ("decode", seq_ids.len()),
                Plan::Idle => ("idle", 0),
            };
            self.trace.emit(self.clock, 0, EventKind::Plan { outcome, batch });
            // Aging promotions: waiting sequences whose effective rank
            // has risen at least one class above their base priority.
            let aging = self.sched.aging_steps;
            if aging > 0 {
                let promoted = self
                    .waiting
                    .iter()
                    .filter(|s| self.clock.saturating_sub(s.submitted_step) >= aging)
                    .count();
                if promoted > 0 {
                    self.trace
                        .emit(self.clock, 0, EventKind::Promote { count: promoted as u64 });
                }
            }
        }
        let out = match p {
            Plan::ChunkPrefill { seq_id } => self.do_chunk_prefill(seq_id),
            Plan::Prefill { seq_ids, t_bucket } => self.do_prefill(&seq_ids, t_bucket),
            Plan::Decode { seq_ids, b_bucket } => {
                if let SamplerSpec::SpecDecode { k, ngram } = self.cfg.sampler {
                    self.do_spec_decode(&seq_ids, b_bucket, k, ngram)
                } else {
                    self.do_decode(&seq_ids, b_bucket)
                }
            }
            Plan::Idle => Ok(Vec::new()),
        };
        if self.trace.full() {
            self.emit_kv_deltas();
        }
        self.metrics.bump("step_total_us", t0.elapsed().as_micros() as u64);
        out
    }

    /// `Full`-level KV bookkeeping events: per-step deltas of the
    /// monotone alloc / free / CoW-fork / radix-eviction counters against
    /// the previous step's baseline (engine-global, request id 0).
    fn emit_kv_deltas(&mut self) {
        let now = [
            self.kvmgr.stat_alloc_blocks(),
            self.kvmgr.stat_freed_blocks(),
            self.kvmgr.stat_cow_forks(),
            self.kvmgr.evicted_blocks(),
        ];
        let d: Vec<u64> = now
            .iter()
            .zip(self.trace_kv_base.iter())
            .map(|(n, b)| n.saturating_sub(*b))
            .collect();
        self.trace_kv_base = now;
        for (i, kind) in [
            EventKind::KvAlloc { blocks: d[0] },
            EventKind::KvFree { blocks: d[1] },
            EventKind::KvCow { blocks: d[2] },
            EventKind::RadixEvict { blocks: d[3] },
        ]
        .into_iter()
        .enumerate()
        {
            if d[i] > 0 {
                self.trace.emit(self.clock, 0, kind);
            }
        }
    }

    /// Backstop for open-loop drivers: when a step produced nothing and
    /// nothing is running, the head of the waiting queue can never be
    /// admitted on this engine — reject it (terminal `Rejected` stream
    /// event + completion) so driver loops always make progress instead
    /// of spinning on `Plan::Idle` forever.  Returns `None` (and changes
    /// nothing) while work is still running — a busy pool may yet free
    /// the blocks the head needs.
    pub fn reject_unschedulable(&mut self) -> Option<Completion> {
        if !self.running.is_empty() {
            return None;
        }
        // A partially-prefilled head is schedulable by construction — its
        // blocks are already held and the next chunk window needs no
        // admission; an idle step around it is transient (e.g. interleave
        // parity), never a dead end.
        if self.waiting.front().is_some_and(|s| s.prefilled_tokens > 0) {
            return None;
        }
        if let Some(seq) = self.waiting.pop_front() {
            return Some(self.complete_seq(seq, FinishReason::Rejected));
        }
        // Waiting empty but swap tier occupied and swap-in starved (a
        // newer sequence pinned the pool and finished — but e.g. the
        // prefix cache holds everything): abandon the oldest victim so
        // drivers cannot livelock on a tier nothing will ever drain.
        if !self.swapped.is_empty() {
            let s = self.swapped.remove(0);
            if self.kvmgr.release(s.id).is_err() {
                return None;
            }
            self.metrics.bump("swap_abandoned", 1);
            return Some(self.complete_seq(s, FinishReason::MaxTokens));
        }
        None
    }

    /// Drain everything currently submitted (batch-compatibility shim
    /// over the handle API: completions are the streams' terminal
    /// artifacts).
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>, EngineError> {
        let mut done = Vec::new();
        while self.pending() > 0 {
            let stepped = self.step()?;
            let no_progress = stepped.is_empty() && self.running.is_empty();
            done.extend(stepped);
            if no_progress {
                // Waiting sequences that can never be admitted => reject.
                match self.reject_unschedulable() {
                    Some(c) => done.push(c),
                    None => {
                        // Latent progress: a partially-prefilled head or a
                        // swapped-out sequence will advance on a later
                        // step (interleave parity / pool drain) — keep
                        // stepping instead of abandoning the drain.
                        let latent = self
                            .waiting
                            .front()
                            .is_some_and(|s| s.prefilled_tokens > 0)
                            || !self.swapped.is_empty();
                        if !latent {
                            break;
                        }
                    }
                }
            }
        }
        Ok(done)
    }

    /// Open-loop serve: admit requests at their arrival offsets (wall
    /// clock), run until all complete.  Returns per-run metrics.
    ///
    /// Batch-compatibility shim over the handle API — each spec is
    /// submitted through [`Engine::submit`] (handles created, events
    /// streamed) and the returned completions are the terminal artifacts
    /// of those streams, byte-identical to the pre-streaming engine.  For
    /// a continuously streaming driver see `main.rs serve`.
    pub fn serve(
        &mut self,
        mut specs: Vec<RequestSpec>,
    ) -> Result<Vec<Completion>, EngineError> {
        specs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let start = Instant::now();
        let mut next = 0usize;
        let mut done = Vec::new();
        while next < specs.len() || self.pending() > 0 {
            // Admit everything that has arrived by now.
            let now = start.elapsed().as_secs_f64();
            while next < specs.len() && specs[next].arrival_s <= now {
                let s = &specs[next];
                // The shim drops its handles: completions carry the
                // result, and streams never block the engine.
                self.submit(Request {
                    id: s.id,
                    prompt: s.prompt.clone(),
                    params: super::request::SamplingParams {
                        temperature: s.temperature,
                        max_new_tokens: s.max_new_tokens,
                        ..Default::default()
                    },
                    priority: s.priority,
                })?;
                next += 1;
            }
            if self.pending() == 0 {
                // Nothing in flight: sleep until the next arrival.
                if next < specs.len() {
                    let wait = specs[next].arrival_s - start.elapsed().as_secs_f64();
                    if wait > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            wait.min(0.05),
                        ));
                    }
                }
                continue;
            }
            let stepped = self.step()?;
            let no_progress = stepped.is_empty() && self.running.is_empty();
            done.extend(stepped);
            if no_progress {
                // Same backstop as run_to_completion: a never-admittable
                // head becomes Rejected instead of spinning on Idle.
                if let Some(c) = self.reject_unschedulable() {
                    done.push(c);
                }
            }
        }
        self.metrics.wall = start.elapsed();
        Ok(done)
    }

    // --- swap tier (DESIGN.md §12) ---------------------------------------

    /// Resume swapped-out sequences (FCFS) while the pool and the
    /// concurrency budget allow.  Runs at the top of every step, ahead of
    /// the planner — resumed rows rejoin the running set and decode this
    /// very step.
    fn swap_in_ready(&mut self) -> Result<(), EngineError> {
        while !self.swapped.is_empty()
            && self.running.len() < self.cfg.max_concurrency
        {
            let id = self.swapped[0].id;
            match self.kvmgr.swap_in(id)? {
                Some(blocks) => {
                    self.metrics.swap_in_blocks += blocks as u64;
                    if self.trace.on() {
                        self.trace.emit(self.clock, id, EventKind::SwapIn { blocks: blocks as u64 });
                    }
                    let mut s = self.swapped.remove(0);
                    // Reconcile the one-token accounting deficit every
                    // preempt site leaves behind: the token that triggered
                    // preemption was emitted but its KV slot never
                    // appended, so the block table trails the context by
                    // at most one token.
                    let table_len =
                        self.kvmgr.table(id).map_or(0, |t| t.len());
                    debug_assert!(
                        s.context_len() >= table_len
                            && s.context_len() - table_len <= 1
                    );
                    let mut ok = true;
                    for _ in table_len..s.context_len() {
                        if !self.kvmgr.append_token(id)? {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        s.state = SeqState::Running;
                        self.running.push(s);
                    } else {
                        // The pool refilled under us: park the sequence
                        // again — the ledger capacity this swap-in just
                        // vacated guarantees the swap-out lands.
                        let n = self
                            .kvmgr
                            .swap_out(id)?
                            .expect("ledger capacity was just vacated");
                        self.metrics.swap_out_blocks += n as u64;
                        // Park-back, not a preemption: no `preempt` event
                        // (and no `swapped_out_seqs` bump) — the trace
                        // mirrors the metrics split exactly.
                        if self.trace.on() {
                            self.trace.emit(self.clock, id, EventKind::SwapOut { blocks: n as u64 });
                        }
                        self.swapped.insert(0, s);
                        break;
                    }
                }
                None => break, // transient pool exhaustion; retry next step
            }
        }
        Ok(())
    }

    /// Try to park a preemption victim in the swap tier instead of
    /// finishing it early.  Prices the PCIe round trip of the victim's
    /// *private* blocks (prefix-cache-attached blocks stay on-device)
    /// against recomputing its context, per `swap_policy`.  `Ok(None)`
    /// means the caller falls back to legacy finish-early preemption:
    /// swap disabled, policy says recompute, or the ledger is full.
    fn swap_preempt(
        &mut self,
        id: u64,
        context_len: usize,
    ) -> Result<Option<usize>, EngineError> {
        if self.kvmgr.swap_capacity() == 0 || self.kvmgr.is_swapped(id) {
            return Ok(None);
        }
        let Some(table) = self.kvmgr.table(id) else {
            return Ok(None);
        };
        let private =
            table.num_blocks() - self.kvmgr.seq_attached_nodes(id).len();
        let (n_layers, n_heads, dh) = {
            let m = self.model();
            (m.n_layers, m.n_heads, m.head_dim())
        };
        let pcie = PcieModel::default();
        let bytes = private
            * PcieModel::kv_block_bytes(
                n_layers,
                n_heads,
                dh,
                self.cfg.kv_block_size,
            );
        let swap_us = 2.0 * pcie.transfer_us(bytes); // out + back in
        let recompute_us =
            pcie.recompute_us(context_len, PREFILL_US_PER_TOKEN);
        if choose(self.cfg.swap_policy, swap_us, recompute_us)
            == PreemptAction::Recompute
        {
            self.metrics.bump("swap_declined_by_policy", 1);
            return Ok(None);
        }
        match self.kvmgr.swap_out(id)? {
            Some(n) => {
                self.metrics.bump("swapped_out_seqs", 1);
                Ok(Some(n))
            }
            None => Ok(None), // ledger full
        }
    }

    // --- chunked prefill (DESIGN.md §12) ---------------------------------

    /// One chunk window over the queue head's prompt: restore the KV built
    /// so far into batch row 0, run the next `prefill_chunk_tokens` prompt
    /// tokens through the cached-prefill artifact (per-row offset = tokens
    /// already resident), and scatter the grown KV back.  Intermediate
    /// chunks are pure KV builds — no `sample_hidden`, no Philox step — so
    /// a chunked prompt's first token draws exactly the same coordinates
    /// as whole-prompt prefill: once the remainder fits one window the
    /// head falls through to the normal prefill scan (`Plan::Prefill`),
    /// batches companions, and samples there.
    fn do_chunk_prefill(
        &mut self,
        seq_id: u64,
    ) -> Result<Vec<Completion>, EngineError> {
        let m = self.model().clone();
        let b = m.prefill_b;
        let bs = self.cfg.kv_block_size;
        let dh = m.head_dim();
        let idx = self
            .waiting
            .iter()
            .position(|s| s.id == seq_id)
            .context("planned sequence vanished")?;
        let mut s = self.waiting.remove(idx).unwrap();
        if s.prefilled_tokens == 0 {
            // Chunk start: allocate the FULL prompt's blocks up front (the
            // plan's admission probe covered them) and seed per-sequence
            // KV with any prefix-cache hit.
            let a = match self.kvmgr.register_with_prefix(s.id, &s.prompt) {
                Ok(a) => a,
                Err(_) => {
                    // The pool raced below the plan's estimate (shared
                    // evictable headroom): re-queue and re-plan, exactly
                    // as `do_prefill` does.
                    self.metrics.bump("prefill_admission_retries", 1);
                    self.waiting.push_front(s);
                    return Ok(Vec::new());
                }
            };
            let mut k = vec![0.0f32; self.kv_len()];
            let mut v = vec![0.0f32; self.kv_len()];
            for (j, blk) in a.kv.iter().enumerate() {
                // Payload [L, H, bs, Dh] -> dense [L, H, S, Dh] at
                // positions [j*bs, (j+1)*bs).
                for l in 0..m.n_layers {
                    for h in 0..m.n_heads {
                        let src = (l * m.n_heads + h) * bs * dh;
                        let dst =
                            ((l * m.n_heads + h) * m.max_seq + j * bs) * dh;
                        k[dst..dst + bs * dh]
                            .copy_from_slice(&blk.k[src..src + bs * dh]);
                        v[dst..dst + bs * dh]
                            .copy_from_slice(&blk.v[src..src + bs * dh]);
                    }
                }
            }
            s.kv = Some(SeqKv { k, v });
            s.prefilled_tokens = a.cached_tokens;
            if a.cached_tokens > 0 {
                self.metrics.cached_prefill_tokens += a.cached_tokens as u64;
                if self.trace.on() {
                    self.trace.emit(
                        self.clock,
                        s.id,
                        EventKind::RadixAttach { tokens: a.cached_tokens as u64 },
                    );
                }
            }
        }
        let chunk = self
            .sched
            .prefill_chunk_tokens
            .min(*m.prefill_t_buckets.last().unwrap());
        // Always leave >= 1 token for the sampling final chunk.
        let take = chunk.min(
            s.prompt
                .len()
                .saturating_sub(1)
                .saturating_sub(s.prefilled_tokens),
        );
        if take == 0 {
            self.waiting.push_front(s);
            return Ok(Vec::new());
        }
        let row_len = m.n_heads * m.max_seq * dh;
        let kv_batch_len = m.n_layers * b * row_len;
        let mut kvk = vec![0.0f32; kv_batch_len];
        let mut kvv = vec![0.0f32; kv_batch_len];
        {
            let kv = s.kv.as_ref().context("chunking sequence without KV")?;
            for l in 0..m.n_layers {
                let src = l * row_len;
                let dst = l * b * row_len;
                kvk[dst..dst + row_len]
                    .copy_from_slice(&kv.k[src..src + row_len]);
                kvv[dst..dst + row_len]
                    .copy_from_slice(&kv.v[src..src + row_len]);
            }
        }
        let t_bucket = pick_bucket(&m.prefill_t_buckets, take);
        let mut tokens = vec![0i32; b * t_bucket];
        let mut lengths = vec![1i32; b]; // pad rows: length 1 of token 0
        let mut offsets = vec![0i32; b];
        tokens[..take].copy_from_slice(
            &s.prompt[s.prefilled_tokens..s.prefilled_tokens + take],
        );
        lengths[0] = take as i32;
        offsets[0] = s.prefilled_tokens as i32;
        let kv_shape = vec![m.n_layers, b, m.n_heads, m.max_seq, dh];
        let kvk_lit = Tensor::F32(kvk, kv_shape.clone()).to_literal()?;
        let kvv_lit = Tensor::F32(kvv, kv_shape).to_literal()?;
        let name = format!("prefill_cached_b{b}_t{t_bucket}");
        let exe =
            self.rt.load(&name).map_err(|e| EngineError::artifact(&name, e))?;
        let off_lit = Tensor::I32(offsets, vec![b]).to_literal()?;
        let tok_lit = Tensor::I32(tokens, vec![b, t_bucket]).to_literal()?;
        let len_lit = Tensor::I32(lengths, vec![b]).to_literal()?;
        let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
        lits.extend([&kvk_lit, &kvv_lit, &off_lit, &tok_lit, &len_lit]);
        let out = exe.run_literals(&lits)?;
        let kv_k = out[0].as_f32()?;
        let kv_v = out[1].as_f32()?;
        {
            let kv = s.kv.as_mut().context("chunking sequence without KV")?;
            for l in 0..m.n_layers {
                let src = l * b * row_len;
                let dst = l * row_len;
                kv.k[dst..dst + row_len]
                    .copy_from_slice(&kv_k[src..src + row_len]);
                kv.v[dst..dst + row_len]
                    .copy_from_slice(&kv_v[src..src + row_len]);
            }
        }
        s.prefilled_tokens += take;
        self.metrics.chunked_prefill_steps += 1;
        if self.trace.on() {
            self.trace.emit(
                self.clock,
                s.id,
                EventKind::ChunkWindow { take, prefilled: s.prefilled_tokens },
            );
        }
        self.metrics.bump("prefill_cached_runs", 1);
        self.metrics.bump("prefill_pad_rows", (b - 1) as u64);
        // The head stays Waiting at the queue front: the next window (or
        // the sampling final chunk) picks it up by priority order.
        self.waiting.push_front(s);
        Ok(Vec::new())
    }

    // --- prefill ---------------------------------------------------------

    fn do_prefill(
        &mut self,
        seq_ids: &[u64],
        _t_bucket: usize,
    ) -> Result<Vec<Completion>, EngineError> {
        let m = self.model().clone();
        let b = m.prefill_b;
        let bs = self.cfg.kv_block_size;
        let dh = m.head_dim();
        // Pull the chosen sequences out of the waiting queue (keep order).
        let mut seqs: Vec<Sequence> = Vec::with_capacity(seq_ids.len());
        for id in seq_ids {
            let idx = self
                .waiting
                .iter()
                .position(|s| s.id == *id)
                .context("planned sequence vanished")?;
            seqs.push(self.waiting.remove(idx).unwrap());
        }

        // Register KV accounting now that admission is final; with prefix
        // caching on this attaches each prompt's cached full-block prefix
        // copy-on-write and hands back the blocks' physical KV payloads.
        // Backstop: if the pool raced below the plan's estimate (shared
        // evictable headroom), re-queue the victim at the front instead of
        // failing the step — it re-plans next iteration.
        let mut attaches: Vec<PrefixAttach> = Vec::with_capacity(seqs.len());
        // Rows whose "cached prefix" is their OWN partial KV from earlier
        // chunk windows (already registered; restored from `s.kv`, not
        // from prefix-cache payloads).
        let mut own_restore: Vec<bool> = Vec::with_capacity(seqs.len());
        let mut admitted: Vec<Sequence> = Vec::with_capacity(seqs.len());
        let mut requeue: Vec<Sequence> = Vec::new();
        for s in seqs {
            if s.prefilled_tokens > 0 {
                // Final chunk of a partially-prefilled head: blocks were
                // allocated at chunk start, KV restored below from the
                // sequence's own storage.  Synthetic attach carries the
                // offset only.
                attaches.push(PrefixAttach {
                    cached_tokens: s.prefilled_tokens,
                    kv: Vec::new(),
                });
                own_restore.push(true);
                admitted.push(s);
                continue;
            }
            match self.kvmgr.register_with_prefix(s.id, &s.prompt) {
                Ok(a) => {
                    attaches.push(a);
                    own_restore.push(false);
                    admitted.push(s);
                }
                Err(_) => {
                    self.metrics.bump("prefill_admission_retries", 1);
                    requeue.push(s);
                }
            }
        }
        for s in requeue.into_iter().rev() {
            self.waiting.push_front(s);
        }
        let seqs = admitted;
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        // Prefix-cache hits only (partial rows' restored tokens were
        // counted when their chunk windows ran).
        let attached_tokens: u64 = attaches
            .iter()
            .zip(&own_restore)
            .filter(|(_, &own)| !own)
            .map(|(a, _)| a.cached_tokens as u64)
            .sum();
        let any_partial = own_restore.iter().any(|&own| own);
        // Without the prefill_cached artifacts the hit still pays for
        // admission headroom, but the compute path stays full-prefill.
        // (Partial rows exist only when chunking is on, which is itself
        // gated on those artifacts.)
        let use_cached =
            self.cached_prefill_available && (attached_tokens > 0 || any_partial);
        if use_cached {
            // Only count tokens whose prefill compute was actually
            // skipped — `prefix_hit_rate()` must never advertise a TTFT
            // win the artifact fallback did not deliver.
            self.metrics.cached_prefill_tokens += attached_tokens;
            if self.trace.on() {
                for ((s, a), &own) in seqs.iter().zip(&attaches).zip(&own_restore) {
                    if !own && a.cached_tokens > 0 {
                        self.trace.emit(
                            self.clock,
                            s.id,
                            EventKind::RadixAttach { tokens: a.cached_tokens as u64 },
                        );
                    }
                }
            }
        } else if attached_tokens > 0 {
            self.metrics.bump("prefix_attached_unskipped_tokens", attached_tokens);
        }

        // Fixed-shape bucket: the cached path packs only each prompt's
        // uncached suffix, so hit-heavy batches drop into smaller prefill
        // executables (the scheduler's plan bucket is recomputed here from
        // the attach results, which are authoritative).
        let longest = seqs
            .iter()
            .zip(&attaches)
            .map(|(s, a)| s.prompt.len() - if use_cached { a.cached_tokens } else { 0 })
            .max()
            .expect("prefill plan is never empty");
        let t_bucket = pick_bucket(&m.prefill_t_buckets, longest);

        // Pack the padded (suffix) token matrix [B, T] + lengths [B]
        // (+ per-row prefix offsets for the cached path).
        let mut tokens = vec![0i32; b * t_bucket];
        let mut lengths = vec![1i32; b]; // pad rows: length 1 of token 0
        let mut offsets = vec![0i32; b];
        for (row, s) in seqs.iter().enumerate() {
            let cached = if use_cached { attaches[row].cached_tokens } else { 0 };
            let suffix = &s.prompt[cached..];
            lengths[row] = suffix.len() as i32;
            offsets[row] = cached as i32;
            tokens[row * t_bucket..row * t_bucket + suffix.len()]
                .copy_from_slice(suffix);
        }
        let pad_rows = b - seqs.len();
        self.metrics.bump("prefill_pad_rows", pad_rows as u64);

        let out = if use_cached {
            // Restore the attached prefix KV byte-identically into the
            // batch cache literals and run ONLY the suffix through the
            // cached-prefill artifact (positions offset per row; attends
            // over restored prefix + in-suffix causal — DESIGN.md §10).
            let row_len = m.n_heads * m.max_seq * dh;
            let kv_batch_len = m.n_layers * b * row_len;
            let mut kvk = vec![0.0f32; kv_batch_len];
            let mut kvv = vec![0.0f32; kv_batch_len];
            for (row, (a, &own)) in
                attaches.iter().zip(&own_restore).enumerate()
            {
                if own {
                    // Partial row: its prefix is its own chunk-built KV,
                    // dense [L, H, S, Dh] -> batch row verbatim.
                    let kv = seqs[row]
                        .kv
                        .as_ref()
                        .context("partial sequence without KV")?;
                    for l in 0..m.n_layers {
                        let src = l * row_len;
                        let dst = (l * b + row) * row_len;
                        kvk[dst..dst + row_len]
                            .copy_from_slice(&kv.k[src..src + row_len]);
                        kvv[dst..dst + row_len]
                            .copy_from_slice(&kv.v[src..src + row_len]);
                    }
                    continue;
                }
                for (j, blk) in a.kv.iter().enumerate() {
                    // Payload [L, H, bs, Dh] -> batch [L, B, H, S, Dh] at
                    // positions [j*bs, (j+1)*bs).
                    for l in 0..m.n_layers {
                        for h in 0..m.n_heads {
                            let src = (l * m.n_heads + h) * bs * dh;
                            let dst = (((l * b + row) * m.n_heads + h) * m.max_seq + j * bs) * dh;
                            kvk[dst..dst + bs * dh].copy_from_slice(&blk.k[src..src + bs * dh]);
                            kvv[dst..dst + bs * dh].copy_from_slice(&blk.v[src..src + bs * dh]);
                        }
                    }
                }
            }
            let kv_shape = vec![m.n_layers, b, m.n_heads, m.max_seq, dh];
            let kvk_lit = Tensor::F32(kvk, kv_shape.clone()).to_literal()?;
            let kvv_lit = Tensor::F32(kvv, kv_shape).to_literal()?;
            let name = format!("prefill_cached_b{b}_t{t_bucket}");
            let exe = self.rt.load(&name).map_err(|e| EngineError::artifact(&name, e))?;
            let off_lit = Tensor::I32(offsets, vec![b]).to_literal()?;
            let tok_lit = Tensor::I32(tokens, vec![b, t_bucket]).to_literal()?;
            let len_lit = Tensor::I32(lengths, vec![b]).to_literal()?;
            let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
            lits.extend([&kvk_lit, &kvv_lit, &off_lit, &tok_lit, &len_lit]);
            self.metrics.bump("prefill_cached_runs", 1);
            exe.run_literals(&lits)?
        } else {
            let name = format!("prefill_b{b}_t{t_bucket}");
            let exe = self.rt.load(&name).map_err(|e| EngineError::artifact(&name, e))?;
            let tok_lit = Tensor::I32(tokens, vec![b, t_bucket]).to_literal()?;
            let len_lit = Tensor::I32(lengths, vec![b]).to_literal()?;
            let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
            lits.push(&tok_lit);
            lits.push(&len_lit);
            exe.run_literals(&lits)?
        };
        let kv_k = out[0].as_f32()?;
        let kv_v = out[1].as_f32()?;
        let hidden = out[2].clone();

        // First output token comes from the prefill hidden state through the
        // fused FlashSampling LM head.
        let sample_name = format!("sample_hidden_b{b}");
        let sampler = self
            .rt
            .load(&sample_name)
            .map_err(|e| EngineError::artifact(&sample_name, e))?;
        let hid_lit = hidden.to_literal()?;
        let seed_lit = Tensor::seed(self.key).to_literal()?;
        // Hoisted: the trace records each first token's Philox
        // `(row, counter-step)` coordinates below.
        let sample_step = self.bump_step();
        let step_lit = Tensor::scalar_u32(sample_step).to_literal()?;
        // Per-row tau (ABI v2): each prompt's own temperature; pad rows
        // sample at tau = 1 and are discarded below.
        let taus: Vec<f32> = (0..b)
            .map(|row| seqs.get(row).map_or(1.0, |s| s.params.temperature))
            .collect();
        let tau_lit = Tensor::F32(taus, vec![b]).to_literal()?;
        let first = sampler.run_literals(&[
            &hid_lit,
            &self.params_lit[self.lm_head_idx],
            &seed_lit,
            &step_lit,
            &tau_lit,
        ])?;
        let first_tokens = first[0].as_i32()?.to_vec();

        // Slice each row's KV out of the [L, B, H, S, Dh] batch tensors.
        let row_len = m.n_heads * m.max_seq * dh;
        let now = Instant::now();
        let clock = self.clock;
        let mut completions = Vec::new();
        for (row, mut s) in seqs.into_iter().enumerate() {
            let mut k = vec![0.0f32; self.kv_len()];
            let mut v = vec![0.0f32; self.kv_len()];
            for l in 0..m.n_layers {
                let src = (l * b + row) * row_len;
                let dst = l * row_len;
                k[dst..dst + row_len].copy_from_slice(&kv_k[src..src + row_len]);
                v[dst..dst + row_len].copy_from_slice(&kv_v[src..src + row_len]);
            }
            s.kv = Some(SeqKv { k, v });
            if self.cfg.prefix_caching {
                // Publish the prompt's full blocks (prefix + the just-
                // computed remainder) so later shared-prefix requests hit.
                // Payload layout [L, H, bs, Dh], sliced from the per-seq
                // dense [L, H, S, Dh] KV; runs only for new cache nodes.
                let kv = s.kv.as_ref().expect("set above");
                let (n_layers, n_heads, max_seq) = (m.n_layers, m.n_heads, m.max_seq);
                self.kvmgr.insert_prefix(s.id, &s.prompt, |j| {
                    let mut pk = vec![0.0f32; n_layers * n_heads * bs * dh];
                    let mut pv = vec![0.0f32; n_layers * n_heads * bs * dh];
                    for l in 0..n_layers {
                        for h in 0..n_heads {
                            let src = ((l * n_heads + h) * max_seq + j * bs) * dh;
                            let dst = (l * n_heads + h) * bs * dh;
                            pk[dst..dst + bs * dh].copy_from_slice(&kv.k[src..src + bs * dh]);
                            pv[dst..dst + bs * dh].copy_from_slice(&kv.v[src..src + bs * dh]);
                        }
                    }
                    BlockKv { k: pk, v: pv }
                })?;
            }
            s.generated.push(first_tokens[row]);
            s.state = SeqState::Running;
            s.first_token_at = Some(now);
            s.last_token_at = Some(now);
            s.timing.ttft = Some(now - s.arrived);
            self.metrics.tokens_generated += 1;
            self.metrics.prefill_tokens += s.prompt.len() as u64;
            if self.trace.on() {
                self.trace.emit(
                    clock,
                    s.id,
                    EventKind::Prefill { prompt_len: s.prompt.len() },
                );
                self.trace.emit(
                    clock,
                    s.id,
                    EventKind::FirstToken {
                        row,
                        cstep: sample_step,
                        token: first_tokens[row],
                    },
                );
            }
            emit_token(&self.streams, &mut s, first_tokens[row], clock);
            if let Some(reason) = s.finished() {
                self.kvmgr.release(s.id)?;
                completions.push(self.complete_seq(s, reason));
            } else if !self.kvmgr.append_token(s.id)? {
                // KV pool exhausted even after cache eviction: preempt —
                // the same exhaustion handling as the decode path.  (The
                // old `?`-only call dropped this signal and let the block
                // table fall one token behind the sequence's context.)
                // The swap tier, when priced in, parks the victim instead
                // of finishing it early.
                match self.swap_preempt(s.id, s.context_len())? {
                    Some(n) => {
                        self.metrics.swap_out_blocks += n as u64;
                        if self.trace.on() {
                            self.trace.emit(clock, s.id, EventKind::Preempt { kind: "swap" });
                            self.trace.emit(clock, s.id, EventKind::SwapOut { blocks: n as u64 });
                        }
                        s.state = SeqState::Preempted;
                        self.swapped.push(s);
                    }
                    None => {
                        self.metrics.bump("preempted", 1);
                        if self.trace.on() {
                            self.trace.emit(clock, s.id, EventKind::Preempt { kind: "recompute" });
                        }
                        self.kvmgr.release(s.id)?;
                        completions
                            .push(self.complete_seq(s, FinishReason::MaxTokens));
                    }
                }
            } else {
                self.running.push(s);
            }
        }
        self.metrics.counters.insert(
            "prefix_evicted_blocks".to_string(),
            self.kvmgr.evicted_blocks(),
        );
        Ok(completions)
    }

    // --- decode ----------------------------------------------------------

    /// Pull the cached batch KV back into per-sequence storage (lazy sync
    /// when the batch composition changes).  Sequences that finished since
    /// the cache was taken are skipped — their blocks are already released.
    fn sync_cache_to_seqs(&mut self) -> Result<()> {
        let Some(cache) = self.decode_cache.take() else {
            return Ok(());
        };
        let m = self.model().clone();
        let row_len = m.n_heads * m.max_seq * m.head_dim();
        let kvk = Tensor::from_literal(&cache.kv_k)?;
        let kvv = Tensor::from_literal(&cache.kv_v)?;
        let (kvk, kvv) = (kvk.as_f32()?, kvv.as_f32()?);
        let b = cache.b_bucket;
        for (slot, id) in cache.seq_ids.iter().enumerate() {
            let Some(seq) = self.running.iter_mut().find(|s| s.id == *id) else {
                continue;
            };
            let kv = seq.kv.as_mut().context("running sequence without KV")?;
            for l in 0..m.n_layers {
                let src = (l * b + slot) * row_len;
                let dst = l * row_len;
                kv.k[dst..dst + row_len].copy_from_slice(&kvk[src..src + row_len]);
                kv.v[dst..dst + row_len].copy_from_slice(&kvv[src..src + row_len]);
            }
        }
        Ok(())
    }

    /// Gather the planned rows' per-sequence KV into the dense
    /// `[L, B, H, S, Dh]` batch literals the decode artifacts consume —
    /// the decode slow path, shared with the spec-decode inner loop.
    fn gather_batch_kv(
        &self,
        rows: &[usize],
        b_bucket: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let m = &self.rt.manifest().model;
        let row_len = m.n_heads * m.max_seq * m.head_dim();
        let kv_batch_len = m.n_layers * b_bucket * row_len;
        let mut kv_k = vec![0.0f32; kv_batch_len];
        let mut kv_v = vec![0.0f32; kv_batch_len];
        for (slot, &ri) in rows.iter().enumerate() {
            let s = &self.running[ri];
            let kv = s.kv.as_ref().context("running sequence without KV")?;
            for l in 0..m.n_layers {
                let dst = (l * b_bucket + slot) * row_len;
                let src = l * row_len;
                kv_k[dst..dst + row_len]
                    .copy_from_slice(&kv.k[src..src + row_len]);
                kv_v[dst..dst + row_len]
                    .copy_from_slice(&kv.v[src..src + row_len]);
            }
        }
        let kv_shape =
            vec![m.n_layers, b_bucket, m.n_heads, m.max_seq, m.head_dim()];
        Ok((
            Tensor::F32(kv_k, kv_shape.clone()).to_literal()?,
            Tensor::F32(kv_v, kv_shape).to_literal()?,
        ))
    }

    /// Remove retired rows from the running set (descending index keeps
    /// positions stable) — the shared tail of both decode paths.
    /// `Some(reason)` rows release their KV blocks and complete; `None`
    /// rows move to the swap tier (their private blocks were already
    /// parked by `swap_preempt`; per-sequence KV must be current — the
    /// callers sync the decode cache first when swaps are pending).
    fn retire_rows(
        &mut self,
        mut retired: Vec<(usize, Option<FinishReason>)>,
    ) -> Result<Vec<Completion>, EngineError> {
        retired.sort_by(|a, b| b.0.cmp(&a.0));
        let mut completions = Vec::new();
        for (ri, reason) in retired {
            let mut s = self.running.remove(ri);
            match reason {
                Some(reason) => {
                    self.kvmgr.release(s.id)?;
                    completions.push(self.complete_seq(s, reason));
                }
                None => {
                    s.state = SeqState::Preempted;
                    self.swapped.push(s);
                }
            }
        }
        Ok(completions)
    }

    fn do_decode(
        &mut self,
        seq_ids: &[u64],
        b_bucket: usize,
    ) -> Result<Vec<Completion>, EngineError> {
        // Steady-state fast path: same batch as last step => reuse the
        // previous output literals as this step's KV inputs directly.
        let cache_hit = self
            .decode_cache
            .as_ref()
            .is_some_and(|c| c.seq_ids == seq_ids && c.b_bucket == b_bucket);
        if !cache_hit {
            self.sync_cache_to_seqs()?;
        }

        let t_gather = Instant::now();
        let rows: Vec<usize> = seq_ids
            .iter()
            .map(|id| {
                self.running
                    .iter()
                    .position(|s| s.id == *id)
                    .context("planned sequence vanished")
            })
            .collect::<Result<_>>()?;

        let mut pos = vec![0i32; b_bucket];
        let mut tok = vec![0i32; b_bucket];
        for (slot, &ri) in rows.iter().enumerate() {
            let s = &self.running[ri];
            pos[slot] = s.next_pos() as i32;
            tok[slot] = s.input_token();
        }

        let (kvk_lit, kvv_lit) = if cache_hit {
            self.metrics.bump("decode_cache_hits", 1);
            let c = self.decode_cache.take().unwrap();
            (c.kv_k, c.kv_v)
        } else {
            self.gather_batch_kv(&rows, b_bucket)?
        };
        self.metrics.bump("decode_pad_rows", (b_bucket - rows.len()) as u64);
        self.metrics.decode_batch_sizes.push(rows.len());
        self.metrics.bump("decode_gather_us", t_gather.elapsed().as_micros() as u64);

        let t_lit = Instant::now();
        let pos_lit = Tensor::I32(pos, vec![b_bucket]).to_literal()?;
        let tok_lit = Tensor::I32(tok, vec![b_bucket]).to_literal()?;
        // Per-row tau (ABI v2): heterogeneous temperatures share the batch.
        let mut taus = vec![1.0f32; b_bucket];
        for (slot, &ri) in rows.iter().enumerate() {
            taus[slot] = self.running[ri].params.temperature;
        }

        let (new_k, new_v, samples, cstep) = if let Some(tp) = self.cfg.tp {
            // TP-sharded decode (DESIGN.md §13): the transformer step runs
            // the hidden-state artifact (no sampling epilogue — it takes no
            // seed/step/tau inputs), then the LM-head matmul + FlashSampling
            // epilogue fan out across vocab shards through the orchestrator.
            let name = format!("decode_hidden_b{b_bucket}");
            let exe =
                self.rt.load(&name).map_err(|e| EngineError::artifact(&name, e))?;
            let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
            lits.extend([&kvk_lit, &kvv_lit, &pos_lit, &tok_lit]);
            self.metrics.bump("decode_lit_us", t_lit.elapsed().as_micros() as u64);
            let t_exec = Instant::now();
            let mut out = exe.run_literals_raw(&lits)?;
            if out.len() != 3 {
                return Err(EngineError::artifact(
                    &name,
                    anyhow::anyhow!("hidden decode artifact returned {} outputs",
                                    out.len()),
                ));
            }
            let hidden_lit = out.pop().unwrap();
            let new_v = out.pop().unwrap();
            let new_k = out.pop().unwrap();
            let hidden = Tensor::from_literal(&hidden_lit)?.as_f32()?.to_vec();
            // One counter bump per decode step, same position as the
            // single-shard path: the distributed merge consumes identical
            // Philox (row, counter-step) coordinates, so the token stream
            // is TP-invariant (rust/tests/integration_tp.rs fan-out test).
            let step = self.bump_step();
            let r = {
                let orch = self.tp_orchestrator(b_bucket)?;
                orch.step(&hidden, step, &taus, tp.strategy)?
            };
            self.metrics.bump("decode_exec_us", t_exec.elapsed().as_micros() as u64);
            self.metrics.bump("tp_wire_bytes", r.wire_bytes);
            (new_k, new_v, r.samples, step)
        } else {
            let kind = if self.cfg.uses_baseline_artifact() {
                "decode_baseline"
            } else {
                "decode_sample"
            };
            // Certified sub-vocab candidate tiles for this batch
            // (DESIGN.md §16), merged across the batch's live candidate
            // sets.  `None` routes straight to the full-vocab artifact.
            let tiles: Option<Vec<i32>> = match self.subvocab.as_mut() {
                Some(sv) if kind == "decode_sample" => {
                    Some(sv.batch_tiles(seq_ids, SUB_TILE_SLOTS))
                }
                _ => None,
            };
            let seed_lit = Tensor::seed(self.key).to_literal()?;
            // Hoisted: the trace records each token's Philox coordinates.
            // The step bumps ONCE even when the certificate forces the
            // full-vocab fallback below — both passes draw the same Gumbel
            // noise, which is what makes the fallback token bit-identical.
            let step = self.bump_step();
            let step_lit = Tensor::scalar_u32(step).to_literal()?;
            let tau_host = taus.clone();
            let tau_lit = Tensor::F32(taus, vec![b_bucket]).to_literal()?;
            self.metrics.bump("decode_lit_us", t_lit.elapsed().as_micros() as u64);

            // Tile-subset attempt: run the candidate tiles, then evaluate
            // the Cauchy–Schwarz certificate host-side per active row from
            // the artifact's (winner score, hidden norm) outputs and the
            // exact per-tile max Gumbel.  Admit the batch only when EVERY
            // active row's winner provably beats all excluded tiles.
            let mut sub_result: Option<(xla::Literal, xla::Literal, Vec<i32>)> =
                None;
            if let Some(tiles) = &tiles {
                let name = format!("decode_sample_sub_b{b_bucket}");
                let exe = self
                    .rt
                    .load(&name)
                    .map_err(|e| EngineError::artifact(&name, e))?;
                let tiles_lit =
                    Tensor::I32(tiles.clone(), vec![SUB_TILE_SLOTS]).to_literal()?;
                let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
                lits.extend([&kvk_lit, &kvv_lit, &pos_lit, &tok_lit, &seed_lit,
                             &step_lit, &tau_lit, &tiles_lit]);
                let t_exec = Instant::now();
                let mut out = exe.run_literals_raw(&lits)?;
                self.metrics
                    .bump("decode_exec_us", t_exec.elapsed().as_micros() as u64);
                if out.len() != 5 {
                    return Err(EngineError::artifact(
                        &name,
                        anyhow::anyhow!(
                            "sub-vocab decode artifact returned {} outputs",
                            out.len()
                        ),
                    ));
                }
                let h_norm_lit = out.pop().unwrap();
                let score_lit = out.pop().unwrap();
                let sample_lit = out.pop().unwrap();
                let new_v = out.pop().unwrap();
                let new_k = out.pop().unwrap();
                let scores = Tensor::from_literal(&score_lit)?.as_f32()?.to_vec();
                let h_norms = Tensor::from_literal(&h_norm_lit)?.as_f32()?.to_vec();
                let sv = self.subvocab.as_ref().expect("tiles imply state");
                // Active rows only: padding slots ran a dummy (pos 0,
                // token 0) forward pass whose certificate is meaningless
                // and whose sample is discarded anyway.
                let admitted = (0..rows.len()).all(|slot| {
                    let bound = subvocab::excluded_bound(
                        &sv.norms,
                        tiles,
                        h_norms[slot],
                        tau_host[slot],
                        self.key,
                        slot as u32,
                        step,
                    );
                    scores[slot] > bound + sv.cfg.slack
                });
                let active = tiles.iter().filter(|&&t| t >= 0).count() as u64;
                let skipped = sv.norms.n_tiles() as u64 - active;
                self.metrics.bump("subvocab_steps", 1);
                let ev_id = seq_ids[0];
                if admitted {
                    if self.trace.on() {
                        self.trace.emit(
                            self.clock,
                            ev_id,
                            EventKind::SubvocabSkip { active, skipped },
                        );
                    }
                    let samples =
                        Tensor::from_literal(&sample_lit)?.as_i32()?.to_vec();
                    sub_result = Some((new_k, new_v, samples));
                } else {
                    // Certificate refused: fall through to the full pass
                    // below at the SAME (seed, step, tau) — the KV outputs
                    // there are identical (the transformer step never saw
                    // the tile subset), and the token is the exact sample.
                    self.metrics.bump("subvocab_fallbacks", 1);
                    if self.trace.on() {
                        self.trace.emit(
                            self.clock,
                            ev_id,
                            EventKind::SubvocabFallback { active, skipped },
                        );
                    }
                }
            }

            if let Some((new_k, new_v, samples)) = sub_result {
                (new_k, new_v, samples, step)
            } else {
                let name = format!("{kind}_b{b_bucket}");
                let exe = self
                    .rt
                    .load(&name)
                    .map_err(|e| EngineError::artifact(&name, e))?;
                let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
                lits.extend([&kvk_lit, &kvv_lit, &pos_lit, &tok_lit, &seed_lit,
                             &step_lit, &tau_lit]);
                let t_exec = Instant::now();
                let mut out = exe.run_literals_raw(&lits)?;
                self.metrics
                    .bump("decode_exec_us", t_exec.elapsed().as_micros() as u64);
                if out.len() != 3 {
                    return Err(EngineError::artifact(
                        &name,
                        anyhow::anyhow!(
                            "decode artifact returned {} outputs",
                            out.len()
                        ),
                    ));
                }
                let sample_lit = out.pop().unwrap();
                let new_v = out.pop().unwrap();
                let new_k = out.pop().unwrap();
                let samples = Tensor::from_literal(&sample_lit)?.as_i32()?.to_vec();
                (new_k, new_v, samples, step)
            }
        };

        // The new KV lives on as next step's input (lazy per-seq sync).
        self.decode_cache = Some(DecodeCache {
            seq_ids: seq_ids.to_vec(),
            b_bucket,
            kv_k: new_k,
            kv_v: new_v,
        });

        // Token bookkeeping + completions.
        let now = Instant::now();
        let clock = self.clock;
        let mut retired: Vec<(usize, Option<FinishReason>)> = Vec::new();
        // Pool-exhausted rows: the swap-vs-recompute decision needs `&mut
        // self` (ledger + policy), so collect here and decide post-loop.
        let mut swap_candidates: Vec<(usize, u64, usize)> = Vec::new();
        for (slot, &ri) in rows.iter().enumerate() {
            let s = &mut self.running[ri];
            s.generated.push(samples[slot]);
            if let Some(prev) = s.last_token_at {
                s.timing.token_latencies.push(now - prev);
            }
            s.last_token_at = Some(now);
            emit_token(&self.streams, s, samples[slot], clock);
            self.metrics.tokens_generated += 1;
            // Fold the emission back into the request's candidate set so
            // the hot tiles track the generation online.
            if let Some(sv) = self.subvocab.as_mut() {
                sv.observe_token(self.running[ri].id, samples[slot]);
            }
            if self.trace.on() {
                let id = self.running[ri].id;
                self.trace.emit(
                    clock,
                    id,
                    EventKind::DecodeToken { row: slot, cstep, token: samples[slot] },
                );
            }
            let s = &mut self.running[ri];
            if let Some(reason) = s.finished() {
                retired.push((ri, Some(reason)));
            } else if !self.kvmgr.append_token(s.id)? {
                swap_candidates.push((ri, s.id, s.context_len()));
            }
        }
        for (ri, id, ctx) in swap_candidates {
            match self.swap_preempt(id, ctx)? {
                Some(n) => {
                    self.metrics.swap_out_blocks += n as u64;
                    if self.trace.on() {
                        self.trace.emit(clock, id, EventKind::Preempt { kind: "swap" });
                        self.trace.emit(clock, id, EventKind::SwapOut { blocks: n as u64 });
                    }
                    retired.push((ri, None));
                }
                None => {
                    // KV pool exhausted, no swap: legacy finish-early.
                    self.metrics.bump("preempted", 1);
                    if self.trace.on() {
                        self.trace.emit(clock, id, EventKind::Preempt { kind: "recompute" });
                    }
                    retired.push((ri, Some(FinishReason::MaxTokens)));
                }
            }
        }
        // A swap victim leaves the batch with this step's KV only in the
        // decode-cache literals; fold them back before it departs.
        if retired.iter().any(|(_, r)| r.is_none()) {
            self.sync_cache_to_seqs()?;
        }

        self.retire_rows(retired)
    }

    // --- speculative decode (DESIGN.md §9) -------------------------------

    /// One speculative engine step over the planned decode batch.
    ///
    /// Draft K tokens per row with the deterministic n-gram drafter, run
    /// `K_max`+1 coupled target passes through the fused `decode_sample`
    /// artifact (inner pass `j` feeds draft token `j−1` and samples the
    /// target with fresh Philox noise — the step counter bumps per pass),
    /// then emit each row's target samples while they agree with its draft:
    /// the Gumbel-coupled token-matching rule
    /// ([`crate::specdec::coupled_emit_len`]).  Every emitted token is
    /// literally a target sample conditioned on the already-emitted
    /// prefix, so the output distribution is exactly the target model's —
    /// the construction that makes spec decode admissible on a
    /// sample-only artifact ABI.
    ///
    /// KV rollback protocol: draft positions are reserved optimistically
    /// ([`KvCacheManager::extend`]) and rejected positions are rolled back
    /// afterwards ([`KvCacheManager::truncate`]).  Dense KV entries past
    /// the verified length are dead under the positional causal mask and
    /// get rewritten by later steps.
    fn do_spec_decode(
        &mut self,
        seq_ids: &[u64],
        b_bucket: usize,
        k: usize,
        ngram: usize,
    ) -> Result<Vec<Completion>, EngineError> {
        let m = self.model().clone();

        // Spec steps rewrite per-sequence KV lengths after verification,
        // so the steady-state batch cache never carries across them.
        self.sync_cache_to_seqs()?;

        let rows: Vec<usize> = seq_ids
            .iter()
            .map(|id| {
                self.running
                    .iter()
                    .position(|s| s.id == *id)
                    .context("planned sequence vanished")
            })
            .collect::<Result<_>>()?;

        // 1. Draft per row, capped so the burst fits the request budget
        //    and max_seq, then clamped to the KV blocks the pool can
        //    actually reserve right now (a short grant = a shorter draft
        //    this step, never a failure).
        let mut drafter = NGramDraft { n: ngram, vocab: m.vocab };
        let mut drafts: Vec<Vec<i32>> = Vec::with_capacity(rows.len());
        for (slot, &ri) in rows.iter().enumerate() {
            let s = &self.running[ri];
            let ctx: Vec<i32> =
                s.prompt.iter().chain(s.generated.iter()).copied().collect();
            let budget = s
                .params
                .max_new_tokens
                .saturating_sub(s.generated.len())
                .saturating_sub(1);
            let room = m.max_seq.saturating_sub(s.context_len() + 1);
            let kk = k.min(budget).min(room);
            // Real Philox coordinates per the DraftModel contract (the
            // n-gram drafter is deterministic and ignores them, but a
            // stochastic drafter substituted here must not collapse its
            // noise across rows/steps).
            drafts.push(drafter.draft(&ctx, kk, slot as u32, self.step_counter).tokens);
        }
        let mut reserved = vec![0usize; rows.len()];
        for (slot, &ri) in rows.iter().enumerate() {
            let id = self.running[ri].id;
            reserved[slot] = self.kvmgr.extend(id, drafts[slot].len())?;
            drafts[slot].truncate(reserved[slot]);
        }
        let k_max = drafts.iter().map(|d| d.len()).max().unwrap_or(0);

        // 2. Gather the batch KV once; the inner passes keep it device-
        //    adjacent as literals, exactly like the decode fast path.
        let (mut kvk_lit, mut kvv_lit) = self.gather_batch_kv(&rows, b_bucket)?;

        let exe_name = format!("decode_sample_b{b_bucket}");
        let exe = self
            .rt
            .load(&exe_name)
            .map_err(|e| EngineError::artifact(&exe_name, e))?;
        let base_pos: Vec<usize> =
            rows.iter().map(|&ri| self.running[ri].next_pos()).collect();
        let base_tok: Vec<i32> =
            rows.iter().map(|&ri| self.running[ri].input_token()).collect();
        let mut taus = vec![1.0f32; b_bucket];
        for (slot, &ri) in rows.iter().enumerate() {
            taus[slot] = self.running[ri].params.temperature;
        }
        // Loop-invariant literals: the session seed and the per-row taus
        // do not change across the inner passes.
        let seed_lit = Tensor::seed(self.key).to_literal()?;
        let tau_lit = Tensor::F32(taus, vec![b_bucket]).to_literal()?;

        // 3. K_max+1 coupled target passes.  Rows with a shorter draft
        //    replay their last (token, position) — a deterministic rewrite
        //    of identical KV, i.e. a no-op — and their surplus samples are
        //    discarded below.
        let mut samples_per_row: Vec<Vec<i32>> = vec![Vec::new(); rows.len()];
        // The burst's first Philox counter-step — the trace's `cstep`
        // anchor for this spec round (passes consume cstep0..=cstep0+k_max).
        let cstep0 = self.step_counter;
        for j in 0..=k_max {
            let mut pos = vec![0i32; b_bucket];
            let mut tok = vec![0i32; b_bucket];
            for slot in 0..rows.len() {
                let jj = j.min(drafts[slot].len());
                pos[slot] = (base_pos[slot] + jj) as i32;
                tok[slot] =
                    if jj == 0 { base_tok[slot] } else { drafts[slot][jj - 1] };
            }
            let pos_lit = Tensor::I32(pos, vec![b_bucket]).to_literal()?;
            let tok_lit = Tensor::I32(tok, vec![b_bucket]).to_literal()?;
            let step_lit = Tensor::scalar_u32(self.bump_step()).to_literal()?;
            let mut lits: Vec<&xla::Literal> = self.params_lit.iter().collect();
            lits.extend([&kvk_lit, &kvv_lit, &pos_lit, &tok_lit, &seed_lit,
                         &step_lit, &tau_lit]);
            let mut out = exe.run_literals_raw(&lits)?;
            if out.len() != 3 {
                return Err(EngineError::artifact(
                    &exe_name,
                    anyhow::anyhow!("decode artifact returned {} outputs", out.len()),
                ));
            }
            let sample_lit = out.pop().unwrap();
            kvv_lit = out.pop().unwrap();
            kvk_lit = out.pop().unwrap();
            let samples = Tensor::from_literal(&sample_lit)?.as_i32()?.to_vec();
            for (slot, row_samples) in samples_per_row.iter_mut().enumerate() {
                if j <= drafts[slot].len() {
                    row_samples.push(samples[slot]);
                }
            }
        }

        // 4. Fold the final KV literals back into per-sequence storage
        //    (positions past each row's verified length are dead data).
        self.decode_cache = Some(DecodeCache {
            seq_ids: seq_ids.to_vec(),
            b_bucket,
            kv_k: kvk_lit,
            kv_v: kvv_lit,
        });
        self.sync_cache_to_seqs()?;

        // 5. Coupled verification, token bookkeeping, KV rollback.
        let now = Instant::now();
        let clock = self.clock;
        let mut retired: Vec<(usize, Option<FinishReason>)> = Vec::new();
        // As in `do_decode`: swap decisions need `&mut self`, so collect
        // pool-exhausted rows and decide after the borrow ends.
        let mut swap_candidates: Vec<(usize, u64, usize)> = Vec::new();
        for (slot, &ri) in rows.iter().enumerate() {
            let draft = &drafts[slot];
            let emit = coupled_emit_len(draft, &samples_per_row[slot]);
            self.metrics.bump("spec_draft_tokens", draft.len() as u64);
            self.metrics.bump("spec_accepted_tokens", (emit - 1) as u64);
            let ctx_before = base_pos[slot] + 1; // prompt + generated so far
            let s = &mut self.running[ri];
            let prev = s.last_token_at;
            let mut emitted = 0usize;
            let mut fin: Option<FinishReason> = None;
            for &t in &samples_per_row[slot][..emit] {
                s.generated.push(t);
                emitted += 1;
                emit_token(&self.streams, s, t, clock);
                self.metrics.tokens_generated += 1;
                if let Some(reason) = s.finished() {
                    fin = Some(reason);
                    break;
                }
            }
            if let Some(prev) = prev {
                // The burst lands at one wall instant: spread the
                // inter-step latency evenly so TPOT means stay honest.
                let per = (now - prev) / emitted.max(1) as u32;
                for _ in 0..emitted {
                    s.timing.token_latencies.push(per);
                }
            }
            s.last_token_at = Some(now);
            let id = s.id;
            // Reconcile the optimistic reservation with the verified
            // length: truncate rejected positions, or account the bonus
            // token of a fully accepted draft.
            let final_len = ctx_before + emitted;
            let reserved_len = ctx_before + reserved[slot];
            if final_len < reserved_len {
                self.metrics.bump(
                    "spec_rollback_tokens",
                    (reserved_len - final_len) as u64,
                );
                self.kvmgr.truncate(id, final_len)?;
            } else if final_len > reserved_len
                && fin.is_none()
                && !self.kvmgr.append_token(id)?
            {
                swap_candidates.push((ri, id, final_len));
            }
            self.metrics.spec_tokens_per_step.push(emitted);
            if self.trace.on() {
                self.trace.emit(
                    clock,
                    id,
                    EventKind::SpecBurst {
                        row: slot,
                        cstep: cstep0,
                        drafted: draft.len() as u64,
                        accepted: (emit - 1) as u64,
                        emitted: emitted as u64,
                    },
                );
            }
            if let Some(reason) = fin {
                retired.push((ri, Some(reason)));
            }
        }
        for (ri, id, ctx) in swap_candidates {
            match self.swap_preempt(id, ctx)? {
                Some(n) => {
                    self.metrics.swap_out_blocks += n as u64;
                    if self.trace.on() {
                        self.trace.emit(clock, id, EventKind::Preempt { kind: "swap" });
                        self.trace.emit(clock, id, EventKind::SwapOut { blocks: n as u64 });
                    }
                    retired.push((ri, None));
                }
                None => {
                    self.metrics.bump("preempted", 1);
                    if self.trace.on() {
                        self.trace.emit(clock, id, EventKind::Preempt { kind: "recompute" });
                    }
                    retired.push((ri, Some(FinishReason::MaxTokens)));
                }
            }
        }
        // Per-sequence KV is already current here: step 4 folded the
        // final inner-pass literals back, so swap victims depart whole.
        self.metrics.bump("spec_rounds", 1);
        self.metrics.bump("spec_inner_passes", (k_max + 1) as u64);
        self.metrics.decode_batch_sizes.push(rows.len());

        self.retire_rows(retired)
    }

    fn bump_step(&mut self) -> u32 {
        let s = self.step_counter;
        self.step_counter += 1;
        s
    }
}
