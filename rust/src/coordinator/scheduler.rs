//! Prefill/decode scheduler: each engine iteration plans ONE batch —
//! continuous batching over fixed-shape executables.
//!
//! Policy (vLLM-v1-like, prefill-prioritized):
//!   1. If waiting sequences exist and KV blocks are available, plan a
//!      prefill batch: up to `prefill_b` prompts that fit the smallest
//!      viable T bucket.
//!   2. Otherwise plan a decode batch: up to the largest decode bucket of
//!      running sequences, FCFS.
//!
//! Sampling parameters never fragment batches: the artifact ABI carries
//! per-row temperature (`tau: [B]`, DESIGN.md §4), so mixed-temperature
//! requests coalesce into full buckets — decode occupancy no longer drops
//! when clients disagree about tau.
//!
//! Fixed-shape executables mean the batch is padded up to a bucket —
//! exactly how GPU serving stacks pad to CUDA-graph capture sizes; padding
//! waste is surfaced in metrics as `pad_slots`.

use super::request::{SeqState, Sequence};

/// What the engine should execute next.
#[derive(Clone, Debug, PartialEq)]
pub enum Plan {
    /// Prefill these waiting sequences (indices into the waiting queue)
    /// using the `t_bucket` prefill artifact.  `t_bucket` is the
    /// planner's estimate from the cache probe; the engine recomputes the
    /// final bucket from its authoritative prefix-attach results (which
    /// may have shifted by then), so treat this value as advisory.
    Prefill { seq_ids: Vec<u64>, t_bucket: usize },
    /// Decode these running sequences using the `b_bucket` artifact.
    Decode { seq_ids: Vec<u64>, b_bucket: usize },
    /// Nothing to do.
    Idle,
}

/// Scheduler configuration derived from the artifact manifest.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Available decode batch buckets, ascending (e.g. [1, 2, 4, 8]).
    pub decode_buckets: Vec<usize>,
    /// Available prefill T buckets, ascending (e.g. [16, 64]).
    pub prefill_t_buckets: Vec<usize>,
    /// Prefill batch size (fixed per artifact).
    pub prefill_b: usize,
    /// Upper bound on concurrently running sequences.
    pub max_concurrency: usize,
    /// Upper bound on tokens one sequence can emit in a single engine
    /// step: 1 for ordinary decode, K+1 under speculative decode
    /// (`specdec:k=K`).  Admission control reserves this much extra KV
    /// headroom per admitted sequence so a freshly prefilled sequence can
    /// always absorb a full speculative burst without immediate
    /// preemption.
    pub max_tokens_per_step: usize,
}

/// Pick the smallest bucket >= n (or the largest available if n exceeds all).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .unwrap_or_else(|| *buckets.last().expect("no buckets"))
}

/// Plan the next engine iteration.
///
/// `can_admit(seq, burst)` reports whether the KV manager can hold the
/// sequence's prompt plus `burst` extra decode-step tokens (admission
/// control; the engine backs it with the cache-aware
/// [`crate::kvcache::KvCacheManager::prefill_blocks_needed`] /
/// [`crate::kvcache::KvCacheManager::prefill_headroom`] pair, which
/// charges only *uncached* prefill blocks against the budget).  The
/// probe is `FnMut` and is called once per chosen candidate in batch
/// order, so the engine's closure can reserve blocks for earlier
/// candidates of the same batch — without that running tally a batch of
/// individually-admissible prompts could oversubscribe the pool.
/// `cached_tokens(seq)` reports the prompt prefix the KV prefix cache
/// would serve (0 with caching off) — prefill only computes the suffix,
/// so the T bucket is picked by the longest *suffix*, not the longest
/// prompt, letting hit-heavy batches drop into smaller prefill
/// executables (the TTFT win, DESIGN.md §10).
pub fn plan(
    cfg: &SchedulerConfig,
    waiting: &[Sequence],
    running: &[Sequence],
    mut can_admit: impl FnMut(&Sequence, usize) -> bool,
    cached_tokens: impl Fn(&Sequence) -> usize,
) -> Plan {
    // --- Prefill-priority: batch waiting prompts while capacity allows.
    if running.len() < cfg.max_concurrency {
        let headroom = cfg.max_concurrency - running.len();
        let max_t = *cfg.prefill_t_buckets.last().unwrap();
        // FCFS scan: take prompts that fit the cache (temperature is
        // per-row in the artifact ABI, so no grouping constraint).  The
        // admission probe asks for the prompt PLUS one full step's token
        // burst (max_tokens_per_step − 1 beyond the ordinary single
        // token), so spec-decode bursts can't strand a just-admitted
        // sequence.
        let burst = cfg.max_tokens_per_step.max(1) - 1;
        let mut chosen: Vec<&Sequence> = Vec::new();
        for s in waiting.iter().filter(|s| s.state == SeqState::Waiting) {
            if s.prompt.len() > max_t || !can_admit(s, burst) {
                continue;
            }
            chosen.push(s);
            if chosen.len() == cfg.prefill_b.min(headroom) {
                break;
            }
        }
        if !chosen.is_empty() {
            // Bucket by the longest uncached suffix (== longest prompt
            // when caching is off; the cap keeps a non-empty suffix even
            // if the probe claims the whole prompt).
            let longest = chosen
                .iter()
                .map(|&s| {
                    s.prompt.len() - cached_tokens(s).min(s.prompt.len().saturating_sub(1))
                })
                .max()
                .unwrap();
            return Plan::Prefill {
                seq_ids: chosen.iter().map(|s| s.id).collect(),
                t_bucket: pick_bucket(&cfg.prefill_t_buckets, longest),
            };
        }
    }

    // --- Decode: FCFS over running sequences, whatever their params.
    let decodable: Vec<&Sequence> = running
        .iter()
        .filter(|s| s.state == SeqState::Running)
        .collect();
    if decodable.is_empty() {
        return Plan::Idle;
    }
    let max_b = *cfg.decode_buckets.last().unwrap();
    let group: Vec<u64> = decodable.iter().take(max_b).map(|s| s.id).collect();
    let bucket = pick_bucket(&cfg.decode_buckets, group.len());
    Plan::Decode { seq_ids: group, b_bucket: bucket }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, SamplingParams};

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            prefill_t_buckets: vec![16, 64],
            prefill_b: 4,
            max_concurrency: 8,
            max_tokens_per_step: 1,
        }
    }

    fn seq(id: u64, prompt_len: usize, tau: f32, state: SeqState) -> Sequence {
        let mut s = Sequence::new(Request {
            id,
            prompt: vec![1; prompt_len],
            params: SamplingParams { temperature: tau, ..Default::default() },
        });
        s.state = state;
        s
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 1), 1);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 3), 4);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 8), 8);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 20), 8); // clamp to largest
    }

    /// The cache-blind closure pair most tests use.
    fn always(_: &Sequence, _: usize) -> bool {
        true
    }
    fn uncached(_: &Sequence) -> usize {
        0
    }

    #[test]
    fn prefill_takes_priority() {
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let p = plan(&cfg(), &waiting, &running, always, uncached);
        assert_eq!(
            p,
            Plan::Prefill { seq_ids: vec![1], t_bucket: 16 }
        );
    }

    #[test]
    fn prefill_t_bucket_fits_longest() {
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                assert_eq!(t_bucket, 64);
            }
            p => panic!("expected prefill, got {p:?}"),
        }
    }

    #[test]
    fn cached_prefixes_shrink_the_t_bucket() {
        // A 40-token prompt with 32 tokens cached prefills only its
        // 8-token suffix: the batch drops from the t=64 bucket to t=16.
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        let cached = |s: &Sequence| if s.id == 2 { 32 } else { 0 };
        match plan(&cfg(), &waiting, &[], always, cached) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("expected prefill, got {p:?}"),
        }
        // An overclaiming probe (cached >= prompt) is capped: at least one
        // suffix token always remains to prefill.
        let overclaim = |_: &Sequence| 1000usize;
        match plan(&cfg(), &waiting, &[], always, overclaim) {
            Plan::Prefill { t_bucket, .. } => assert_eq!(t_bucket, 16),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn oversized_prompt_skipped() {
        let waiting = vec![
            seq(1, 100, 1.0, SeqState::Waiting), // > max T bucket
            seq(2, 10, 1.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn admission_control_blocks_prefill() {
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let p = plan(&cfg(), &waiting, &running, |_, _| false, uncached);
        assert_eq!(
            p,
            Plan::Decode { seq_ids: vec![2], b_bucket: 1 }
        );
    }

    #[test]
    fn cache_aware_admission_sees_the_sequence() {
        // The admission probe receives the SEQUENCE (so the engine can
        // charge only uncached blocks), not a bare token count: a probe
        // that admits exactly the cached-prefix prompt proves the plumbing.
        let waiting = vec![
            seq(1, 40, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        let admit_cached_only = |s: &Sequence, _burst: usize| s.id == 2;
        match plan(&cfg(), &waiting, &[], admit_cached_only, uncached) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperatures_share_one_decode_bucket() {
        // Pre-redesign this planned [1, 3] (tau grouping) and left row 2 for
        // a second, padded batch; with the tau: [B] ABI everything coalesces.
        let running = vec![
            seq(1, 5, 1.0, SeqState::Running),
            seq(2, 5, 0.7, SeqState::Running),
            seq(3, 5, 1.0, SeqState::Running),
        ];
        match plan(&cfg(), &[], &running, always, uncached) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids, vec![1, 2, 3]); // FCFS, tau-blind
                assert_eq!(b_bucket, 4);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperature_occupancy_is_full() {
        // 8 running sequences at 4 distinct temperatures fill the largest
        // bucket with zero pad rows — the occupancy win the redesign buys.
        // (Temperature grouping would have planned a fragmented 2-row batch
        // with 6 of 8 slots padded: 4 batches to cover one decode round.)
        let running: Vec<Sequence> = (0..8)
            .map(|i| seq(i, 5, 0.25 * (1 + i % 4) as f32, SeqState::Running))
            .collect();
        match plan(&cfg(), &[], &running, always, uncached) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids.len(), 8);
                assert_eq!(b_bucket, 8);
                assert_eq!(b_bucket - seq_ids.len(), 0); // decode_pad_rows = 0
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperature_prefill_batches_together() {
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 10, 0.5, SeqState::Waiting),
            seq(3, 10, 2.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2, 3]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn decode_respects_largest_bucket() {
        let running: Vec<Sequence> =
            (0..12).map(|i| seq(i, 5, 1.0, SeqState::Running)).collect();
        match plan(&cfg(), &[], &running, always, uncached) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids.len(), 8);
                assert_eq!(b_bucket, 8);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn max_concurrency_caps_prefill() {
        let waiting = vec![seq(10, 4, 1.0, SeqState::Waiting)];
        let running: Vec<Sequence> =
            (0..8).map(|i| seq(i, 5, 1.0, SeqState::Running)).collect();
        // at capacity: no prefill even though prompts wait
        match plan(&cfg(), &waiting, &running, always, uncached) {
            Plan::Decode { .. } => {}
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn idle_when_empty() {
        assert_eq!(plan(&cfg(), &[], &[], always, uncached), Plan::Idle);
    }

    #[test]
    fn spec_decode_headroom_inflates_the_admission_probe() {
        // Under specdec:k=4 a sequence may emit 5 tokens per step; the
        // admission check must ask the KV manager for prompt + 4 extra
        // slots (ordinary decode: exactly the prompt).
        let mut c = cfg();
        c.max_tokens_per_step = 5;
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let asked = std::cell::Cell::new(0usize);
        let probe = |s: &Sequence, burst: usize| {
            asked.set(s.context_len() + burst);
            true
        };
        let p = plan(&c, &waiting, &[], probe, uncached);
        assert!(matches!(p, Plan::Prefill { .. }));
        assert_eq!(asked.get(), 10 + 4);
        // Ordinary decode keeps the original probe.
        let p = plan(&cfg(), &waiting, &[], probe, uncached);
        assert!(matches!(p, Plan::Prefill { .. }));
        assert_eq!(asked.get(), 10);
    }

    #[test]
    fn burst_headroom_can_defer_admission_to_decode() {
        // 12 free token slots: a 10-token prompt is admissible for plain
        // decode but NOT with a 5-token burst reservation.
        let mut c = cfg();
        c.max_tokens_per_step = 5;
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let fits = |s: &Sequence, burst: usize| s.context_len() + burst <= 12;
        let p = plan(&c, &waiting, &running, fits, uncached);
        assert_eq!(p, Plan::Decode { seq_ids: vec![2], b_bucket: 1 });
        let p = plan(&cfg(), &waiting, &running, fits, uncached);
        assert!(matches!(p, Plan::Prefill { .. }));
    }
}
