//! Prefill/decode scheduler: each engine iteration plans ONE batch —
//! continuous batching over fixed-shape executables.
//!
//! Policy (vLLM-v1-like, prefill-prioritized):
//!   1. With chunked prefill on (`prefill_chunk_tokens > 0`) and the
//!      queue head holding more than one window of uncomputed suffix,
//!      plan ONE chunk window for it ([`Plan::ChunkPrefill`]).
//!   2. If waiting sequences exist and KV blocks are available, plan a
//!      prefill batch: up to `prefill_b` prompts that fit the smallest
//!      viable T bucket (a partial head's final chunk batches here).
//!   3. Otherwise plan a decode batch: up to the largest decode bucket of
//!      running sequences, FCFS.
//!
//! Sampling parameters never fragment batches: the artifact ABI carries
//! per-row temperature (`tau: [B]`, DESIGN.md §4), so mixed-temperature
//! requests coalesce into full buckets — decode occupancy no longer drops
//! when clients disagree about tau.
//!
//! Fixed-shape executables mean the batch is padded up to a bucket —
//! exactly how GPU serving stacks pad to CUDA-graph capture sizes; padding
//! waste is surfaced in metrics as `pad_slots`.
//!
//! Priority (DESIGN.md §11): when a candidate set spans multiple
//! priority classes, it is ordered by *effective rank* — the request's
//! [`Priority`](super::request::Priority) rank plus an anti-starvation
//! aging bonus of one rank per `aging_steps` logical engine steps waited
//! — with FCFS (queue-order) tiebreak via a stable sort.  A
//! uniform-priority candidate set is never reordered at all (see
//! `sort_by_effective_rank`), so priority-free workloads reproduce the
//! legacy FCFS plan exactly, preserving byte-identical token streams.

use std::cmp::Reverse;

use super::request::{SeqState, Sequence};

/// What the engine should execute next.
#[derive(Clone, Debug, PartialEq)]
pub enum Plan {
    /// Prefill these waiting sequences (indices into the waiting queue)
    /// using the `t_bucket` prefill artifact.  `t_bucket` is the
    /// planner's estimate from the cache probe; the engine recomputes the
    /// final bucket from its authoritative prefix-attach results (which
    /// may have shifted by then), so treat this value as advisory.
    Prefill { seq_ids: Vec<u64>, t_bucket: usize },
    /// Run ONE intermediate prefill chunk (`prefill_chunk_tokens` prompt
    /// tokens, never the last one) for the queue-head sequence — chunked
    /// prefill, DESIGN.md §12.  Intermediate chunks build KV only and
    /// consume no Philox steps; the *final* chunk of a partial head is
    /// deliberately NOT planned here — it falls through to the normal
    /// [`Plan::Prefill`] scan so it batches companions and samples
    /// exactly as the unchunked baseline would.
    ChunkPrefill { seq_id: u64 },
    /// Decode these running sequences using the `b_bucket` artifact.
    Decode { seq_ids: Vec<u64>, b_bucket: usize },
    /// Nothing to do.
    Idle,
}

/// Scheduler configuration derived from the artifact manifest.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Available decode batch buckets, ascending (e.g. [1, 2, 4, 8]).
    pub decode_buckets: Vec<usize>,
    /// Available prefill T buckets, ascending (e.g. [16, 64]).
    pub prefill_t_buckets: Vec<usize>,
    /// Prefill batch size (fixed per artifact).
    pub prefill_b: usize,
    /// Upper bound on concurrently running sequences.
    pub max_concurrency: usize,
    /// Upper bound on tokens one sequence can emit in a single engine
    /// step: 1 for ordinary decode, K+1 under speculative decode
    /// (`specdec:k=K`).  Admission control reserves this much extra KV
    /// headroom per admitted sequence so a freshly prefilled sequence can
    /// always absorb a full speculative burst without immediate
    /// preemption.
    pub max_tokens_per_step: usize,
    /// Anti-starvation aging: a waiting/running sequence gains one
    /// priority-class worth of effective rank per `aging_steps` logical
    /// engine steps since submission (0 disables aging).  Neutral under
    /// uniform priorities — see the module docs.
    pub aging_steps: u64,
    /// Chunked prefill (DESIGN.md §12): split a long prompt's prefill
    /// into windows of at most this many tokens so one adversarial
    /// prompt cannot monopolize a step.  0 disables chunking — the plan
    /// stream is then byte-identical to the pre-chunking scheduler.
    /// Values above the largest prefill T bucket are clamped to it
    /// (chunk windows run through the fixed-shape prefill executables).
    pub prefill_chunk_tokens: usize,
    /// Interleave chunk windows with other work on alternating steps
    /// (even logical steps chunk, odd steps run the normal scan/decode) —
    /// the TTFT-under-load lever.  Off (the default, "sticky" mode),
    /// chunk windows run back-to-back, which keeps completed requests'
    /// Philox coordinates bit-identical to the unchunked baseline;
    /// interleaving trades that replay identity (the distribution is
    /// unchanged — every draw still uses fresh counters) for bounded
    /// short-request TTFT.
    pub chunk_interleave: bool,
}

/// Effective scheduling rank: base priority plus the aging bonus.
fn effective_rank(s: &Sequence, now_step: u64, aging_steps: u64) -> i64 {
    let mut rank = s.priority.rank();
    if aging_steps > 0 {
        rank += (now_step.saturating_sub(s.submitted_step) / aging_steps) as i64;
    }
    rank
}

/// Order candidates by effective rank — but ONLY when the set actually
/// spans multiple priority classes.  A uniform-priority candidate set
/// keeps its exact queue order untouched: this is what makes the
/// redesign bit-for-bit identical to the legacy FCFS scheduler for
/// priority-free workloads even in corners where the queue order drifts
/// from submission order (e.g. the engine's prefill requeue backstop
/// push-fronts a raced candidate), where an unconditional aging sort
/// could otherwise reorder equal-priority requests by age and move
/// Philox (row, step) coordinates.  Aging is anti-starvation machinery
/// *for priority scheduling*; without priorities in play there is
/// nothing to starve.
fn sort_by_effective_rank(
    candidates: &mut [&Sequence],
    cfg: &SchedulerConfig,
    now_step: u64,
) {
    let mixed = candidates
        .first()
        .is_some_and(|f| candidates.iter().any(|s| s.priority != f.priority));
    if mixed {
        candidates
            .sort_by_key(|s| Reverse(effective_rank(s, now_step, cfg.aging_steps)));
    }
}

/// Pick the smallest bucket >= n (or the largest available if n exceeds all).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .unwrap_or_else(|| *buckets.last().expect("no buckets"))
}

/// Plan the next engine iteration.
///
/// `can_admit(seq, burst)` reports whether the KV manager can hold the
/// sequence's prompt plus `burst` extra decode-step tokens (admission
/// control; the engine backs it with the cache-aware
/// [`crate::kvcache::KvCacheManager::prefill_blocks_needed`] /
/// [`crate::kvcache::KvCacheManager::prefill_headroom`] pair, which
/// charges only *uncached* prefill blocks against the budget).  The
/// probe is `FnMut` and is called once per chosen candidate in batch
/// order, so the engine's closure can reserve blocks for earlier
/// candidates of the same batch — without that running tally a batch of
/// individually-admissible prompts could oversubscribe the pool.
/// `cached_tokens(seq)` reports the prompt prefix the KV prefix cache
/// would serve (0 with caching off) — prefill only computes the suffix,
/// so the T bucket is picked by the longest *suffix*, not the longest
/// prompt, letting hit-heavy batches drop into smaller prefill
/// executables (the TTFT win, DESIGN.md §10).
/// `now_step` is the engine's logical step clock, the aging rule's "now".
pub fn plan(
    cfg: &SchedulerConfig,
    waiting: &[Sequence],
    running: &[Sequence],
    mut can_admit: impl FnMut(&Sequence, usize) -> bool,
    cached_tokens: impl Fn(&Sequence) -> usize,
    now_step: u64,
) -> Plan {
    // An interleave-parity-skipped chunk window, kept as the fallback of
    // last resort: yielding the odd step to other work must never turn
    // into Idle starvation (run_to_completion's no-progress backstop
    // would reject a still-fresh head).
    let mut deferred_window: Option<&Sequence> = None;
    let burst = cfg.max_tokens_per_step.max(1) - 1;
    // --- Prefill-priority: batch waiting prompts while capacity allows.
    if running.len() < cfg.max_concurrency {
        let headroom = cfg.max_concurrency - running.len();
        let max_t = *cfg.prefill_t_buckets.last().unwrap();
        // Priority-then-FCFS scan: take prompts that fit the cache
        // (temperature is per-row in the artifact ABI, so no grouping
        // constraint).  The stable sort keeps submission order within
        // equal effective rank.  The admission probe asks for the prompt
        // PLUS one full step's token burst (max_tokens_per_step − 1
        // beyond the ordinary single token), so spec-decode bursts can't
        // strand a just-admitted sequence.
        let mut queue: Vec<&Sequence> =
            waiting.iter().filter(|s| s.state == SeqState::Waiting).collect();
        sort_by_effective_rank(&mut queue, cfg, now_step);
        // Chunk windows run through the fixed-shape prefill executables,
        // so the window size is capped by the largest T bucket.
        let chunk = cfg.prefill_chunk_tokens.min(max_t);
        // --- Chunked prefill window (DESIGN.md §12): when the queue head
        // still has more than one chunk of uncomputed suffix, open ONE
        // window for it instead of a batch.  In interleave mode windows
        // only run on even logical steps, leaving odd steps to the
        // normal scan (other shorts prefill) and decode.
        if chunk > 0 {
            if let Some(&head) = queue.first() {
                let remaining = if head.prefilled_tokens > 0 {
                    // Partial head: its own restored KV covers what prior
                    // windows built; blocks are already held, so no
                    // admission probe.
                    head.prompt.len() - head.prefilled_tokens
                } else {
                    head.prompt.len()
                        - cached_tokens(head)
                            .min(head.prompt.len().saturating_sub(1))
                };
                if remaining > chunk {
                    if cfg.chunk_interleave && now_step % 2 == 1 {
                        // Yield this step to the scan/decode below; the
                        // admission probe is deferred with it so the
                        // scan's budget tally is untouched.
                        deferred_window = Some(head);
                    } else if head.prefilled_tokens > 0
                        || can_admit(head, burst)
                    {
                        return Plan::ChunkPrefill { seq_id: head.id };
                    }
                }
            }
        }
        let mut chosen: Vec<&Sequence> = Vec::new();
        for s in queue {
            // A deferred head yielded its window to this scan — it must
            // not sneak into the batch WHOLE instead (that would turn
            // interleave mode into whole prefill for any head that fits
            // the largest bucket, un-yielding the very step being ceded).
            if deferred_window.is_some_and(|d| d.id == s.id) {
                continue;
            }
            if s.prefilled_tokens > 0 {
                // A partial head's FINAL chunk (suffix now <= one window)
                // batches here like any prefill; with a longer suffix it
                // waits for its next window (interleave mode reaches this
                // scan on odd steps with the window still open).
                if s.prompt.len() - s.prefilled_tokens > chunk {
                    continue;
                }
            } else if s.prompt.len() > max_t || !can_admit(s, burst) {
                continue;
            }
            chosen.push(s);
            if chosen.len() == cfg.prefill_b.min(headroom) {
                break;
            }
        }
        if !chosen.is_empty() {
            // Bucket by the longest uncached suffix (== longest prompt
            // when caching is off; the cap keeps a non-empty suffix even
            // if the probe claims the whole prompt).  Partial heads
            // charge only their unprefilled suffix.
            let longest = chosen
                .iter()
                .map(|&s| {
                    if s.prefilled_tokens > 0 {
                        s.prompt.len() - s.prefilled_tokens
                    } else {
                        s.prompt.len()
                            - cached_tokens(s)
                                .min(s.prompt.len().saturating_sub(1))
                    }
                })
                .max()
                .unwrap();
            return Plan::Prefill {
                seq_ids: chosen.iter().map(|s| s.id).collect(),
                t_bucket: pick_bucket(&cfg.prefill_t_buckets, longest),
            };
        }
    }

    // --- Decode: priority-then-FCFS over running sequences, whatever
    // their params (mixed-priority-gated stable sort again — uniform
    // priorities decode in the exact legacy running order, same batch
    // slots, same Philox rows).
    let mut decodable: Vec<&Sequence> = running
        .iter()
        .filter(|s| s.state == SeqState::Running)
        .collect();
    if decodable.is_empty() {
        // Nothing else ran this step: an interleave-deferred window takes
        // the step after all rather than idling (and rather than exposing
        // a fresh head to the no-progress reject backstop).
        if let Some(head) = deferred_window {
            if head.prefilled_tokens > 0 || can_admit(head, burst) {
                return Plan::ChunkPrefill { seq_id: head.id };
            }
        }
        return Plan::Idle;
    }
    sort_by_effective_rank(&mut decodable, cfg, now_step);
    let max_b = *cfg.decode_buckets.last().unwrap();
    let group: Vec<u64> = decodable.iter().take(max_b).map(|s| s.id).collect();
    let bucket = pick_bucket(&cfg.decode_buckets, group.len());
    Plan::Decode { seq_ids: group, b_bucket: bucket }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, SamplingParams};

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            prefill_t_buckets: vec![16, 64],
            prefill_b: 4,
            max_concurrency: 8,
            max_tokens_per_step: 1,
            aging_steps: 0,
            prefill_chunk_tokens: 0,
            chunk_interleave: false,
        }
    }

    fn seq(id: u64, prompt_len: usize, tau: f32, state: SeqState) -> Sequence {
        let mut s = Sequence::new(Request::new(
            id,
            vec![1; prompt_len],
            SamplingParams { temperature: tau, ..Default::default() },
        ));
        s.state = state;
        s
    }

    /// `seq` with an explicit priority and submission step.
    fn pseq(
        id: u64,
        prio: crate::coordinator::request::Priority,
        submitted_step: u64,
        state: SeqState,
    ) -> Sequence {
        let mut s = seq(id, 8, 1.0, state);
        s.priority = prio;
        s.submitted_step = submitted_step;
        s
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 1), 1);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 3), 4);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 8), 8);
        assert_eq!(pick_bucket(&[1, 2, 4, 8], 20), 8); // clamp to largest
    }

    /// The cache-blind closure pair most tests use.
    fn always(_: &Sequence, _: usize) -> bool {
        true
    }
    fn uncached(_: &Sequence) -> usize {
        0
    }

    #[test]
    fn prefill_takes_priority() {
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let p = plan(&cfg(), &waiting, &running, always, uncached, 0);
        assert_eq!(
            p,
            Plan::Prefill { seq_ids: vec![1], t_bucket: 16 }
        );
    }

    #[test]
    fn prefill_t_bucket_fits_longest() {
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                assert_eq!(t_bucket, 64);
            }
            p => panic!("expected prefill, got {p:?}"),
        }
    }

    #[test]
    fn cached_prefixes_shrink_the_t_bucket() {
        // A 40-token prompt with 32 tokens cached prefills only its
        // 8-token suffix: the batch drops from the t=64 bucket to t=16.
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        let cached = |s: &Sequence| if s.id == 2 { 32 } else { 0 };
        match plan(&cfg(), &waiting, &[], always, cached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("expected prefill, got {p:?}"),
        }
        // An overclaiming probe (cached >= prompt) is capped: at least one
        // suffix token always remains to prefill.
        let overclaim = |_: &Sequence| 1000usize;
        match plan(&cfg(), &waiting, &[], always, overclaim, 0) {
            Plan::Prefill { t_bucket, .. } => assert_eq!(t_bucket, 16),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn oversized_prompt_skipped() {
        let waiting = vec![
            seq(1, 100, 1.0, SeqState::Waiting), // > max T bucket
            seq(2, 10, 1.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached, 0) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn admission_control_blocks_prefill() {
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let p = plan(&cfg(), &waiting, &running, |_, _| false, uncached, 0);
        assert_eq!(
            p,
            Plan::Decode { seq_ids: vec![2], b_bucket: 1 }
        );
    }

    #[test]
    fn cache_aware_admission_sees_the_sequence() {
        // The admission probe receives the SEQUENCE (so the engine can
        // charge only uncached blocks), not a bare token count: a probe
        // that admits exactly the cached-prefix prompt proves the plumbing.
        let waiting = vec![
            seq(1, 40, 1.0, SeqState::Waiting),
            seq(2, 40, 1.0, SeqState::Waiting),
        ];
        let admit_cached_only = |s: &Sequence, _burst: usize| s.id == 2;
        match plan(&cfg(), &waiting, &[], admit_cached_only, uncached, 0) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperatures_share_one_decode_bucket() {
        // Pre-redesign this planned [1, 3] (tau grouping) and left row 2 for
        // a second, padded batch; with the tau: [B] ABI everything coalesces.
        let running = vec![
            seq(1, 5, 1.0, SeqState::Running),
            seq(2, 5, 0.7, SeqState::Running),
            seq(3, 5, 1.0, SeqState::Running),
        ];
        match plan(&cfg(), &[], &running, always, uncached, 0) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids, vec![1, 2, 3]); // FCFS, tau-blind
                assert_eq!(b_bucket, 4);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperature_occupancy_is_full() {
        // 8 running sequences at 4 distinct temperatures fill the largest
        // bucket with zero pad rows — the occupancy win the redesign buys.
        // (Temperature grouping would have planned a fragmented 2-row batch
        // with 6 of 8 slots padded: 4 batches to cover one decode round.)
        let running: Vec<Sequence> = (0..8)
            .map(|i| seq(i, 5, 0.25 * (1 + i % 4) as f32, SeqState::Running))
            .collect();
        match plan(&cfg(), &[], &running, always, uncached, 0) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids.len(), 8);
                assert_eq!(b_bucket, 8);
                assert_eq!(b_bucket - seq_ids.len(), 0); // decode_pad_rows = 0
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mixed_temperature_prefill_batches_together() {
        let waiting = vec![
            seq(1, 10, 1.0, SeqState::Waiting),
            seq(2, 10, 0.5, SeqState::Waiting),
            seq(3, 10, 2.0, SeqState::Waiting),
        ];
        match plan(&cfg(), &waiting, &[], always, uncached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2, 3]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn decode_respects_largest_bucket() {
        let running: Vec<Sequence> =
            (0..12).map(|i| seq(i, 5, 1.0, SeqState::Running)).collect();
        match plan(&cfg(), &[], &running, always, uncached, 0) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids.len(), 8);
                assert_eq!(b_bucket, 8);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn max_concurrency_caps_prefill() {
        let waiting = vec![seq(10, 4, 1.0, SeqState::Waiting)];
        let running: Vec<Sequence> =
            (0..8).map(|i| seq(i, 5, 1.0, SeqState::Running)).collect();
        // at capacity: no prefill even though prompts wait
        match plan(&cfg(), &waiting, &running, always, uncached, 0) {
            Plan::Decode { .. } => {}
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn idle_when_empty() {
        assert_eq!(plan(&cfg(), &[], &[], always, uncached, 0), Plan::Idle);
    }

    #[test]
    fn spec_decode_headroom_inflates_the_admission_probe() {
        // Under specdec:k=4 a sequence may emit 5 tokens per step; the
        // admission check must ask the KV manager for prompt + 4 extra
        // slots (ordinary decode: exactly the prompt).
        let mut c = cfg();
        c.max_tokens_per_step = 5;
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let asked = std::cell::Cell::new(0usize);
        let probe = |s: &Sequence, burst: usize| {
            asked.set(s.context_len() + burst);
            true
        };
        let p = plan(&c, &waiting, &[], probe, uncached, 0);
        assert!(matches!(p, Plan::Prefill { .. }));
        assert_eq!(asked.get(), 10 + 4);
        // Ordinary decode keeps the original probe.
        let p = plan(&cfg(), &waiting, &[], probe, uncached, 0);
        assert!(matches!(p, Plan::Prefill { .. }));
        assert_eq!(asked.get(), 10);
    }

    #[test]
    fn high_priority_jumps_the_prefill_queue() {
        use crate::coordinator::request::Priority;
        let mut c = cfg();
        c.aging_steps = 0;
        // Submission order: 1 (normal), 2 (high), 3 (low), 4 (normal).
        let waiting = vec![
            pseq(1, Priority::Normal, 0, SeqState::Waiting),
            pseq(2, Priority::High, 0, SeqState::Waiting),
            pseq(3, Priority::Low, 0, SeqState::Waiting),
            pseq(4, Priority::Normal, 0, SeqState::Waiting),
        ];
        match plan(&c, &waiting, &[], always, uncached, 0) {
            // High first, then normals FCFS, then low.
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2, 1, 4, 3]),
            p => panic!("{p:?}"),
        }
        // Uniform priorities: exact legacy FCFS, aging on or off.
        let uniform = vec![
            pseq(1, Priority::Normal, 0, SeqState::Waiting),
            pseq(2, Priority::Normal, 1, SeqState::Waiting),
            pseq(3, Priority::Normal, 2, SeqState::Waiting),
        ];
        for aging in [0u64, 16] {
            let mut c = cfg();
            c.aging_steps = aging;
            match plan(&c, &uniform, &[], always, uncached, 100) {
                Plan::Prefill { seq_ids, .. } => {
                    assert_eq!(seq_ids, vec![1, 2, 3], "aging={aging}")
                }
                p => panic!("{p:?}"),
            }
        }
    }

    #[test]
    fn uniform_priority_queue_order_survives_aging_even_when_scrambled() {
        // Regression: the engine's prefill requeue backstop can push a
        // later-submitted sequence to the FRONT of the waiting queue.
        // Under uniform priority the scheduler must keep that queue
        // order bit-for-bit (legacy FCFS semantics) — an unconditional
        // aging sort would move the older request ahead once its age
        // bonus ticks over, shifting Philox coordinates.
        let mut c = cfg();
        c.aging_steps = 8;
        // Queue order [B(submitted 50), A(submitted 0)]: A is much older.
        let waiting = vec![
            pseq(7, Priority::Normal, 50, SeqState::Waiting), // requeued B
            pseq(3, Priority::Normal, 0, SeqState::Waiting),  // older A
        ];
        match plan(&c, &waiting, &[], always, uncached, 100) {
            Plan::Prefill { seq_ids, .. } => {
                assert_eq!(seq_ids, vec![7, 3], "uniform priority reordered");
            }
            p => panic!("{p:?}"),
        }
        // Same queue with mixed priorities: ranking (with aging) engages.
        let mixed = vec![
            pseq(7, Priority::Normal, 50, SeqState::Waiting), // rank 1+6=7
            pseq(3, Priority::Low, 0, SeqState::Waiting),     // rank 0+12=12
        ];
        match plan(&c, &mixed, &[], always, uncached, 100) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![3, 7]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn aging_prevents_low_priority_starvation() {
        use crate::coordinator::request::Priority;
        let mut c = cfg();
        c.prefill_b = 1; // one admission per step: contention
        c.aging_steps = 8;
        // A low-priority request submitted at step 0; a high-priority
        // stream submitted at step 30.
        let waiting = vec![
            pseq(1, Priority::Low, 0, SeqState::Waiting),
            pseq(2, Priority::High, 30, SeqState::Waiting),
        ];
        // At step 30 the low-priority request has aged 30/8 = 3 classes
        // (effective rank 0 + 3 = 3) while the fresh high-priority one
        // sits at rank 2 — the starving request overtakes.
        match plan(&c, &waiting, &[], always, uncached, 30) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![1]),
            p => panic!("{p:?}"),
        }
        // Shortly after submission (step 8), low has aged only 1 class
        // (rank 1) and the high-priority request still wins.
        let fresh = vec![
            pseq(1, Priority::Low, 0, SeqState::Waiting),
            pseq(2, Priority::High, 6, SeqState::Waiting),
        ];
        match plan(&c, &fresh, &[], always, uncached, 8) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn decode_orders_by_priority_with_stable_fcfs_ties() {
        use crate::coordinator::request::Priority;
        let mut c = cfg();
        c.decode_buckets = vec![1, 2];
        // 3 running, bucket capacity 2: the low-priority one is left out,
        // and the two normals keep their running order (batch slots are
        // Philox rows — ties must stay stable).
        let running = vec![
            pseq(1, Priority::Normal, 0, SeqState::Running),
            pseq(2, Priority::Low, 0, SeqState::Running),
            pseq(3, Priority::Normal, 0, SeqState::Running),
        ];
        match plan(&c, &[], &running, always, uncached, 0) {
            Plan::Decode { seq_ids, b_bucket } => {
                assert_eq!(seq_ids, vec![1, 3]);
                assert_eq!(b_bucket, 2);
            }
            p => panic!("{p:?}"),
        }
    }

    /// `cfg()` with chunking enabled at the given window size.
    fn ccfg(chunk: usize) -> SchedulerConfig {
        SchedulerConfig { prefill_chunk_tokens: chunk, ..cfg() }
    }

    #[test]
    fn chunk_window_opens_for_a_long_fresh_head() {
        // 40-token head with a 16-token window: more than one chunk of
        // suffix remains, so the plan is a single window, not a batch.
        let waiting = vec![
            seq(1, 40, 1.0, SeqState::Waiting),
            seq(2, 10, 1.0, SeqState::Waiting),
        ];
        let p = plan(&ccfg(16), &waiting, &[], always, uncached, 0);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        // chunk = 0 must replay the legacy batch plan byte-identically.
        let p = plan(&cfg(), &waiting, &[], always, uncached, 0);
        assert_eq!(p, Plan::Prefill { seq_ids: vec![1, 2], t_bucket: 64 });
        // A window larger than the whole prompt: no chunking needed.
        let p = plan(&ccfg(64), &waiting, &[], always, uncached, 0);
        assert_eq!(p, Plan::Prefill { seq_ids: vec![1, 2], t_bucket: 64 });
    }

    #[test]
    fn partial_head_final_chunk_batches_with_companions() {
        // Head has prefilled 32 of 40 tokens: 8 remaining <= 16-token
        // window, so it falls through to the normal scan and batches with
        // the short companion — exactly the baseline's batch shape.
        let mut head = seq(1, 40, 1.0, SeqState::Waiting);
        head.prefilled_tokens = 32;
        let waiting = vec![head, seq(2, 10, 1.0, SeqState::Waiting)];
        match plan(&ccfg(16), &waiting, &[], always, uncached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                // Bucket charges the head's 8-token suffix, not 40.
                assert_eq!(t_bucket, 16);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn partial_head_with_long_suffix_keeps_its_window_open() {
        let mut head = seq(1, 60, 1.0, SeqState::Waiting);
        head.prefilled_tokens = 16;
        let waiting = vec![head, seq(2, 10, 1.0, SeqState::Waiting)];
        // 44 tokens remain > 16: another window, and NO admission probe
        // (the partial head already holds its blocks).
        let p = plan(&ccfg(16), &waiting, &[], |_, _| false, uncached, 0);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
    }

    #[test]
    fn chunking_admits_prompts_beyond_the_largest_t_bucket() {
        // A 100-token prompt exceeds t=64 and is unservable unchunked
        // (oversized_prompt_skipped above) — but windows of 16 cover it.
        let waiting = vec![seq(1, 100, 1.0, SeqState::Waiting)];
        let p = plan(&ccfg(16), &waiting, &[], always, uncached, 0);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        // The window size itself is clamped to the largest bucket: the
        // executables are fixed-shape.
        let p = plan(&ccfg(1000), &waiting, &[], always, uncached, 0);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        // Once partially prefilled down to a final suffix <= window, it
        // batches even though prompt.len() > max_t.
        let mut head = seq(1, 100, 1.0, SeqState::Waiting);
        head.prefilled_tokens = 96;
        match plan(&ccfg(16), &[head], &[], always, uncached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn interleave_alternates_windows_with_other_work() {
        let mut head = seq(1, 60, 1.0, SeqState::Waiting);
        head.prefilled_tokens = 16; // 44 remaining: window stays open
        let waiting = vec![head, seq(2, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(3, 5, 1.0, SeqState::Running)];
        let mut c = ccfg(16);
        c.chunk_interleave = true;
        // Even step: the head's window runs.
        let p = plan(&c, &waiting, &running, always, uncached, 0);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        // Odd step: the partial head is skipped (suffix > window) and the
        // short companion prefills instead — that's the TTFT lever.
        match plan(&c, &waiting, &running, always, uncached, 1) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
        // Odd step with nothing else waiting: decode proceeds.
        let solo = vec![waiting[0].clone()];
        let p = plan(&c, &solo, &running, always, uncached, 1);
        assert_eq!(p, Plan::Decode { seq_ids: vec![3], b_bucket: 1 });
        // Sticky mode never yields the window: odd steps still chunk.
        let p = plan(&ccfg(16), &waiting, &running, always, uncached, 1);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        // Odd step, interleave, solo head, NOTHING else to run: the
        // deferred window fires instead of Idle — otherwise the engine's
        // no-progress backstop would reject a perfectly servable head.
        let p = plan(&c, &solo, &[], always, uncached, 1);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 1 });
        let fresh = vec![seq(4, 60, 1.0, SeqState::Waiting)];
        let p = plan(&c, &fresh, &[], always, uncached, 1);
        assert_eq!(p, Plan::ChunkPrefill { seq_id: 4 });
        // A FRESH long head on an odd step is deferred, not batched whole
        // (60 fits the 64 bucket, so without the exclusion the scan would
        // whole-prefill it and interleave mode would never open windows):
        // the short companion prefills alone.
        let fresh2 = vec![
            seq(4, 60, 1.0, SeqState::Waiting),
            seq(5, 10, 1.0, SeqState::Waiting),
        ];
        match plan(&c, &fresh2, &running, always, uncached, 1) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![5]),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn chunk_window_respects_admission_and_cached_prefix() {
        // Fresh head denied admission: no window, companion prefills.
        let waiting = vec![
            seq(1, 40, 1.0, SeqState::Waiting),
            seq(2, 10, 1.0, SeqState::Waiting),
        ];
        let admit = |s: &Sequence, _: usize| s.id == 2;
        match plan(&ccfg(16), &waiting, &[], admit, uncached, 0) {
            Plan::Prefill { seq_ids, .. } => assert_eq!(seq_ids, vec![2]),
            p => panic!("{p:?}"),
        }
        // A cached prefix shrinks the fresh head's effective suffix below
        // the window: no chunking, straight to a normal batch.
        let cached = |s: &Sequence| if s.id == 1 { 32 } else { 0 };
        match plan(&ccfg(16), &waiting, &[], always, cached, 0) {
            Plan::Prefill { seq_ids, t_bucket } => {
                assert_eq!(seq_ids, vec![1, 2]);
                assert_eq!(t_bucket, 16);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn burst_headroom_can_defer_admission_to_decode() {
        // 12 free token slots: a 10-token prompt is admissible for plain
        // decode but NOT with a 5-token burst reservation.
        let mut c = cfg();
        c.max_tokens_per_step = 5;
        let waiting = vec![seq(1, 10, 1.0, SeqState::Waiting)];
        let running = vec![seq(2, 5, 1.0, SeqState::Running)];
        let fits = |s: &Sequence, burst: usize| s.context_len() + burst <= 12;
        let p = plan(&c, &waiting, &running, fits, uncached, 0);
        assert_eq!(p, Plan::Decode { seq_ids: vec![2], b_bucket: 1 });
        let p = plan(&cfg(), &waiting, &running, fits, uncached, 0);
        assert!(matches!(p, Plan::Prefill { .. }));
    }
}
