//! Request/sequence types — the coordinator's state machine currency.

use std::time::Instant;

use crate::metrics::RequestTiming;

/// Per-request sampling configuration (vLLM `SamplingParams` analogue).
#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// Softmax temperature (tau > 0). Sequences batch together only with
    /// equal temperature because the fused artifact takes one tau per batch.
    pub temperature: f32,
    /// Maximum number of generated tokens.
    pub max_new_tokens: usize,
    /// Optional stop token.
    pub eos_token: Option<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, max_new_tokens: 32, eos_token: None }
    }
}

/// An incoming generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
}

/// Why a sequence stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    EosToken,
    /// Dropped because the prompt can never fit (prompt + budget > max_seq).
    Rejected,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    pub timing: RequestTiming,
}

/// Lifecycle state of a sequence inside the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    /// Admitted, prompt not yet prefetched into the KV cache.
    Waiting,
    /// KV cache holds the prompt; decoding.
    Running,
    /// Preempted under memory pressure; must re-prefill.
    Preempted,
}

/// Per-sequence KV storage: dense `[L, H, S, Dh]` f32 blocks for K and V.
///
/// (The paged `kvcache::KvCacheManager` tracks the *logical* block
/// accounting; this is the physical storage the dense AOT artifacts consume
/// — see DESIGN.md §2.)
#[derive(Clone, Debug)]
pub struct SeqKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// A live sequence.
#[derive(Clone, Debug)]
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub params: SamplingParams,
    pub state: SeqState,
    pub kv: Option<SeqKv>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Option<Instant>,
    pub timing: RequestTiming,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Self {
            id: req.id,
            prompt: req.prompt,
            generated: Vec::new(),
            params: req.params,
            state: SeqState::Waiting,
            kv: None,
            arrived: Instant::now(),
            first_token_at: None,
            last_token_at: None,
            timing: RequestTiming::default(),
        }
    }

    /// Total tokens resident in the KV cache (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Position at which the *next* token will be written.
    pub fn next_pos(&self) -> usize {
        self.context_len() - 1
    }

    /// The token to feed into the next decode step.
    pub fn input_token(&self) -> i32 {
        *self.generated.last().unwrap_or_else(|| {
            self.prompt.last().expect("prompt must be non-empty")
        })
    }

    /// Has the sequence hit a stop condition?
    pub fn finished(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) =
            (self.params.eos_token, self.generated.last())
        {
            if last == eos {
                return Some(FinishReason::EosToken);
            }
        }
        if self.generated.len() >= self.params.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    pub fn into_completion(self, finish: FinishReason) -> Completion {
        Completion {
            id: self.id,
            prompt_len: self.prompt.len(),
            tokens: self.generated,
            finish,
            timing: self.timing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id: 1,
            prompt,
            params: SamplingParams {
                max_new_tokens: max_new,
                ..Default::default()
            },
        }
    }

    #[test]
    fn positions_and_inputs() {
        let mut s = Sequence::new(req(vec![5, 6, 7], 4));
        assert_eq!(s.context_len(), 3);
        assert_eq!(s.next_pos(), 2);
        assert_eq!(s.input_token(), 7);
        s.generated.push(42);
        assert_eq!(s.context_len(), 4);
        assert_eq!(s.next_pos(), 3);
        assert_eq!(s.input_token(), 42);
    }

    #[test]
    fn finish_conditions() {
        let mut s = Sequence::new(req(vec![1], 2));
        assert_eq!(s.finished(), None);
        s.generated.push(9);
        assert_eq!(s.finished(), None);
        s.generated.push(9);
        assert_eq!(s.finished(), Some(FinishReason::MaxTokens));

        let mut s = Sequence::new(Request {
            id: 2,
            prompt: vec![1],
            params: SamplingParams {
                max_new_tokens: 100,
                eos_token: Some(0),
                ..Default::default()
            },
        });
        s.generated.push(3);
        assert_eq!(s.finished(), None);
        s.generated.push(0);
        assert_eq!(s.finished(), Some(FinishReason::EosToken));
    }
}
