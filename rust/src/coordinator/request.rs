//! Request/sequence types — the coordinator's state machine currency.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::RequestTiming;
use crate::sampling::{Key, Transform};

/// Scheduling priority of a request (DESIGN.md §11).
///
/// Higher priorities are planned first; ties break FCFS by queue order.
/// An anti-starvation aging rule (`SchedulerConfig::aging_steps`, config
/// key `priority_aging_steps`) promotes a waiting request one priority
/// class worth of rank for every `aging_steps` logical engine steps it
/// has waited, so a saturated high-priority stream can delay but never
/// permanently starve low-priority work.  Priority ordering engages only
/// when a candidate set actually mixes priority classes; a
/// uniform-priority workload is never reordered — exactly the legacy
/// FCFS, byte-identical token streams, same Philox coordinates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Numeric rank (higher = more urgent) — the base the aging rule
    /// adds to.
    pub fn rank(self) -> i64 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim() {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => bail!("unknown priority '{other}' (expected low|normal|high)"),
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        })
    }
}

/// Per-request sampling configuration (vLLM `SamplingParams` analogue).
///
/// Temperature is carried per row through the artifact ABI (`tau: [B]`,
/// DESIGN.md §4), so requests with different temperatures batch together
/// freely.  The remaining knobs are honored by the host-side sampling
/// paths (`ExactSampler::sample_batch_rows` with [`SamplingParams::transform`]
/// / `Transform::truncated`); the fused decode artifacts do not carry them
/// yet, and [`SamplingParams::artifact_unsupported`] names what a given
/// request would need so the engine can reject instead of silently
/// ignoring.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature (tau > 0).
    pub temperature: f32,
    /// Keep only the `k` highest-probability tokens (App. D.6).
    pub top_k: Option<usize>,
    /// Nucleus mass in (0, 1]; applied after `top_k` (vLLM order).
    pub top_p: Option<f32>,
    /// Additive per-token logit bias as `(token, bias)` pairs;
    /// `-inf` bias bans the token.
    ///
    /// Convention: the bias adds to the **temperature-scaled** logit
    /// (`logit / tau + bias`), matching the paper's Alg. 1 transform and
    /// the fused kernel's epilogue — NOT vLLM/OpenAI, which bias the raw
    /// logit before scaling.  To port a vLLM-style bias, divide it by the
    /// request temperature.
    pub logit_bias: Vec<(i32, f32)>,
    /// Tokens excluded from sampling entirely (bias `-inf` shorthand).
    pub banned_tokens: Vec<i32>,
    /// Per-request RNG seed overriding the engine session key.  Consumed
    /// via [`SamplingParams::row_key`] when building the per-row sampling
    /// context (host-side paths; the fused artifacts take one session
    /// seed, so the engine rejects it at submit).
    pub seed: Option<u64>,
    /// Maximum number of generated tokens.
    pub max_new_tokens: usize,
    /// Generation stops when any of these tokens is sampled
    /// (vLLM `stop_token_ids`).
    pub stop_tokens: Vec<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self {
            temperature: 1.0,
            top_k: None,
            top_p: None,
            logit_bias: Vec::new(),
            banned_tokens: Vec::new(),
            seed: None,
            max_new_tokens: 32,
            stop_tokens: Vec::new(),
        }
    }
}

impl SamplingParams {
    /// Shorthand for the common single-stop-token configuration.
    pub fn with_eos(eos: i32) -> Self {
        Self { stop_tokens: vec![eos], ..Default::default() }
    }

    /// Range-check every field against the model's vocabulary.
    pub fn validate(&self, vocab: usize) -> Result<()> {
        if !(self.temperature > 0.0 && self.temperature.is_finite()) {
            bail!("temperature must be finite and > 0, got {}", self.temperature);
        }
        if self.top_k == Some(0) {
            bail!("top_k must be >= 1");
        }
        if let Some(p) = self.top_p {
            if !(p > 0.0 && p <= 1.0) {
                bail!("top_p must be in (0, 1], got {p}");
            }
        }
        if self.max_new_tokens == 0 {
            bail!("max_new_tokens must be >= 1");
        }
        let in_vocab = |t: i32| t >= 0 && (t as usize) < vocab;
        for &(t, b) in &self.logit_bias {
            if !in_vocab(t) {
                bail!("logit_bias token {t} out of vocab range 0..{vocab}");
            }
            // -inf is the ban idiom; NaN and +inf poison the softmax and
            // the nucleus cumsum (NaN never compares >= p).
            if b.is_nan() || b == f32::INFINITY {
                bail!("logit_bias for token {t} must be finite or -inf, got {b}");
            }
        }
        if let Some(&t) = self.banned_tokens.iter().find(|&&t| !in_vocab(t)) {
            bail!("banned token {t} out of vocab range 0..{vocab}");
        }
        if let Some(&t) = self.stop_tokens.iter().find(|&&t| !in_vocab(t)) {
            bail!("stop token {t} out of vocab range 0..{vocab}");
        }
        Ok(())
    }

    /// The deterministic logit transform these params describe, before
    /// top-k/top-p truncation (that part needs the row's logits — see
    /// `Transform::truncated`).
    ///
    /// Out-of-vocab bias/ban entries are skipped rather than panicking —
    /// [`SamplingParams::validate`] is where they are reported as errors.
    pub fn transform(&self, vocab: usize) -> Transform {
        let mut bias: Option<Vec<f32>> = None;
        if !self.logit_bias.is_empty() || !self.banned_tokens.is_empty() {
            let mut b = vec![0.0f32; vocab];
            for &(t, v) in &self.logit_bias {
                if let Some(slot) = usize::try_from(t).ok().and_then(|i| b.get_mut(i)) {
                    *slot += v;
                }
            }
            for &t in &self.banned_tokens {
                if let Some(slot) = usize::try_from(t).ok().and_then(|i| b.get_mut(i)) {
                    *slot = f32::NEG_INFINITY;
                }
            }
            bias = Some(b);
        }
        Transform { temperature: self.temperature, bias }
    }

    /// The Philox key this request samples under: the per-request
    /// [`seed`](Self::seed) when set, else the session key.  Host-side
    /// batch paths put this in the row's `RowCtx`, decoupling the
    /// request's randomness from the session key.  Note the stream is
    /// still indexed by the `RowCtx` row (the batch slot) and step, so
    /// reproducing a seeded draw requires the same slot and step — the
    /// seed does not make draws placement-invariant.
    pub fn row_key(&self, session: Key) -> Key {
        self.seed.map(Key::from_seed).unwrap_or(session)
    }

    /// Fields the fused decode artifacts cannot honor (ABI v2 carries
    /// per-row `tau` only); empty means the request is fully servable by
    /// the artifact path.
    pub fn artifact_unsupported(&self) -> Vec<&'static str> {
        let mut missing = Vec::new();
        if self.top_k.is_some() {
            missing.push("top_k");
        }
        if self.top_p.is_some() {
            missing.push("top_p");
        }
        if !self.logit_bias.is_empty() {
            missing.push("logit_bias");
        }
        if !self.banned_tokens.is_empty() {
            missing.push("banned_tokens");
        }
        if self.seed.is_some() {
            missing.push("seed");
        }
        missing
    }
}

/// An incoming generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    /// Scheduling priority (see [`Priority`]; `Normal` preserves legacy
    /// FCFS exactly).
    pub priority: Priority,
}

impl Request {
    /// A `Normal`-priority request — the common construction.
    pub fn new(id: u64, prompt: Vec<i32>, params: SamplingParams) -> Self {
        Self { id, prompt, params, priority: Priority::default() }
    }
}

/// Why a sequence stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    /// One of the request's `stop_tokens` was sampled (the pre-redesign
    /// single `eos_token` generalized; vLLM `stop_token_ids` semantics).
    StopToken,
    /// Dropped because the prompt can never fit (prompt + budget > max_seq).
    Rejected,
    /// Cancelled mid-flight by [`Engine::abort`](super::Engine::abort):
    /// KV blocks and prefix-cache attachments released, partial tokens
    /// preserved on the completion.
    Aborted,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    pub timing: RequestTiming,
}

/// Lifecycle state of a sequence inside the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    /// Admitted, prompt not yet (fully) prefetched into the KV cache.  A
    /// chunked prefill in progress keeps the sequence `Waiting` with
    /// [`Sequence::prefilled_tokens`] > 0 at the head of the queue.
    Waiting,
    /// KV cache holds the prompt; decoding.
    Running,
    /// Preempted under memory pressure: either swapped out to the host
    /// ledger (KV parked, resumes via `swap_in`) or finished early for
    /// recompute, per the `swap_policy` decision (DESIGN.md §12).
    Preempted,
}

/// Per-sequence KV storage: dense `[L, H, S, Dh]` f32 blocks for K and V.
///
/// (The paged `kvcache::KvCacheManager` tracks the *logical* block
/// accounting; this is the physical storage the dense AOT artifacts consume
/// — see DESIGN.md §2.)
#[derive(Clone, Debug)]
pub struct SeqKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// A live sequence.
#[derive(Clone, Debug)]
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub params: SamplingParams,
    pub priority: Priority,
    pub state: SeqState,
    pub kv: Option<SeqKv>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Option<Instant>,
    /// Logical engine step at submission (the step-clock TTFT anchor and
    /// the aging rule's reference point; 0 outside an engine).
    pub submitted_step: u64,
    /// Logical engine step of this sequence's most recent token (drives
    /// the per-event `inter_token_steps`).
    pub last_token_step: Option<u64>,
    /// Prompt tokens already resident in the KV cache from completed
    /// prefill chunks (counts prefix-cache-attached tokens too).  0 until
    /// the first chunk lands; a partially-prefilled sequence waits at the
    /// queue head with this nonzero until the final chunk samples its
    /// first token (DESIGN.md §12).
    pub prefilled_tokens: usize,
    pub timing: RequestTiming,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Self {
            id: req.id,
            prompt: req.prompt,
            generated: Vec::new(),
            params: req.params,
            priority: req.priority,
            state: SeqState::Waiting,
            kv: None,
            arrived: Instant::now(),
            first_token_at: None,
            last_token_at: None,
            submitted_step: 0,
            last_token_step: None,
            prefilled_tokens: 0,
            timing: RequestTiming::default(),
        }
    }

    /// Total tokens resident in the KV cache (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Position at which the *next* token will be written.
    pub fn next_pos(&self) -> usize {
        self.context_len() - 1
    }

    /// The token to feed into the next decode step.
    pub fn input_token(&self) -> i32 {
        *self.generated.last().unwrap_or_else(|| {
            self.prompt.last().expect("prompt must be non-empty")
        })
    }

    /// Has the sequence hit a stop condition?
    pub fn finished(&self) -> Option<FinishReason> {
        if let Some(&last) = self.generated.last() {
            if self.params.stop_tokens.contains(&last) {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.params.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    pub fn into_completion(self, finish: FinishReason) -> Completion {
        Completion {
            id: self.id,
            prompt_len: self.prompt.len(),
            tokens: self.generated,
            finish,
            timing: self.timing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: Vec<i32>, max_new: usize) -> Request {
        Request::new(
            1,
            prompt,
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn positions_and_inputs() {
        let mut s = Sequence::new(req(vec![5, 6, 7], 4));
        assert_eq!(s.context_len(), 3);
        assert_eq!(s.next_pos(), 2);
        assert_eq!(s.input_token(), 7);
        s.generated.push(42);
        assert_eq!(s.context_len(), 4);
        assert_eq!(s.next_pos(), 3);
        assert_eq!(s.input_token(), 42);
    }

    #[test]
    fn finish_conditions() {
        let mut s = Sequence::new(req(vec![1], 2));
        assert_eq!(s.finished(), None);
        s.generated.push(9);
        assert_eq!(s.finished(), None);
        s.generated.push(9);
        assert_eq!(s.finished(), Some(FinishReason::MaxTokens));

        let mut s = Sequence::new(Request::new(
            2,
            vec![1],
            SamplingParams {
                max_new_tokens: 100,
                stop_tokens: vec![0, 7],
                ..Default::default()
            },
        ));
        s.generated.push(3);
        assert_eq!(s.finished(), None);
        s.generated.push(7); // any stop token ends generation
        assert_eq!(s.finished(), Some(FinishReason::StopToken));
        s.generated[1] = 0;
        assert_eq!(s.finished(), Some(FinishReason::StopToken));
    }

    #[test]
    fn params_validation_catches_bad_fields() {
        let v = 128usize;
        assert!(SamplingParams::default().validate(v).is_ok());
        let bad = [
            SamplingParams { temperature: 0.0, ..Default::default() },
            SamplingParams { temperature: f32::NAN, ..Default::default() },
            SamplingParams { top_k: Some(0), ..Default::default() },
            SamplingParams { top_p: Some(0.0), ..Default::default() },
            SamplingParams { top_p: Some(1.5), ..Default::default() },
            SamplingParams { max_new_tokens: 0, ..Default::default() },
            SamplingParams { logit_bias: vec![(200, 1.0)], ..Default::default() },
            SamplingParams {
                logit_bias: vec![(1, f32::NAN)],
                ..Default::default()
            },
            SamplingParams {
                logit_bias: vec![(1, f32::INFINITY)],
                ..Default::default()
            },
            SamplingParams { banned_tokens: vec![-1], ..Default::default() },
            SamplingParams { stop_tokens: vec![128], ..Default::default() },
        ];
        for (i, p) in bad.iter().enumerate() {
            assert!(p.validate(v).is_err(), "case {i} should fail");
        }
        let rich = SamplingParams {
            temperature: 0.7,
            top_k: Some(16),
            top_p: Some(0.95),
            logit_bias: vec![(3, -1.0), (4, f32::NEG_INFINITY)],
            banned_tokens: vec![5],
            seed: Some(9),
            stop_tokens: vec![0],
            ..Default::default()
        };
        assert!(rich.validate(v).is_ok());
        assert_eq!(
            rich.artifact_unsupported(),
            vec!["top_k", "top_p", "logit_bias", "banned_tokens", "seed"]
        );
        assert!(SamplingParams::default().artifact_unsupported().is_empty());
    }

    #[test]
    fn params_transform_builds_bias_vector() {
        let p = SamplingParams {
            temperature: 2.0,
            logit_bias: vec![(1, 0.5), (1, 0.25)], // additive accumulation
            banned_tokens: vec![3],
            ..Default::default()
        };
        let t = p.transform(4);
        assert_eq!(t.temperature, 2.0);
        let b = t.bias.as_ref().unwrap();
        assert_eq!(b[0], 0.0);
        assert_eq!(b[1], 0.75);
        assert_eq!(b[3], f32::NEG_INFINITY);
        // No bias fields => no bias vector allocated.
        assert!(SamplingParams::default().transform(4).bias.is_none());
        // Out-of-vocab entries (caught by validate()) must not panic here.
        let bad = SamplingParams {
            logit_bias: vec![(-1, 1.0), (99, 1.0)],
            banned_tokens: vec![-5, 77],
            ..Default::default()
        };
        let t = bad.transform(4);
        assert_eq!(t.bias.as_ref().unwrap(), &vec![0.0f32; 4]);
    }

    #[test]
    fn priority_ranks_parse_and_default() {
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::Low.rank() < Priority::Normal.rank());
        assert!(Priority::Normal.rank() < Priority::High.rank());
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            let back: Priority = p.to_string().parse().unwrap();
            assert_eq!(back, p);
        }
        assert!(" high ".parse::<Priority>().is_ok()); // trimmed
        assert!("urgent".parse::<Priority>().is_err());
        // New requests default to Normal.
        assert_eq!(req(vec![1], 1).priority, Priority::Normal);
        assert_eq!(Sequence::new(req(vec![1], 1)).priority, Priority::Normal);
    }

    #[test]
    fn row_key_prefers_per_request_seed() {
        let session = Key::new(1, 2);
        assert_eq!(SamplingParams::default().row_key(session), session);
        let seeded = SamplingParams { seed: Some(0xBEEF), ..Default::default() };
        assert_eq!(seeded.row_key(session), Key::from_seed(0xBEEF));
        assert_ne!(seeded.row_key(session), session);
    }
}
