//! Minimal JSON reader (offline substitute for `serde_json`).
//!
//! Parses the strict subset emitted by `python/compile/aot.py` (objects,
//! arrays, strings, numbers, booleans, null; UTF-8; `\uXXXX` escapes).  Used
//! only at startup to read `artifacts/manifest.json`; not a general-purpose
//! parser and deliberately rejects anything malformed.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the missing key's name.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => bail!("expected string, got {v:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            v => bail!("expected number, got {v:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => bail!("expected array, got {v:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            v => bail!("expected object, got {v:?}"),
        }
    }

    /// Convenience: `[1, 2, 3]` -> `Vec<usize>` (tensor shapes).
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing bytes at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at offset {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at offset {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // Consume a full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7F => Ok(1),
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"éé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"éé");
    }

    #[test]
    fn shape_helper() {
        let v = parse("[4, 256, 64]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![4, 256, 64]);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_bad_shape() {
        assert!(parse("[1.5]").unwrap().as_shape().is_err());
        assert!(parse("[-1]").unwrap().as_shape().is_err());
    }

    #[test]
    fn roundtrips_manifest_like_document() {
        let doc = r#"{
          "artifacts": [
            {"name": "flash_sample_b4", "file": "x.hlo.txt",
             "inputs": [{"name": "h", "shape": [4, 256], "dtype": "f32"}],
             "meta": {"B": 4, "tile_v": 512}}
          ],
          "weights": []
        }"#;
        let v = parse(doc).unwrap();
        let a = &v.req("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.req("name").unwrap().as_str().unwrap(), "flash_sample_b4");
        assert_eq!(
            a.req("inputs").unwrap().as_arr().unwrap()[0]
                .req("shape")
                .unwrap()
                .as_shape()
                .unwrap(),
            vec![4, 256]
        );
        assert_eq!(a.req("meta").unwrap().req("B").unwrap().as_usize().unwrap(), 4);
    }
}
